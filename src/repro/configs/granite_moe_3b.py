"""Granite-3.0 MoE 3B (800M active) — 40 experts, top-8.

[hf:ibm-granite/granite-3.0-3b-a800m-base; pool spec primary: 40e top-8]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE every layer.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    moe_top_k=8,
    moe_period=1,
    expert_pad_to=48,   # 40 experts tile the 16-way model axis as 48 (3/shard)
    head_pad_to=32,     # 24 heads tile the 16-way model axis as 32 (masked)
    rope_theta=1e4,
    tie_embeddings=True,
)
