"""SeamlessM4T-large-v2 — encoder-decoder backbone, stub audio frontend.

[arXiv:2308.11596]
24L (encoder) + 24L (decoder) d_model=1024 16H (kv=16) d_ff=8192
vocab=256206.  The speech frontend (conformer feature extractor) is a STUB:
input_specs() provides precomputed frame embeddings (B, frames, d_model).
Decode shapes lower the text-decoder step (self-attn KV cache + cross-attn
over encoder states) — enc-dec is NOT encoder-only, so decode applies.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    frontend_frames=1024,
    rope_theta=1e4,
)
