"""The paper's own artifact: a standalone distributed l-NN service config.

Mirrors the paper's experimental setup (Section 3): synthetic points
distributed over the mesh, scalar or d-dimensional, query broadcast,
answer = l nearest.  Used by examples/quickstart.py, launch/serve.py
--arch knn-service, and — via the ``service_*`` fields — the micro-batched
query service in runtime/knn_server.py.  This dataclass is the single
source of service tuning: bucket shapes, selection knobs, and the
selection-vs-gather A/B switch all live here (benchmarks/bench_serve.py
sweeps them; nothing else hard-codes a service parameter).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class KnnServiceConfig:
    name: str = "knn-service"
    n_points: int = 1 << 22          # paper: 2^22 points per process
    dim: int = 64                    # paper uses scalars; dim=1 reproduces it
    l: int = 128                     # neighbors per query
    query_batch: int = 8
    num_classes: int = 16            # for the classification head
    value_range: float = 4294967295.0  # paper: U[0, 2^32 - 1]

    # ---- micro-batched query service (runtime/knn_server.py) ------------
    # Incoming requests are coalesced into one of these device batch shapes
    # (ascending; each a static jit specialization).  A flush picks the
    # smallest bucket >= pending count and pads the rest with l=0 rows.
    bucket_sizes: tuple = (1, 2, 4, 8, 16, 32)
    # Shared static upper bound on per-request l — the (B, l_max) buffer
    # width every bucket compiles against; requests may ask for any l in
    # [1, l_max] (per-row masking inside knn_query_batched).
    l_max: int = 128
    # Micro-batcher linger: how long the background batcher waits for more
    # requests after the first one arrives before dispatching a partial
    # bucket.
    max_wait_ms: float = 2.0
    # Algorithm knobs, passed straight through to Algorithm 2.
    use_sampling: bool = True        # Lemma 2.3 sample-and-prune on/off
    num_pivots: int = 1              # >1 = beyond-paper multi-pivot mode
    # A/B switch: "selection" = Algorithm 2 (O(log l) rounds), "gather" =
    # the paper's simple method (knn_simple; one O(k*l)-value all_gather).
    sampler: str = "selection"
    # Distance computation: "auto" routes through kernels/ops.py (Pallas
    # kernel on TPU, jnp oracle elsewhere); "jnp" forces the pure-jnp path.
    distance_impl: str = "auto"
    # Shard routing (store/summaries.py): "exact" sends every query to all
    # k shards (the paper's collective); "pruned" consults per-shard pivot
    # summaries (centroid + covering radius + random-projection sketch)
    # and masks shards that provably cannot hold an l-NN winner.  Answers
    # are bit-identical either way (tests/test_routing.py); only the
    # k-machine message/round bill and QueryResult.shards_touched change.
    route: str = "exact"
    # Relative float-safety margin of the routing lower-bound test: a
    # shard is kept unless lb > T*(1+slack) + err, where err is the
    # magnitude-absolute f32 rounding bound computed per query
    # (summaries.pipeline_error_bound) — so pipeline rounding can never
    # turn a mathematically sound prune into a dropped winner, even for
    # data far from the origin.
    route_slack: float = 1e-4
    # Random-projection sketch width (directions per summary) and the seed
    # of the shared direction matrix (deterministic: two servers over the
    # same generation must route identically).  Store-backed servers take
    # the sketch from the store (MutableStore summary_projections /
    # summary_seed); a mismatch with these values raises at construction.
    route_num_projections: int = 8
    route_proj_seed: int = 0
    # Where the route="pruned" decision is computed: "host" runs the f64
    # numpy route_shards per dispatch (a serial host pass ahead of the
    # launch); "device" folds the identical decision into the service
    # executable's prologue (kernels/routing.py — f32, bit-identical
    # masks on every tested instance, tests/test_routing.py) so routing
    # rides the batch's own launch and the touched-shard set returns
    # with the answers.  Ignored under route="exact".
    route_compute: str = "host"

    # ---- mutable sharded store (store/mutable.py) -----------------------
    # Slots per shard of the capacity-padded buffers; fixes every compiled
    # shape, so the store can mutate forever without recompilation.
    store_capacity_per_shard: int = 2048
    # Write-ahead staging: pending mutations auto-flush (one scatter + one
    # epoch swap) once this many ops are queued.
    store_staging_size: int = 128
    # Compaction triggers (store/compaction.py): repack when dead slots
    # exceed this fraction of occupied slots...
    store_compact_tombstone_frac: float = 0.35
    # ...or when (max_live - min_live) / capacity exceeds this skew.
    store_compact_imbalance_frac: float = 0.5
    # Placement subsystem (store/placement.py): "balance" sends each
    # applied insert to the emptiest shard; "affinity" sends it to the
    # nearest live summary centroid so clusters stay shard-coherent and
    # route="pruned" can skip shards on store-backed serving too.
    placement: str = "balance"
    # Affinity balance guardrail: only shards within this many live
    # points of the global minimum are eligible, so insert-only streams
    # can never skew live counts beyond guard_slack + 1 — far below the
    # compaction imbalance trigger, which therefore never thrashes.
    placement_guard_slack: int = 32
    # Compaction re-deal mode: "round_robin" deals live points by id;
    # "proximity" re-deals them to Lloyd-centroid-owned shards (balanced
    # to within one, ids stable) so a repack *restores* locality instead
    # of smearing it.
    redeal: str = "round_robin"
    # ---- adaptive summary maintenance (store/adaptive.py) ----------------
    # Pivot balls per shard summary: 1 is the classic single-ball form;
    # >1 lets one shard host several small clusters without voiding its
    # routing bounds (the lower bound becomes the min over pivots, still
    # provably exact).  Store-backed pruned servers must match the store,
    # like the sketch knobs above.
    summary_pivots: int = 1
    # Scheduled exact re-tightening: a shard that absorbs this many ops
    # since its last exact rebuild becomes due; the store re-tightens at
    # most ONE due shard per flush (round-robin, O(live·dim) host work) so
    # covering radii shrink back to the live spread mid-stream instead of
    # inflating until the next compaction.  0 disables.
    retighten_every: int = 0
    # Radius-triggered shard splitting: when a shard's covering radius
    # exceeds this factor times the gap to its nearest occupied neighbor
    # centroid (and has grown since its last exact rebuild), the store
    # schedules its own quota-bounded proximity re-deal instead of
    # waiting for the tombstone/imbalance compaction trigger.  0 disables.
    split_radius_factor: float = 0.0
    # Maintenance execution plane (store/maintenance.py): "inline" runs
    # re-tightening / splits / auto-compaction at the tail of every flush
    # under the store lock (today's exact behavior); "background" moves
    # them to a worker thread that plans by a sampled summary-slack
    # probe, prepares repacked buffers off-lock, and commits via the
    # epoch swap under a short lock window — flushes stop paying for
    # maintenance and in-flight micro-batches keep serving their
    # snapshot.  Answers are bit-identical either way at every
    # generation (tests/test_async_maintenance.py).
    maintenance: str = "inline"
    # ---- in-shard approximate search index (store/index.py) --------------
    # "exact" (default) brute-forces every live slot of every touched
    # shard — answers bit-identical to the paper's collective.  "approx"
    # adds the per-shard bucket index: a query prologue keeps only the
    # covering-ball buckets whose lower bound can still hold a top-l
    # winner and masks the rest of the slots, trading exactness for a
    # measured recall contract (recall_floor, audited by the shadow
    # replay and hard-asserted by bench_serve's "index" section).
    search: str = "exact"
    # Covering-ball buckets per shard (store/index.py); store-backed
    # approx servers must match the store's index_buckets, like the
    # summary knobs.  Ignored under search="exact".
    index_buckets: int = 8
    # Candidate oversampling: the bucket keep rule targets
    # max(l, ceil(index_oversample · l)) cumulative live points before
    # it stops keeping buckets.  Larger = higher recall, more
    # candidates; large enough that the target is never reached keeps
    # every bucket (bit-identical to exact).
    index_oversample: float = 2.0
    # The serving recall contract: the shadow-exact audit flags any
    # approx batch whose measured recall@l drops below this floor.
    recall_floor: float = 0.95

    # ---- label prediction (src/repro/predict/) --------------------------
    # What to predict from the neighbors' label payloads: "none" (default)
    # serves ids/distances only; "vote" majority-votes a class id over
    # num_classes classes; "regress" means the label values.  Requires a
    # labeled backing (MutableStore with_labels=True, or the static
    # labels= constructor arg).
    predict: str = "none"
    # How the prediction is computed: "exact" runs Algorithm 2 and folds
    # the winner mask into the vote inside the fused executable — the
    # label is bit-identical to a single-machine vote/mean over the true
    # l nearest neighbors, for +1 round / +(t-1) messages (the class
    # histogram crossing the network).  "ensemble" skips the selection
    # collectives entirely: each routed shard answers its local-kNN vote
    # in ONE message (arXiv 1812.05005) and the host aggregates — the
    # message bill is exactly touched_shards, and accuracy-vs-exact is a
    # measured contract (accuracy_floor).  Ensemble requires
    # search="exact" and host-computed routing (route_compute="host").
    predict_mode: str = "exact"
    # Ensemble local-k rule: 0 (auto) uses ceil(l / touched_shards) — the
    # budget split arXiv 1812.05005 analyzes, which degenerates to the
    # exact vote on a 1-shard store; >0 pins every shard's local k.
    local_k: int = 0
    # The ensemble accuracy contract: the accuracy-mode shadow audit
    # (obs/audit.py) flags any sampled batch whose ensemble-vs-exact
    # label agreement drops below this floor.
    accuracy_floor: float = 0.9
    # Label-agreement SLO (obs/slo.py): lower bound on the shadow-audited
    # agreement fraction, burn-rate-windowed like the recall floor.
    # 0 = off.
    slo_label_agreement_floor: float = 0.0

    # ---- observability plane (src/repro/obs/) ---------------------------
    # Flight-recorder tracing: when on, the server records spans for the
    # full request lifecycle (enqueue -> queued -> dispatch -> snapshot ->
    # route -> kernel -> resolve) and the maintenance worker's
    # plan/prepare/commit/discard phases into a fixed ring buffer
    # (obs/trace.py); export with KnnServer.export_trace_jsonl().  Off
    # by default: the disabled plane is a shared no-op (NULL_TRACER).
    # The metrics registry is always live regardless of this knob.
    obs_trace: bool = False
    # Ring capacity (finished spans retained; newest win).
    obs_trace_capacity: int = 8192
    # Shadow-exact auditing: every Nth routed (pruned) micro-batch is
    # replayed through the exact collective at the same generation and
    # byte-compared (obs/audit.py).  0 disables.  The Theorem-1
    # round/message contract auditor is always on (it is arithmetic on
    # numbers the server already computes).
    obs_audit_every: int = 0
    # ---- SLO engine (obs/slo.py) — all objectives opt-in ----------------
    # Each knob declares one promise; leaving it at its zero default
    # leaves that objective un-monitored, and with no objective declared
    # the server constructs no engine at all.  Fired/cleared alerts
    # surface as slo.* spans in the trace ring, slo.alerts_* counters in
    # the registry, and obs_snapshot()["slo"].
    # Per-request end-to-end latency promise (seconds; the p99 framing:
    # with the default 1% budget, the burn rate is 1.0 exactly when 1%
    # of windowed requests exceed the bound).  0 = off.
    slo_latency_p99_s: float = 0.0
    # Shadow-audited minimum recall@l promise (lower bound; only
    # meaningful with obs_audit_every > 0 on an approx server).  0 = off.
    slo_recall_floor: float = 0.0
    # Answer-generation staleness promise: how many generations behind
    # the store head an answer may be computed (epoch-swapped serving is
    # normally 0-1 behind).  0 = off.
    slo_staleness_generations: int = 0
    # Promise that the Theorem-1 round/message envelope never trips
    # (any contract-audit violation is a bad event).  False = off.
    slo_contract_violations: bool = False
    # Multi-window burn-rate mechanics: an alert fires when the bad-
    # event fraction over BOTH windows exceeds burn_threshold × budget,
    # and clears when the fast window's burn drops back under threshold.
    slo_fast_window_s: float = 60.0
    slo_slow_window_s: float = 300.0
    slo_burn_threshold: float = 1.0
    slo_budget: float = 0.01
    # ---- metrics exposition endpoint (obs/export.py) --------------------
    # >0: serve Prometheus text (/metrics), OTLP-ish JSON
    # (/metrics.json), and the full obs snapshot (/obs) on this
    # localhost port via a stdlib ThreadingHTTPServer; -1: bind an
    # ephemeral port (tests); 0 (default): no endpoint.
    obs_http_port: int = 0

    def replace(self, **kw) -> "KnnServiceConfig":
        return dataclasses.replace(self, **kw)

    def store_kwargs(self) -> dict:
        """MutableStore construction kwargs this config pins — the single
        source of service tuning extends to the store: capacity, staging,
        compaction triggers, placement policy, re-deal mode, the routing
        sketch (matched to route_num_projections/route_proj_seed so a
        store-backed ``route="pruned"`` server always constructs), and
        the adaptive-maintenance knobs (summary_pivots matched the same
        way)."""
        return dict(
            capacity_per_shard=self.store_capacity_per_shard,
            staging_size=self.store_staging_size,
            compact_tombstone_frac=self.store_compact_tombstone_frac,
            compact_imbalance_frac=self.store_compact_imbalance_frac,
            placement=self.placement,
            placement_guard_slack=self.placement_guard_slack,
            redeal=self.redeal,
            summary_projections=self.route_num_projections,
            summary_seed=self.route_proj_seed,
            summary_pivots=self.summary_pivots,
            retighten_every=self.retighten_every,
            split_radius_factor=self.split_radius_factor,
            maintenance=self.maintenance,
            index_buckets=self.index_buckets if self.search == "approx"
            else 0,
            with_labels=self.predict != "none")


CONFIG = KnnServiceConfig()
