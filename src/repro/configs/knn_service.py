"""The paper's own artifact: a standalone distributed l-NN service config.

Mirrors the paper's experimental setup (Section 3): synthetic points
distributed over the mesh, scalar or d-dimensional, query broadcast,
answer = l nearest.  Used by examples/quickstart.py and launch/serve.py
--arch knn-service.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class KnnServiceConfig:
    name: str = "knn-service"
    n_points: int = 1 << 22          # paper: 2^22 points per process
    dim: int = 64                    # paper uses scalars; dim=1 reproduces it
    l: int = 128                     # neighbors per query
    query_batch: int = 8
    num_classes: int = 16            # for the classification head
    value_range: float = 4294967295.0  # paper: U[0, 2^32 - 1]


CONFIG = KnnServiceConfig()
