"""Qwen2-0.5B — dense GQA decoder with QKV bias.  [arXiv:2407.10671]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, head_dim 64.
Also the ~100M-class backbone used by examples/train_100m.py (reduced).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    head_pad_to=16,     # 14 heads tile the 16-way model axis (masked)
    rope_theta=1e6,
    tie_embeddings=True,
)
