"""Qwen1.5-4B — dense MHA (kv == heads) decoder with QKV bias.

[arch pool spec; hf:Qwen/Qwen1.5-0.5B family card]
40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936, head_dim 128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    head_pad_to=32,     # MHA 20 heads -> 32 physical (masked)
    kv_head_pad_to=32,
    rope_theta=1e6,
)
