"""xLSTM-125M — alternating mLSTM / sLSTM blocks.  [arXiv:2405.04517]

12L d_model=768 4H d_ff=0 (blocks carry their own projections)
vocab=50304.  Sub-quadratic: runs the long_500k cell with O(1)-per-token
recurrent state.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    slstm_period=2,
    slstm_offset=1,
    tie_embeddings=True,
)
