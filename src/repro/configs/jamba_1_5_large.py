"""Jamba-1.5-Large (398B) — Mamba + attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887 / Jamba-1.5 tech report]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; attention at every
8th layer (1:7), MoE FFN every 2nd layer.  The long_500k cell runs on this
arch (sub-quadratic Mamba backbone; the 9 attention layers use a
sequence-sharded KV cache — DESIGN.md Section 5).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    n_experts=16,
    moe_top_k=2,
    moe_period=2,
    attn_period=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=1e6,
)
