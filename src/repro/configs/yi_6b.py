"""Yi-6B — llama-architecture GQA decoder.  [arXiv:2403.04652]

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, head_dim 128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab=64000,
    qkv_bias=False,
    rope_theta=5e6,
)
