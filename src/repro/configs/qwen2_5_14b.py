"""Qwen2.5-14B — dense GQA decoder with QKV bias.

[arch pool spec; hf:Qwen/Qwen2.5-0.5B family card for the bias/GQA scheme]
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, head_dim 128.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    head_pad_to=48,     # 40 heads tile the 16-way model axis as 48 (masked)
    rope_theta=1e6,
)
