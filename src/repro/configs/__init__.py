"""Architecture registry: one module per assigned architecture.

`get(name)` returns the exact published ModelConfig; `registry()` lists all.
"""

from __future__ import annotations

import importlib

_ARCHS = (
    "qwen2_5_14b",
    "qwen1_5_4b",
    "qwen2_0_5b",
    "yi_6b",
    "phi3_5_moe_42b",
    "granite_moe_3b",
    "jamba_1_5_large",
    "pixtral_12b",
    "seamless_m4t_v2",
    "xlstm_125m",
)

_ALIASES = {
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen1.5-4b": "qwen1_5_4b",
    "qwen2-0.5b": "qwen2_0_5b",
    "yi-6b": "yi_6b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
    "xlstm-125m": "xlstm_125m",
    "knn-service": "knn_service",
}


def get(name: str):
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def registry():
    return tuple(_ARCHS)


def all_names():
    return tuple(a for a in _ARCHS)
