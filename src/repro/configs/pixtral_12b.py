"""Pixtral-12B — mistral-nemo decoder + (stub) pixtral-ViT patch frontend.

[hf:mistralai/Pixtral-12B-2409; unverified tier]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim 128.
The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (B, num_prefix_embeds, d_model) that the
backbone consumes as a prefix.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    num_prefix_embeds=256,   # one 1024px image at 16x16 patches, pooled 4x
    rope_theta=1e9,
)
