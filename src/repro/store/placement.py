"""Locality-aware placement — which shard gets each point, and why.

PR 3's pruned routing (store/summaries.py) only pays when clusters are
*confined* to few shards: the lower-bound test can rule a shard out only
if its pivot set — one covering ball in the default single-pivot form,
up to ``summary_pivots`` balls under the adaptive maintainer
(store/adaptive.py) — sits far from the query.  The store's original
balance-first insert rule and round-robin repack smear every cluster
across all k shards, so covering radii overlap and routing proves almost
nothing — the static cluster-contiguous layout prunes to one shard while
the mutable store touches all k.  This module makes placement an explicit
subsystem so the streaming store can earn the same locality:

* **Placement policies** (:func:`make_placement`) decide the destination
  shard of each applied insert.  ``balance`` is the original emptiest-
  shard rule, extracted verbatim.  ``affinity`` routes a point to the
  nearest live summary centroid (the *aggregate* mean of the shard's
  pivot state — placement wants one mean per shard even when routing
  carries several pivot balls) — reusing the maintainer state the store
  already keeps incrementally for routing — under a
  balance guardrail: only shards whose live count is within
  ``guard_slack`` of the global minimum are eligible, so an insert-only
  history can never skew live counts beyond ``guard_slack + 1``
  (tests/test_placement.py pins the bound).  That keeps per-shard sample
  sizes comparable — the balance condition the distributed-kNN
  statistical guarantees rest on (Duan/Qiao/Cheng) — while still letting
  clusters pool.  A point outside every eligible shard's covering ball
  seeds an empty eligible shard instead (online k-center-style), which is
  how the k shards spread over the k clusters of a streaming mix.

* **Proximity re-deal** (:func:`repack_proximity`) is the compaction-time
  counterpart (``redeal="proximity"``): at repack, run a few Lloyd
  iterations over the live points (centroids seeded from the current
  shard summaries, completed farthest-point-first; empty clusters
  re-seeded deterministically), then assign points to centroid-owned
  shards under slack-bounded quotas (no shard above the even share by
  more than ``balance_slack``) — near the round-robin repack's balance,
  same id stability (only slots move), same dense per-shard prefixes,
  but cluster-coherent shards.  Assignment order is
  by descending regret (second-best minus best centroid distance), so the
  points with the most to lose claim their shard first when quotas bind.

Placement never affects answers — Algorithm 2 reduces over all live
points wherever they sit, and routing is proven exact for any layout
(tests/test_routing.py) — it only decides how much routing can prune.
tests/test_placement.py holds answers bit-identical across every
placement x redeal combination under interleaved mutation histories.
Policy interface, guardrail math, and re-deal invariants: DESIGN.md
Section 9.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

from repro.store import compaction

_INT64_MAX = np.iinfo(np.int64).max


class PlacementView(NamedTuple):
    """What a policy may look at when placing one point (store lock held).

    ``live``/``used``: (k,) live counts and high-water marks; ``cap``:
    slots per shard; ``centroids``: (k, dim) float64 live means (zeros
    where empty); ``radii``: (k,) covering radii; ``occupied``: (k,) bool
    — whether the centroid/radius row describes any live point.  The
    store builds the centroid/radius/occupied triple only for policies
    that declare ``uses_centroids`` (it costs O(k·dim) per insert); a
    policy that opts out receives None in those three fields.
    """

    live: np.ndarray
    used: np.ndarray
    cap: int
    centroids: np.ndarray
    radii: np.ndarray
    occupied: np.ndarray


class PlacementPolicy:
    """One staged insert -> one destination shard.

    ``pick`` returns the shard index, or -1 if no shard has tail space
    (``used == cap`` everywhere) — the store then repacks and retries.
    Policies are consulted under the store lock with the view reflecting
    every previously applied op of the same flush, so a policy sees its
    own earlier placements.  ``uses_centroids`` (default True — safe for
    custom policies) tells the store whether to pay for the view's
    centroid/radius/occupied fields; only policies that never read them
    should set it False.
    """

    name: str = "base"
    uses_centroids: bool = True

    def pick(self, point: Optional[np.ndarray], view: PlacementView) -> int:
        raise NotImplementedError


def _balance_pick(view: PlacementView, eligible: np.ndarray) -> int:
    """Least-loaded eligible shard, smallest index on ties."""
    live = np.where(eligible, view.live, _INT64_MAX)
    return int(np.argmin(live))


class BalancePlacement(PlacementPolicy):
    """The original rule: emptiest shard with tail space, ignoring the
    point entirely (Duan/Qiao-style shard balance, nothing else)."""

    name = "balance"
    uses_centroids = False

    def pick(self, point, view: PlacementView) -> int:
        open_mask = view.used < view.cap
        if not open_mask.any():
            return -1
        return _balance_pick(view, open_mask)


class AffinityPlacement(PlacementPolicy):
    """Nearest-live-centroid placement under a balance guardrail.

    Eligibility: tail space AND ``live <= min(live) + guard_slack``.  An
    insert into an eligible shard leaves it at most ``guard_slack + 1``
    above the global minimum, which is the whole guardrail proof — no
    insert-only history can skew further, so the compaction imbalance
    trigger (a fraction of *capacity*) never fires off the back of
    affinity placement.  Delete-driven skew is out of a placement
    policy's hands; that regime stays the compactor's job.

    Among eligible shards: nearest occupied centroid wins, unless an
    empty eligible shard exists and the point is an outsider — farther
    from its nearest centroid than both that shard's covering radius and
    half the gap to the centroid's nearest occupied neighbor (the
    natural new-cluster test; radius alone misfires during cold start,
    when one-point shards have radius zero and *everything* looks
    outside).  Outsiders seed the empty shard (lowest index) so a
    previously unseen cluster claims fresh capacity instead of inflating
    a foreign shard's radius.  If the guardrail leaves nothing eligible
    (possible only with tombstones: the min-live shard may have no
    tail), fall back to the balance rule over open shards.
    """

    def __init__(self, guard_slack: int = 32):
        if guard_slack < 0:
            raise ValueError(f"guard_slack must be >= 0, got {guard_slack}")
        self.guard_slack = int(guard_slack)
        self.name = "affinity"

    def pick(self, point, view: PlacementView) -> int:
        open_mask = view.used < view.cap
        if not open_mask.any():
            return -1
        eligible = open_mask & (view.live <= view.live.min()
                                + self.guard_slack)
        if not eligible.any():
            return _balance_pick(view, open_mask)
        candidates = eligible & view.occupied
        if not candidates.any():
            return _balance_pick(view, eligible)
        p = np.asarray(point, np.float64)
        d = np.full(view.live.shape, np.inf)
        d[candidates] = np.sqrt(
            ((view.centroids[candidates] - p) ** 2).sum(-1))
        j = int(np.argmin(d))
        empties = eligible & ~view.occupied
        if empties.any() and d[j] > self._seed_threshold(view, j):
            return int(np.argmax(empties))
        return j

    @staticmethod
    def _seed_threshold(view: PlacementView, j: int) -> float:
        """How far outside shard j a point must sit to seed an empty
        shard instead: beyond the covering radius AND beyond half the
        gap to j's nearest occupied neighbor centroid."""
        half_gap = 0.0
        others = view.occupied.copy()
        others[j] = False
        if others.any():
            half_gap = 0.5 * float(np.sqrt(
                ((view.centroids[others] - view.centroids[j]) ** 2)
                .sum(-1)).min())
        return max(float(view.radii[j]), half_gap)


def make_placement(name, *, guard_slack: int = 32) -> PlacementPolicy:
    """Policy factory; accepts an already-built policy unchanged (the
    pluggable path for custom policies)."""
    if isinstance(name, PlacementPolicy):
        return name
    if name == "balance":
        return BalancePlacement()
    if name == "affinity":
        return AffinityPlacement(guard_slack=guard_slack)
    raise ValueError(
        f"unknown placement policy {name!r} (want 'balance', 'affinity', "
        f"or a PlacementPolicy instance)")


# ---- proximity re-deal (compaction-time counterpart) ---------------------

def _farthest_point_seeds(pts: np.ndarray, seeds: list, k: int) -> np.ndarray:
    """Complete ``seeds`` to k rows by greedy farthest-point traversal of
    ``pts`` — deterministic (argmax takes the first maximum)."""
    if not seeds:
        seeds = [pts[int(np.argmax(
            ((pts - pts.mean(0)) ** 2).sum(-1)))]]
    while len(seeds) < k:
        d = ((pts[:, None, :] - np.asarray(seeds)[None]) ** 2).sum(-1)
        seeds.append(pts[int(np.argmax(d.min(1)))])
    return np.asarray(seeds, np.float64)


def lloyd_centroids(pts: np.ndarray, k: int, *,
                    seed_centroids: Optional[np.ndarray] = None,
                    iters: int = 4) -> np.ndarray:
    """(k, dim) centroids after ``iters`` Lloyd steps, no RNG anywhere.

    Seeds: ``seed_centroids`` rows (the live shard centroids at repack
    time), completed farthest-point-first from the points when fewer than
    k are supplied.  Clusters that come up empty re-seed to the points
    currently farthest from their assigned centroid, each empty cluster
    taking a distinct point — identical seeds can never permanently
    collapse the iteration.
    """
    pts = np.asarray(pts, np.float64)
    seeds = [] if seed_centroids is None else [
        np.asarray(c, np.float64) for c in seed_centroids[:k]]
    cents = _farthest_point_seeds(pts, seeds, k)
    for _ in range(max(iters, 1)):
        d = ((pts[:, None, :] - cents[None]) ** 2).sum(-1)      # (n, k)
        assign = d.argmin(1)
        counts = np.bincount(assign, minlength=k)
        empty = np.flatnonzero(counts == 0)
        if empty.size:
            far = np.argsort(-d[np.arange(len(pts)), assign],
                             kind="stable")
            for i, c in enumerate(empty):
                cents[c] = pts[far[i % len(far)]]
            continue
        for j in range(k):
            cents[j] = pts[assign == j].mean(0)
    return cents


def repack_proximity(points: np.ndarray, ids: np.ndarray, valid: np.ndarray,
                     k: int, cap: int, *, id_sentinel: int,
                     seed_centroids: Optional[np.ndarray] = None,
                     balance_slack: int = 32,
                     lloyd_iters: int = 4) -> compaction.RepackResult:
    """Proximity re-deal: repack live points into cluster-coherent shards.

    Same contract as :func:`compaction.repack` — ids stable (only slots
    move), every shard's occupied region a dense prefix, deterministic —
    but destinations come from Lloyd centroids
    (:func:`lloyd_centroids`) instead of round-robin: shard j owns
    centroid j, and each point goes to the nearest centroid whose shard
    still has quota.  The balanced-capacity constraint is the quota
    ``min(cap, ceil(n/k) + balance_slack)``: no shard exceeds the even
    share by more than the slack, yet a natural cluster slightly larger
    than n/k stays whole instead of bleeding its tail into a foreign
    shard — one straggler point would otherwise inflate that shard's
    covering radius and void the very pruning the re-deal exists to buy.
    Points claim shards in descending regret order — the gap between
    their best and second-best centroid — so when quotas bind, the
    points that care most choose first.  Within a shard, points sit in
    ascending-id order.
    """
    dim = points.shape[1]
    total = k * cap
    live_slots = np.flatnonzero(valid)
    order = live_slots[np.argsort(ids[live_slots], kind="stable")]
    n = order.size
    assert n <= total

    new_pts = np.zeros((total, dim), points.dtype)
    new_ids = np.full(total, id_sentinel, np.int32)
    new_valid = np.zeros(total, bool)
    if n == 0:
        return compaction.RepackResult(
            points=new_pts, ids=new_ids, valid=new_valid, slot_of={},
            live=np.zeros(k, np.int64), used=np.zeros(k, np.int64))

    pts = np.asarray(points[order], np.float64)
    cents = lloyd_centroids(pts, k, seed_centroids=seed_centroids,
                            iters=lloyd_iters)
    d = ((pts[:, None, :] - cents[None]) ** 2).sum(-1)          # (n, k)
    pref = np.argsort(d, axis=1, kind="stable")                 # (n, k)
    if k > 1:
        d_sorted = np.take_along_axis(d, pref[:, :2], axis=1)
        regret = d_sorted[:, 1] - d_sorted[:, 0]
    else:
        regret = np.zeros(n)
    greedy = np.argsort(-regret, kind="stable")

    quota = np.full(k, min(cap, -(-n // k) + max(int(balance_slack), 0)),
                    np.int64)
    shard_of = np.empty(n, np.int64)
    for t in greedy:
        for j in pref[t]:
            if quota[j] > 0:
                quota[j] -= 1
                shard_of[t] = j
                break

    # points are already in ascending-id order, so a stable sort by shard
    # leaves each shard's members ascending by id
    by_shard = np.argsort(shard_of, kind="stable")
    live = np.bincount(shard_of, minlength=k).astype(np.int64)
    offsets = np.concatenate(([0], np.cumsum(live)[:-1]))
    dest = np.empty(n, np.int64)
    dest[by_shard] = (shard_of[by_shard] * cap
                      + (np.arange(n) - offsets[shard_of[by_shard]]))
    new_pts[dest] = points[order]
    new_ids[dest] = ids[order]
    new_valid[dest] = True
    slot_of = {int(i): int(s) for i, s in zip(ids[order], dest)}
    return compaction.RepackResult(points=new_pts, ids=new_ids,
                                   valid=new_valid, slot_of=slot_of,
                                   live=live, used=live.copy())
