"""Per-shard approximate search index — bucket-pruned candidates.

Pruned routing (store/summaries.py) skips *shards*, but every touched
shard still brute-forces all of its live slots: per-query cost stays
O(live/k · dim) no matter how tight the clusters are.  This module adds
the in-shard tier: each shard's live points are covered by up to ``b``
covering balls ("buckets") built on the same pivot machinery as the
routing summaries (store/adaptive.py ``compute_pivots``), and a query
prologue keeps only the buckets whose lower bound can still hold a
top-l winner — the surviving buckets' slots become the candidate mask
the masked fused kernel already understands (core/knn.py
``point_candidates``; every non-candidate competes as +inf exactly like
a tombstone).

The keep rule is the routing threshold at bucket granularity: order all
buckets (in routing-kept shards) by distance upper bound, find the
smallest ``T`` whose cumulative live count reaches
``target = max(l, ceil(oversample · l))``, keep buckets with
``lb <= T``.  Unlike shard routing this is *approximate* — a bucket's
live points are anywhere inside its ball, so the kept set can miss a
true winner whose bucket looked far — which is why the tier sits behind
``search="approx"`` and carries a measured recall contract
(``recall_floor``, audited by the serving layer's shadow-exact replay
and hard-asserted in benchmarks/bench_serve.py's "index" section)
instead of the repo's bit-identical invariant.  Two exactness anchors
remain: ``oversample`` large enough that the cumulative-live walk never
reaches the target keeps *every* live bucket — answers bit-identical to
exact (tests/test_index.py) — and a slot outside any bucket can only
happen for dead slots (every live slot is assigned at insert/rebuild).

Generation coupling mirrors the summaries: the :class:`IndexMaintainer`
is updated incrementally under the store lock on every applied op,
rebuilt exactly on any repack (inline or the background worker's
commit-replay), and frozen as an immutable :class:`ShardIndex` with
every generation — ``MutableStore.serving_snapshot()`` hands out
(snapshot, summaries, index) from one lock acquisition so
``index.generation == snapshot.generation`` always.  DESIGN.md §13.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.store import adaptive as adaptive_mod


class ShardIndex(NamedTuple):
    """One frozen generation of the in-shard bucket index.

    ``centers``: (k, b, dim) f64 bucket ball centers; ``radii``: (k, b)
    f64 covering radii; ``live``: (k, b) exact live count per bucket
    (exact, not the undercount credits of the routing summaries — the
    maintainer knows each slot's bucket, so deletes debit precisely);
    ``count``: (k,) occupied bucket slots per shard; ``assign``:
    (k*cap,) int32 slot -> bucket id within its shard, -1 for dead/free
    slots.
    """

    generation: int
    centers: np.ndarray
    radii: np.ndarray
    live: np.ndarray
    count: np.ndarray
    assign: np.ndarray

    @property
    def num_buckets(self) -> int:
        return self.centers.shape[1]


class IndexMaintainer:
    """Incrementally-maintained bucket index for one store; see module
    docstring.  All methods assume the store lock is held (the store's
    op hooks call them inside ``_apply_locked`` / the worker's
    commit-replay)."""

    def __init__(self, k: int, cap: int, dim: int, num_buckets: int):
        if num_buckets < 1:
            raise ValueError(f"num_buckets must be >= 1, got {num_buckets}")
        self.k = int(k)
        self.cap = int(cap)
        self.dim = int(dim)
        self.num_buckets = int(num_buckets)
        b = self.num_buckets
        self._centers = np.zeros((k, b, dim))
        self._radii = np.zeros((k, b))
        self._live = np.zeros((k, b), np.int64)
        self._count = np.zeros(k, np.int64)
        self._assign = np.full(k * cap, -1, np.int32)

    # ---- incremental ops -------------------------------------------------

    def insert(self, shard: int, slot: int, point) -> None:
        """Assign the new live slot to a bucket: claim a free bucket when
        the point sits outside every ball (same rule as the routing
        pivots), else join the ball needing the least inflation."""
        j = int(shard)
        p = np.asarray(point, np.float64)
        c = int(self._count[j])
        if c == 0:
            self._centers[j, 0] = p
            self._radii[j, 0] = 0.0
            self._count[j] = 1
            self._live[j, 0] = 1
            self._assign[slot] = 0
            return
        d = np.sqrt(((self._centers[j, :c] - p) ** 2).sum(-1))
        if (d > self._radii[j, :c]).all() and c < self.num_buckets:
            self._centers[j, c] = p
            self._radii[j, c] = 0.0
            self._count[j] = c + 1
            self._live[j, c] = 1
            self._assign[slot] = c
        else:
            t = int(np.argmin(d - self._radii[j, :c]))
            self._radii[j, t] = max(self._radii[j, t], float(d[t]))
            self._live[j, t] += 1
            self._assign[slot] = t

    def delete(self, slot: int) -> None:
        """Debit the slot's bucket exactly (the assignment is known,
        unlike the routing summaries' containing-ball undercount); the
        ball stays covering for its remaining members."""
        t = int(self._assign[slot])
        if t >= 0:
            j = int(slot) // self.cap
            self._live[j, t] = max(self._live[j, t] - 1, 0)
            self._assign[slot] = -1

    def update(self, slot: int, point) -> None:
        """An in-place overwrite keeps its bucket; the ball inflates to
        keep covering the moved point (stale-but-valid, like every
        incremental bound in this store)."""
        t = int(self._assign[slot])
        if t < 0:
            return
        j = int(slot) // self.cap
        d = float(np.sqrt(
            ((np.asarray(point, np.float64) - self._centers[j, t]) ** 2)
            .sum()))
        self._radii[j, t] = max(self._radii[j, t], d)

    # ---- exact rebuild ---------------------------------------------------

    def rebuild(self, points: np.ndarray, valid: np.ndarray) -> None:
        """Exact per-shard rebuild from the store mirrors (the repack /
        background-commit hook): farthest-point bucket centers
        (adaptive.compute_pivots), argmin assignment, exact radii and
        live counts."""
        pts = np.asarray(points, np.float64)
        valid = np.asarray(valid, bool)
        self._assign[:] = -1
        for j in range(self.k):
            sl = slice(j * self.cap, (j + 1) * self.cap)
            mine = np.flatnonzero(valid[sl])
            self._centers[j] = 0.0
            self._radii[j] = 0.0
            self._live[j] = 0
            if mine.size == 0:
                self._count[j] = 0
                continue
            pj = pts[sl][mine]
            piv, rad, cnt = adaptive_mod.compute_pivots(
                pj, self.num_buckets)
            self._centers[j, :cnt] = piv[:cnt]
            self._radii[j, :cnt] = rad[:cnt]
            self._count[j] = cnt
            dists = np.sqrt(
                ((pj[:, None, :] - piv[None, :cnt]) ** 2).sum(-1))
            assign = dists.argmin(1)
            self._live[j, :cnt] = np.bincount(assign, minlength=cnt)
            self._assign[sl][mine] = assign.astype(np.int32)

    def freeze(self, generation: int) -> ShardIndex:
        """Immutable copy coupled to ``generation`` (the store freezes
        one per epoch swap, beside the routing summaries)."""
        return ShardIndex(
            generation=int(generation),
            centers=self._centers.copy(),
            radii=self._radii.copy(),
            live=self._live.copy(),
            count=self._count.copy(),
            assign=self._assign.copy())


# ---- query-time candidate selection (host path) --------------------------


def bucket_keep(index: ShardIndex, queries, ls, shard_keep=None, *,
                oversample: float = 2.0) -> np.ndarray:
    """(B, k, b) bool — buckets that may hold a top-l winner, per query.

    The keep rule from the module docstring, f64 on host (the device
    mirror is kernels/routing.index_mask — f32, and NOT required to be
    bit-identical: the tier is approximate either way, and each path's
    recall is measured, not derived).  ``shard_keep`` (B, k) bool is the
    routing decision (None = all shards); rows with ``ls == 0`` (bucket
    padding) keep nothing.
    """
    q = np.atleast_2d(np.asarray(queries, np.float64))
    B = q.shape[0]
    k, b, _ = index.centers.shape
    ls = np.asarray(ls, np.int64).reshape(B)
    d = np.sqrt(
        ((q[:, None, None, :] - index.centers[None]) ** 2).sum(-1))
    occ = ((np.arange(b)[None, :] < index.count[:, None])
           & (index.live > 0))
    if shard_keep is None:
        shard_keep = np.ones((B, k), bool)
    g = occ[None] & np.asarray(shard_keep, bool)[:, :, None]
    lb = np.where(g, np.maximum(d - index.radii[None], 0.0) ** 2, np.inf)
    ub = np.where(g, (d + index.radii[None]) ** 2, np.inf)
    target = np.maximum(ls, np.ceil(oversample * ls).astype(np.int64))
    ubf = ub.reshape(B, -1)
    livef = np.where(g, index.live[None], 0).reshape(B, -1)
    order = np.argsort(ubf, axis=1, kind="stable")
    csum = np.cumsum(np.take_along_axis(livef, order, axis=1), axis=1)
    reached = csum >= target[:, None]
    has = reached.any(axis=1)
    first = np.where(has, reached.argmax(axis=1), 0)
    ub_sorted = np.take_along_axis(ubf, order, axis=1)
    # No T when total live < target: keep every live bucket (exact).
    T = np.where(has, ub_sorted[np.arange(B), first], np.inf)
    return g & (lb <= T[:, None, None]) & (ls > 0)[:, None, None]


def candidate_mask(index: ShardIndex, keep_any: np.ndarray,
                   cap: int) -> np.ndarray:
    """(k*cap,) bool slot candidates from a (k, b) batch-union bucket
    keep (the union-across-rows convention shard routing also uses —
    one collective pass serves the whole micro-batch)."""
    k, b = keep_any.shape
    a = index.assign
    shard = np.arange(k * cap) // cap
    return (a >= 0) & keep_any[shard, np.maximum(a, 0)]


def candidate_fraction(index: ShardIndex, keep_any: np.ndarray) -> float:
    """Kept live points / total live — the per-dispatch cost observable
    (serve.candidate_fraction); computed from the index's own live
    counts, no device readback."""
    total = int(index.live.sum())
    if total == 0:
        return 1.0
    return float(index.live[keep_any].sum()) / total
