"""Mutable, sharded point store with epoch-swapped snapshots.

The paper — and the whole query path built on it — assumes a static point
set wrapped once by ``core.datastore.build_local``.  Production kNN
services (kNN-LM stores, feature retrieval) must absorb inserts, deletes,
and updates *while serving*.  This module adds that layer without giving
up the repo's static-shape discipline:

* **Capacity-padded shard buffers.**  Each of the k shards owns ``cap``
  slots of a device-resident ``(k*cap, dim)`` point buffer (NamedSharding
  over the service axis) plus parallel ``ids``/``valid`` buffers.  Shapes
  never change, so no mutation ever recompiles an executable; a slot that
  holds no live point is masked by ``valid`` and competes in Algorithm 2
  exactly like the paper's +inf fake padding points.

* **Write-ahead staging.**  Mutations are staged host-side
  (:meth:`insert` / :meth:`delete` / :meth:`update` validate and enqueue;
  nothing is device-visible yet), then :meth:`flush` applies the whole
  batch: ops replay onto the host mirrors in submission order, and the
  net effect — one final value per touched slot — lands on device as a
  single padded scatter.  Auto-flush triggers at ``staging_size`` pending
  ops.

* **Generations / epoch swap.**  Every applied batch produces a fresh
  immutable :class:`StoreSnapshot` (device arrays + generation number);
  readers grab the current snapshot at dispatch time and keep computing
  against it even while newer generations land — jax array immutability
  makes the swap free and torn reads impossible.  The serving integration
  (``runtime/knn_server.py``) reports the generation each answer was
  computed against.

* **Placement policies** (``store/placement.py``).  Deletes leave
  tombstones; each applied insert asks the store's placement policy for
  a destination shard — ``balance`` (the emptiest-shard rule) or
  ``affinity`` (nearest live summary centroid under a balance
  guardrail), so a clustered stream can keep locality that pruned
  routing (Section 8) converts into skipped shards.

* **Compaction / rebalance** (``store/compaction.py``).  When tombstone
  density or shard imbalance crosses its threshold (or a shard's tail
  runs out while global space remains), the store repacks live points
  into dense, balanced prefixes — one full re-upload, one generation
  bump, ids stable throughout.  ``redeal="round_robin"`` deals by id;
  ``redeal="proximity"`` re-deals by Lloyd-centroid affinity under the
  same balanced-within-one guarantee (``store/placement.py``).

* **Adaptive summary maintenance** (``store/adaptive.py``).  The routing
  summaries the store keeps per op are covering but loosening; at the
  tail of every apply (when no repack already rebuilt them exactly) the
  store re-tightens at most one due shard (O(live·dim) host work,
  ``retighten_every`` op-count trigger) and lets a shard whose covering
  radius outgrew the inter-centroid gap schedule its own proximity
  re-deal (``split_radius_factor`` trigger, ``split_cooldown`` applies
  between splits) — pruned routing stays effective mid-stream instead of
  decaying until the next compaction.

* **Maintenance planes** (``store/maintenance.py``).  Under the default
  ``maintenance="inline"`` all of the above runs at the tail of
  ``_apply_locked`` under the store lock — exact, simple, and a stall
  every flush pays.  ``maintenance="background"`` hands re-tightening,
  splits, and auto-compaction to a worker thread: every applied op is
  journaled while the worker holds a capture, the worker prepares exact
  rebuilds / repacked buffers / device uploads entirely off-lock, then
  commits by replaying the journal and swapping the epoch under a short
  lock window.  Forced repacks (a full shard mid-flush) and explicit
  :meth:`compact` stay inline — they are correctness, not hygiene — and
  invalidate any in-flight capture.  Answers stay bit-identical to the
  inline plane at every generation (tests/test_async_maintenance.py).

Protocol details and the trigger math: DESIGN.md Sections 7, 9, 10,
and 11.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import NamedTuple, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.obs.trace import NULL_TRACER
from repro.parallel.compat import make_mesh
from repro.store import adaptive as adaptive_mod
from repro.store import compaction
from repro.store import index as index_mod
from repro.store import maintenance as maintenance_mod
from repro.store import placement as placement_mod
from repro.store import summaries as summaries_mod

ID_SENTINEL = 2**31 - 1


class StoreFullError(RuntimeError):
    """Raised when an insert cannot fit even after compaction."""


class StoreSnapshot(NamedTuple):
    """One immutable generation of the store, as the device sees it.

    ``points``: (k*cap, dim) f32, sharded over the service axis;
    ``ids``: (k*cap,) int32 global point ids (ID_SENTINEL in dead/free
    slots); ``valid``: (k*cap,) bool live mask; ``live``: global live
    count at this generation; ``labels``: (k*cap,) f32 per-point
    label/value payload riding the same slot layout (None unless the
    store was built ``with_labels=True``) — frozen with the generation
    so prediction can never read labels torn from a different epoch
    than the points that carry them.
    """

    generation: int
    points: jax.Array
    ids: jax.Array
    valid: jax.Array
    live: int
    labels: Optional[jax.Array] = None


@dataclasses.dataclass
class IngestStats:
    inserted: int = 0
    deleted: int = 0
    updated: int = 0
    applies: int = 0               # flushes that produced a generation
    compactions: int = 0
    forced_compactions: int = 0    # repacks forced by a full shard mid-flush
    retightens: int = 0            # scheduled per-shard exact re-tightenings
    splits: int = 0                # radius-triggered proximity re-deals
    last_compact_reason: Optional[str] = None


@dataclasses.dataclass
class _Op:
    kind: str                      # "insert" | "delete" | "update"
    id: int
    point: Optional[np.ndarray] = None
    value: Optional[int] = None
    label: Optional[float] = None  # None on update = keep current label


class MutableStore:
    """Mutable sharded point store; see module docstring.

    Thread-safe: mutations, flushes, and snapshot reads may come from any
    thread (the serving integration reads snapshots from the micro-batcher
    thread while an ingest thread mutates).
    """

    def __init__(self, dim: int, *, capacity_per_shard: int, mesh=None,
                 axis_name: str = "knn", staging_size: int = 64,
                 compact_tombstone_frac: float = 0.35,
                 compact_imbalance_frac: float = 0.5,
                 auto_compact: bool = True, with_values: bool = False,
                 with_labels: bool = False,
                 track_history: bool = False,
                 summary_projections: int = 8, summary_seed: int = 0,
                 placement="balance", placement_guard_slack: int = 32,
                 redeal: str = "round_robin",
                 summary_pivots: int = 1, retighten_every: int = 0,
                 split_radius_factor: float = 0.0,
                 split_cooldown: int = 2, maintenance: str = "inline",
                 maintenance_probe_sample: int = 64,
                 index_buckets: int = 0):
        if capacity_per_shard < 1:
            raise ValueError("capacity_per_shard must be >= 1")
        if redeal not in ("round_robin", "proximity"):
            raise ValueError(f"redeal must be 'round_robin' or 'proximity', "
                             f"got {redeal!r}")
        if maintenance not in ("inline", "background"):
            raise ValueError(f"maintenance must be 'inline' or 'background', "
                             f"got {maintenance!r}")
        self.dim = int(dim)
        self.axis_name = axis_name
        self.mesh = mesh if mesh is not None else make_mesh(
            (jax.device_count(),), (axis_name,))
        self.k = int(dict(self.mesh.shape)[axis_name])
        self.cap = int(capacity_per_shard)
        self.total = self.k * self.cap
        self.staging_size = int(staging_size)
        self.compact_tombstone_frac = float(compact_tombstone_frac)
        self.compact_imbalance_frac = float(compact_imbalance_frac)
        self.auto_compact = bool(auto_compact)
        self.with_values = bool(with_values)
        self.with_labels = bool(with_labels)
        # Placement subsystem (store/placement.py): the policy object that
        # places every applied insert, and the repack mode that re-deals
        # live points at compaction.
        self._placement = placement_mod.make_placement(
            placement, guard_slack=placement_guard_slack)
        self.placement = self._placement.name
        self.placement_guard_slack = int(placement_guard_slack)
        self.redeal = str(redeal)
        self.stats = IngestStats()

        self._lock = threading.RLock()
        self._sharding = NamedSharding(self.mesh, P(axis_name))

        # Host mirrors — authoritative control plane; the device snapshot
        # is always a pure function of these (mirror first, then upload).
        self._pts = np.zeros((self.total, self.dim), np.float32)
        self._ids = np.full(self.total, ID_SENTINEL, np.int32)
        self._valid = np.zeros(self.total, bool)
        self._slot_of: dict[int, int] = {}
        # Ids are single-use, forever: once staged for insertion an id can
        # never be inserted again, even after deletion.  This is what makes
        # the id -> value map monotone (values_for answers correctly for
        # any generation's ids) and an id denote one immutable point
        # identity across all generations.  Grows with total inserts.
        self._used_ids: set[int] = set()
        self._live = np.zeros(self.k, np.int64)   # live points per shard
        self._used = np.zeros(self.k, np.int64)   # high-water mark per shard
        self._values: dict[int, int] = {}
        # Per-slot label payload mirror (prediction plane).  f32 serves
        # both classification (integer class ids are exact below 2^24)
        # and regression; slots ride the exact same scatter / validity /
        # repack machinery as the points they annotate.  The id -> label
        # map is monotone like _values, so oracle lookups against older
        # generations' ids stay well-defined.
        self._labels = (np.zeros(self.total, np.float32)
                        if self.with_labels else None)
        self._label_of: dict[int, float] = {}
        self._next_id = 0

        # Write-ahead staging.
        self._pending: list[_Op] = []
        self._staged_state: dict[int, bool] = {}  # id -> live after flush
        self._projected_live = 0

        # The labeled variant carries one extra buffer through the same
        # scatter; arity is fixed at construction so the jit cache never
        # sees a mixed signature (maintenance.py calls through this too).
        self._apply_fn = jax.jit(
            _scatter_apply_labeled if self.with_labels else _scatter_apply,
            out_shardings=(self._sharding,) * (4 if self.with_labels else 3))

        # Per-shard pivot summaries for pruned routing (store/summaries.py),
        # in the adaptive form (store/adaptive.py): updated incrementally
        # alongside every op below, rebuilt exactly on repack, re-tightened
        # on schedule / split on radius decay at the tail of each apply,
        # and frozen with each generation so the (snapshot, summaries)
        # pair handed to routing_snapshot() can never disagree.
        self._summ = adaptive_mod.AdaptiveMaintainer(
            self.k, self.dim, num_projections=summary_projections,
            seed=summary_seed, num_pivots=summary_pivots,
            retighten_every=retighten_every,
            split_radius_factor=split_radius_factor)
        self.split_cooldown = int(split_cooldown)
        self._applies_at_split = -(1 << 30)   # no split yet: first may fire

        # In-shard approximate index tier (store/index.py): maintained
        # incrementally beside the summaries at every op site below,
        # rebuilt exactly on any repack, frozen per generation so
        # serving_snapshot()'s (snapshot, summaries, index) triple is
        # generation-coupled.  index_buckets=0 (the default) disables it.
        self._index = (index_mod.IndexMaintainer(
            self.k, self.cap, self.dim, index_buckets)
            if index_buckets > 0 else None)

        self._history: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._track_history = bool(track_history)
        self._snap = self._upload_snapshot_locked(generation=0)
        self._summaries = self._summ.freeze(0)
        self._frozen_index = (self._index.freeze(0)
                              if self._index is not None else None)
        self._record_history()

        # Maintenance plane (store/maintenance.py).  The journal exists
        # only while the background worker holds an outstanding capture:
        # _apply_locked appends every applied op to it so the worker's
        # commit can replay what raced its off-lock preparation; an
        # inline repack (forced, or explicit compact()) invalidates the
        # capture instead — the repack already rebuilt everything the
        # staged work was about to.
        self.maintenance = str(maintenance)
        self._journal: Optional[list] = None
        self._journal_invalid = False
        # Observability plane (src/repro/obs/): attached after
        # construction by the serving layer (KnnServer hands the store
        # its own plane so store applies and maintenance cycles land in
        # the same trace/registry as the queries racing them).  Unattached
        # stores trace into the shared no-op and record no metrics.
        self._obs = None
        # Maintenance-commit clock: a monotone count of committed
        # maintenance cycles (retighten/repack, inline or background)
        # plus the last commit's facts.  The serving layer samples it
        # before and after each dispatch so explain reports can say
        # whether a commit raced the request (obs/explain.py) and the
        # SLO staleness objective can reason about churn.
        self._maint_commits = 0
        self._last_maint_commit: Optional[dict] = None
        self._worker: Optional[maintenance_mod.MaintenanceWorker] = None
        if self.maintenance == "background":
            self._worker = maintenance_mod.MaintenanceWorker(
                self, probe_sample=maintenance_probe_sample)

    def attach_obs(self, plane) -> None:
        """Attach an :class:`repro.obs.ObsPlane`; applies and background
        maintenance cycles from here on emit spans into its tracer and
        timings into its registry.  Late attach is safe (the worker
        re-reads the plane each cycle); attaching replaces any previous
        plane."""
        self._obs = plane

    def _obs_tracer(self):
        return self._obs.tracer if self._obs is not None else NULL_TRACER

    def _obs_registry(self):
        return self._obs.metrics if self._obs is not None else None

    def _note_maint_commit(self, info: dict) -> None:
        """Advance the maintenance-commit clock.  Called by the
        maintenance plane *with the store lock already held* (both
        commit sites sit inside their lock block), so this must not —
        and does not — re-acquire it."""
        self._maint_commits += 1
        self._last_maint_commit = dict(info, seq=self._maint_commits)

    def maint_commit_clock(self) -> tuple:
        """(commit count, last commit info dict or None) — one lock
        acquisition, so a before/after pair brackets a dispatch
        consistently."""
        with self._lock:
            return self._maint_commits, self._last_maint_commit

    def close(self) -> None:
        """Stop the background maintenance worker (no-op when inline or
        already closed).  Staged work in flight is either committed or
        discarded before the worker thread exits; the store itself stays
        fully usable — only unscheduled maintenance stops happening."""
        worker, self._worker = self._worker, None
        if worker is not None:
            worker.stop()
            # final counters stay reportable after close (benchmarks and
            # the concurrency harness read them post-quiesce)
            self._worker_final = worker

    # ---- read side -------------------------------------------------------

    def snapshot(self) -> StoreSnapshot:
        """The current generation (immutable; safe to compute against while
        newer generations land)."""
        with self._lock:
            return self._snap

    def routing_snapshot(self):
        """(snapshot, summaries) captured under one lock acquisition —
        the generation-coupling invariant: ``summaries.generation ==
        snapshot.generation`` always, so pruned routing can never consult
        metadata from a different epoch than the one that answers."""
        with self._lock:
            return self._snap, self._summaries

    def serving_snapshot(self):
        """(snapshot, summaries, index) captured under one lock
        acquisition — the full serving triple for ``search="approx"``:
        ``index.generation == summaries.generation ==
        snapshot.generation`` always (``index`` is None when the store
        was built with ``index_buckets=0``)."""
        with self._lock:
            return self._snap, self._summaries, self._frozen_index

    def summaries(self) -> summaries_mod.ShardSummaries:
        """The current generation's per-shard pivot summaries."""
        with self._lock:
            return self._summaries

    @property
    def summary_projections(self) -> int:
        """Sketch width of this store's routing summaries (servers with
        route="pruned" must be configured to match)."""
        return self._summ.num_projections

    @property
    def summary_seed(self) -> int:
        """Direction-matrix seed of this store's routing summaries."""
        return self._summ.seed

    @property
    def summary_pivots(self) -> int:
        """Pivot balls per shard of this store's routing summaries
        (servers with route="pruned" must be configured to match)."""
        return self._summ.num_pivots

    @property
    def index_buckets(self) -> int:
        """Buckets per shard of this store's approximate index tier —
        0 when disabled (servers with search="approx" must be configured
        to match, like the summary knobs)."""
        return self._index.num_buckets if self._index is not None else 0

    def summary_slack(self) -> np.ndarray:
        """(k,) covering-radius slack of the current generation's
        summaries vs the exact live spread (summaries.summary_slack) —
        the bound-decay observable KnnServer.placement_stats() reports.
        O(live·dim) host probe; never on the dispatch path."""
        with self._lock:
            return summaries_mod.summary_slack(
                self._summaries, self._pts, self._valid, self.cap)

    def maintenance_stats(self) -> dict:
        """Adaptive-maintenance counters and knobs, one dict (the
        placement_stats() payload)."""
        with self._lock:
            out = {
                "summary_pivots": self._summ.num_pivots,
                "retighten_every": self._summ.retighten_every,
                "split_radius_factor": self._summ.split_radius_factor,
                "retightens": self.stats.retightens,
                "splits": self.stats.splits,
                "maintenance": self.maintenance,
            }
            worker = self._worker or getattr(self, "_worker_final", None)
            if worker is not None:
                out["worker"] = worker.stats_dict()
            return out

    @property
    def generation(self) -> int:
        return self.snapshot().generation

    @property
    def live_count(self) -> int:
        """Live points in the *applied* state (staged ops excluded)."""
        with self._lock:
            return int(self._live.sum())

    @property
    def pending_ops(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def live_per_shard(self) -> np.ndarray:
        """(k,) live points per shard — the balance the compactor defends."""
        with self._lock:
            return self._live.copy()

    def live_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, points) of the applied live set, ascending by id — the
        brute-force oracle view used by tests and benchmarks."""
        with self._lock:
            slots = np.flatnonzero(self._valid)
            order = slots[np.argsort(self._ids[slots], kind="stable")]
            return self._ids[order].copy(), self._pts[order].copy()

    def history(self, generation: int) -> tuple[np.ndarray, np.ndarray]:
        """(ids, points) live at ``generation`` (requires track_history)."""
        if not self._track_history:
            raise RuntimeError("store built with track_history=False")
        with self._lock:
            return self._history[generation]

    def values_for(self, ids: np.ndarray) -> np.ndarray:
        """Map global point ids to their payload values, -1 where absent.

        The id→value map is monotone (entries survive deletion) so lookups
        against older generations' answers stay well-defined.
        """
        with self._lock:
            return np.array([self._values.get(int(i), -1) for i in ids],
                            np.int32)

    def labels_for(self, ids: np.ndarray) -> np.ndarray:
        """Map global point ids to their label payloads, NaN where absent.

        Monotone like the id→value map: a label survives its point's
        deletion, so oracle lookups against older generations' answers
        stay well-defined (requires ``with_labels``).
        """
        if not self.with_labels:
            raise RuntimeError("store built with with_labels=False")
        with self._lock:
            return np.array([self._label_of.get(int(i), np.nan) for i in ids],
                            np.float32)

    def live_labels(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, labels) of the applied live set, ascending by id —
        aligned with :meth:`live_arrays` (requires ``with_labels``)."""
        if not self.with_labels:
            raise RuntimeError("store built with with_labels=False")
        with self._lock:
            slots = np.flatnonzero(self._valid)
            order = slots[np.argsort(self._ids[slots], kind="stable")]
            return self._ids[order].copy(), self._labels[order].copy()

    # ---- write side (staging) -------------------------------------------

    def insert(self, points, ids=None, values=None, labels=None) -> np.ndarray:
        """Stage point insertions; returns the assigned global ids.

        ``points``: (n, dim) or (dim,).  ``ids`` (optional) must be fresh —
        never used before, not even by a since-deleted point (ids are
        single-use so the id->value map stays monotone); omitted ids are
        assigned from a monotone counter.  ``values`` (optional, requires
        ``with_values``): per-point int payloads.  ``labels`` (optional,
        requires ``with_labels``): per-point f32 label/value payloads for
        the prediction plane (class id or regression target; default 0.0
        when omitted).  Atomic: on any validation error (duplicate/reused
        id, capacity) the whole batch is rejected and nothing is staged.
        """
        points = np.atleast_2d(np.asarray(points, np.float32))
        n = points.shape[0]
        if points.shape != (n, self.dim):
            raise ValueError(f"points shape {points.shape} != (n, {self.dim})")
        if values is not None and not self.with_values:
            raise ValueError("store built with with_values=False")
        if values is not None:
            values = np.broadcast_to(np.asarray(values, np.int32), (n,))
        if labels is not None and not self.with_labels:
            raise ValueError("store built with with_labels=False")
        if labels is not None:
            labels = np.broadcast_to(np.asarray(labels, np.float32), (n,))
        with self._lock:
            if ids is None:
                ids = np.arange(self._next_id, self._next_id + n,
                                dtype=np.int64)
            else:
                ids = np.broadcast_to(np.asarray(ids, np.int64), (n,))
            # validate the whole batch before staging any of it
            if self._projected_live + n > self.total:
                raise StoreFullError(
                    f"store full: capacity {self.total}, projected live "
                    f"{self._projected_live}, insert batch {n}")
            batch = set()
            for pid in ids:
                pid = int(pid)
                if not 0 <= pid < ID_SENTINEL:
                    raise ValueError(f"id {pid} outside [0, 2^31-1)")
                if pid in batch or pid in self._used_ids:
                    raise ValueError(
                        f"id {pid} was already used (ids are single-use)")
                batch.add(pid)
            for t in range(n):
                pid = int(ids[t])
                self._pending.append(_Op(
                    "insert", pid, point=points[t].copy(),
                    value=None if values is None else int(values[t]),
                    label=(0.0 if labels is None else float(labels[t]))
                    if self.with_labels else None))
                self._staged_state[pid] = True
                self._used_ids.add(pid)
                self._next_id = max(self._next_id, pid + 1)
            self._projected_live += n
            self._maybe_autoflush_locked()
            return ids.astype(np.int32)

    def delete(self, ids) -> None:
        """Stage deletions by global id (KeyError if not live/staged).
        Atomic: one unknown id rejects the whole batch, staging nothing."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        with self._lock:
            gone = set()
            for pid in ids:
                pid = int(pid)
                if pid in gone or not self._would_be_live(pid):
                    raise KeyError(f"id {pid} is not live")
                gone.add(pid)
            for pid in ids:
                pid = int(pid)
                self._pending.append(_Op("delete", pid))
                self._staged_state[pid] = False
            self._projected_live -= len(ids)
            self._maybe_autoflush_locked()

    def update(self, ids, points, labels=None) -> None:
        """Stage in-place point overwrites (same id, same slot).
        ``labels`` (optional, requires ``with_labels``) overwrites the
        label payload alongside; omitted labels stay as they were.
        Atomic: one unknown id rejects the whole batch, staging nothing."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        points = np.atleast_2d(np.asarray(points, np.float32))
        if points.shape != (len(ids), self.dim):
            raise ValueError(
                f"points shape {points.shape} != ({len(ids)}, {self.dim})")
        if labels is not None and not self.with_labels:
            raise ValueError("store built with with_labels=False")
        if labels is not None:
            labels = np.broadcast_to(np.asarray(labels, np.float32),
                                     (len(ids),))
        with self._lock:
            for pid in ids:
                if not self._would_be_live(int(pid)):
                    raise KeyError(f"id {int(pid)} is not live")
            for t, (pid, pt) in enumerate(zip(ids, points)):
                self._pending.append(_Op(
                    "update", int(pid), point=pt.copy(),
                    label=None if labels is None else float(labels[t])))
            self._maybe_autoflush_locked()

    def _would_be_live(self, pid: int) -> bool:
        if pid in self._staged_state:
            return self._staged_state[pid]
        return pid in self._slot_of

    def _maybe_autoflush_locked(self):
        if len(self._pending) >= self.staging_size:
            self.flush()

    # ---- apply (epoch swap) ---------------------------------------------

    def flush(self) -> int:
        """Apply all staged mutations as one epoch swap; returns the new
        generation (or the current one if nothing was staged)."""
        with self._lock:
            if not self._pending:
                return self._snap.generation
            return self._apply_locked(force_compact=False)

    def compact(self) -> int:
        """Flush staged ops (if any) and force a repack/rebalance; always
        produces a new generation."""
        with self._lock:
            return self._apply_locked(force_compact=True)

    def _apply_locked(self, *, force_compact: bool) -> int:
        t_apply = time.perf_counter()
        ops, self._pending = self._pending, []
        self._staged_state = {}
        touched: set[int] = set()
        repacked = False

        for op in ops:
            if op.kind == "insert":
                j = self._pick_shard_locked(op.point)
                if j < 0:
                    # Every shard is at its high-water mark but global
                    # capacity remains (staging checked it): reclaim
                    # tombstones now.  At most once per flush — after a
                    # repack the free tail covers all remaining inserts.
                    self._repack_locked()
                    repacked = True
                    self.stats.forced_compactions += 1
                    self.stats.last_compact_reason = "forced: all shards at high-water"
                    j = self._pick_shard_locked(op.point)
                    assert j >= 0, "repack must free tail space"
                slot = j * self.cap + int(self._used[j])
                self._used[j] += 1
                self._live[j] += 1
                self._summ.insert(j, op.point)
                if self._index is not None:
                    self._index.insert(j, slot, op.point)
                self._pts[slot] = op.point
                self._ids[slot] = op.id
                self._valid[slot] = True
                self._slot_of[op.id] = slot
                if op.value is not None:
                    self._values[op.id] = op.value
                if self.with_labels:
                    self._labels[slot] = op.label
                    self._label_of[op.id] = float(op.label)
                touched.add(slot)
                self.stats.inserted += 1
                if self._journal is not None:
                    self._journal.append(("insert", op.id, j, op.point,
                                          None, op.label))
            elif op.kind == "delete":
                slot = self._slot_of.pop(op.id)
                self._live[slot // self.cap] -= 1
                self._summ.delete(slot // self.cap, self._pts[slot])
                if self._index is not None:
                    self._index.delete(slot)
                if self._journal is not None:
                    self._journal.append(("delete", op.id,
                                          slot // self.cap, None,
                                          self._pts[slot].copy(), None))
                self._valid[slot] = False
                self._ids[slot] = ID_SENTINEL
                touched.add(slot)
                self.stats.deleted += 1
            else:  # update
                slot = self._slot_of[op.id]
                self._summ.update(slot // self.cap, self._pts[slot],
                                  op.point)
                if self._index is not None:
                    self._index.update(slot, op.point)
                if self._journal is not None:
                    self._journal.append(("update", op.id,
                                          slot // self.cap, op.point,
                                          self._pts[slot].copy(), op.label))
                self._pts[slot] = op.point
                if self.with_labels and op.label is not None:
                    self._labels[slot] = op.label
                    self._label_of[op.id] = float(op.label)
                touched.add(slot)
                self.stats.updated += 1

        if force_compact and not repacked:
            self._repack_locked()
            repacked = True
            self.stats.last_compact_reason = "forced: explicit compact()"
        elif (self.auto_compact and self.maintenance == "inline"
              and not repacked):
            decision = compaction.evaluate(
                self._live, self._used, self.cap,
                tombstone_frac=self.compact_tombstone_frac,
                imbalance_frac=self.compact_imbalance_frac,
                registry=self._obs_registry())
            if decision.compact:
                self._repack_locked()
                repacked = True
                self.stats.last_compact_reason = decision.reason

        # Adaptive maintenance (store/adaptive.py, DESIGN.md Section 10):
        # runs only when no repack already rebuilt every bound exactly.
        # A radius-triggered split schedules its own proximity re-deal —
        # the quota clamp and the maintainer's growth guard keep it from
        # re-arming the compactor — else at most ONE due shard gets an
        # O(live·dim) exact re-tightening, round-robin, off any stall
        # path.  maintenance="background" moves this whole tail (and the
        # auto-compact evaluation above) to the worker thread
        # (store/maintenance.py) — the flush publishes immediately and
        # the worker is poked after the swap.
        if self.maintenance == "inline":
            if not repacked:
                j = self._split_due_locked()
                if j is not None:
                    self._repack_locked(redeal="proximity")
                    repacked = True
                    self.stats.splits += 1
                    self._applies_at_split = self.stats.applies
                    self.stats.last_compact_reason = (
                        f"split: shard {j} radius outgrew the centroid gap")
            if not repacked:
                j = self._summ.retighten_due()
                if j is not None:
                    self._summ.retighten(j, self._pts, self._valid,
                                         self.cap)
                    self.stats.retightens += 1

        self._projected_live = int(self._live.sum())
        gen = self._snap.generation + 1
        if repacked:
            # A repack moves slots wholesale: one full upload.
            self._snap = self._upload_snapshot_locked(generation=gen)
        else:
            new_pts, new_ids, new_valid, new_labels = self._scatter_locked(
                sorted(touched))
            self._snap = StoreSnapshot(generation=gen, points=new_pts,
                                       ids=new_ids, valid=new_valid,
                                       live=self._projected_live,
                                       labels=new_labels)
        self.stats.applies += 1
        self._summaries = self._summ.freeze(gen)
        if self._index is not None:
            self._frozen_index = self._index.freeze(gen)
        self._record_history()
        if self._worker is not None:
            self._worker.notify()
        t_done = time.perf_counter()
        self._obs_tracer().record("store.apply", t_apply, t_done,
                                  generation=gen, ops=len(ops),
                                  repacked=repacked)
        if self._obs is not None:
            reg = self._obs.metrics
            reg.histogram("store.apply_s").observe(t_done - t_apply)
            reg.counter("store.applies").inc()
            reg.gauge("store.live").set(self._projected_live)
        return gen

    def _upload_snapshot_locked(self, *, generation: int) -> StoreSnapshot:
        """Full upload of the mirrors as a fresh snapshot.

        device_put is handed *copies*: the host->device transfer may still
        be in flight when this method returns, and the next flush mutates
        the mirrors in place — uploading the live mirror would let a later
        batch's writes leak into (and tear) this supposedly immutable
        generation under concurrent serving.
        """
        return StoreSnapshot(
            generation=generation,
            points=jax.device_put(self._pts.copy(), self._sharding),
            ids=jax.device_put(self._ids.copy(), self._sharding),
            valid=jax.device_put(self._valid.copy(), self._sharding),
            live=int(self._live.sum()),
            labels=(jax.device_put(self._labels.copy(), self._sharding)
                    if self.with_labels else None))

    def _pick_shard_locked(self, point=None) -> int:
        """Policy-dispatched placement (store/placement.py): hand the
        configured policy the live/used counts — plus the summary
        maintainer's centroid view, if the policy pays attention to it —
        and get back a destination shard; -1 if no shard has tail space
        (the caller then repacks and retries)."""
        if self._placement.uses_centroids:
            centroids, radii, occupied = self._summ.placement_view()
        else:
            centroids = radii = occupied = None
        return self._placement.pick(point, placement_mod.PlacementView(
            live=self._live, used=self._used, cap=self.cap,
            centroids=centroids, radii=radii, occupied=occupied))

    def _split_due_locked(self) -> Optional[int]:
        """Shard the adaptive split trigger fires on this apply, or None;
        the cooldown (applies between splits) is the store's guard, the
        radius/growth conditions are the maintainer's."""
        if (self._summ.split_radius_factor <= 0
                or self.stats.applies - self._applies_at_split
                < self.split_cooldown):
            return None
        return self._summ.split_candidate()

    def _repack_locked(self, redeal: Optional[str] = None):
        """Repack under ``redeal`` (default: the store's configured mode;
        adaptive splits pass "proximity" explicitly — a split exists to
        separate clusters, whatever the compaction-time deal is)."""
        # An inline repack rebuilds mirrors AND summaries exactly; any
        # background capture prepared against the pre-repack layout is
        # now both stale and pointless — invalidate it.
        t_repack = time.perf_counter()
        if self._journal is not None:
            self._journal_invalid = True
        if (redeal or self.redeal) == "proximity":
            centroids, _, occupied = self._summ.placement_view()
            # Quota slack shares the placement guardrail knob, clamped
            # (compaction.redeal_slack) so a re-deal can never leave a
            # skew that would immediately re-arm the compactor.
            slack = compaction.redeal_slack(
                self.placement_guard_slack, self.compact_imbalance_frac,
                self.cap, self.k)
            res = placement_mod.repack_proximity(
                self._pts, self._ids, self._valid, self.k, self.cap,
                id_sentinel=ID_SENTINEL, balance_slack=slack,
                seed_centroids=centroids[occupied] if occupied.any()
                else None)
        else:
            res = compaction.repack(self._pts, self._ids, self._valid,
                                    self.k, self.cap,
                                    id_sentinel=ID_SENTINEL)
        if self.with_labels:
            # Labels follow their points through the re-deal: remap the
            # per-slot payload from the old layout to the new one by id
            # (compaction.remap_payload) — alignment is what the
            # labels-survive-compaction regression test asserts.
            self._labels = compaction.remap_payload(
                self._labels, self._ids, self._valid, res.ids, res.valid)
        self._pts, self._ids, self._valid = res.points, res.ids, res.valid
        self._slot_of = res.slot_of
        self._live, self._used = res.live, res.used
        # Exact rebuild: compaction is the point where the incremental
        # (covering-but-loose) summary bounds get re-tightened.
        self._summ.rebuild(self._pts, self._valid, self.cap)
        if self._index is not None:
            self._index.rebuild(self._pts, self._valid)
        self.stats.compactions += 1
        t_done = time.perf_counter()
        self._obs_tracer().record("store.repack", t_repack, t_done,
                                  redeal=redeal or self.redeal,
                                  plane="inline")
        if self._obs is not None:
            self._obs.metrics.histogram("store.repack_s").observe(
                t_done - t_repack)
            self._obs.metrics.counter("store.repacks").inc()

    def _scatter_locked(self, slots: list[int]):
        """Apply the final per-slot values of one staged batch on device.

        Touched slots are deduplicated by construction (a set), so the
        scatter has unique indices; padding rows point at slot ``total``
        and are dropped.  Padded to powers of two so the jit cache stays
        small across flushes of varying size.
        """
        idx, upd_pts, upd_ids, upd_valid = compaction.scatter_operands(
            slots, self._pts, self._ids, self._valid, self.total,
            self.dim, id_sentinel=ID_SENTINEL)
        if self.with_labels:
            upd_labels = compaction.payload_operand(slots, self._labels,
                                                    len(idx))
            return self._apply_fn(self._snap.points, self._snap.ids,
                                  self._snap.valid, self._snap.labels,
                                  idx, upd_pts, upd_ids, upd_valid,
                                  upd_labels)
        out = self._apply_fn(self._snap.points, self._snap.ids,
                             self._snap.valid, idx, upd_pts, upd_ids,
                             upd_valid)
        return out + (None,)

    def _record_history(self):
        if self._track_history:
            ids, pts = self.live_arrays()
            self._history[self._snap.generation] = (ids, pts)


def _scatter_apply(pts, ids, valid, slots, upd_pts, upd_ids, upd_valid):
    """On-device batched mutation: one scatter per buffer, out-of-range
    (padding) rows dropped.  No donation — older generations stay live for
    in-flight readers (the epoch-swap contract)."""
    return (pts.at[slots].set(upd_pts, mode="drop"),
            ids.at[slots].set(upd_ids, mode="drop"),
            valid.at[slots].set(upd_valid, mode="drop"))


def _scatter_apply_labeled(pts, ids, valid, labels, slots, upd_pts,
                           upd_ids, upd_valid, upd_labels):
    """_scatter_apply with the label payload riding the same scatter —
    same indices, same drop semantics, same no-donation contract, so a
    generation's labels can never tear from its points."""
    return (pts.at[slots].set(upd_pts, mode="drop"),
            ids.at[slots].set(upd_ids, mode="drop"),
            valid.at[slots].set(upd_valid, mode="drop"),
            labels.at[slots].set(upd_labels, mode="drop"))
