"""Mutable sharded point store — streaming ingest/deletes under the
static-shape query path, with epoch-swapped serving (DESIGN.md Section 7).
"""

from repro.store.mutable import (ID_SENTINEL, IngestStats, MutableStore,
                                 StoreFullError, StoreSnapshot)
from repro.store.compaction import CompactionDecision, evaluate, repack
from repro.store.summaries import (ShardSummaries, SummaryMaintainer,
                                   build_summaries, lower_bounds,
                                   route_shards, summary_invariants,
                                   upper_bounds)

__all__ = [
    "MutableStore", "StoreSnapshot", "StoreFullError", "IngestStats",
    "ID_SENTINEL", "CompactionDecision", "evaluate", "repack",
    "ShardSummaries", "SummaryMaintainer", "build_summaries",
    "lower_bounds", "upper_bounds", "route_shards", "summary_invariants",
]
