"""Mutable sharded point store — streaming ingest/deletes under the
static-shape query path, with epoch-swapped serving (DESIGN.md Section 7),
pruned shard routing (Section 8), locality-aware placement (Section 9),
adaptive summary maintenance (Section 10), a background maintenance
plane (Section 11), and an in-shard approximate index tier (Section 13).
"""

from repro.store.mutable import (ID_SENTINEL, IngestStats, MutableStore,
                                 StoreFullError, StoreSnapshot)
from repro.store.adaptive import AdaptiveMaintainer, compute_pivots
from repro.store.compaction import (CompactionDecision, evaluate,
                                    redeal_slack, repack,
                                    scatter_operands)
from repro.store.index import (IndexMaintainer, ShardIndex, bucket_keep,
                               candidate_fraction, candidate_mask)
from repro.store.maintenance import MaintenanceStats, MaintenanceWorker
from repro.store.placement import (AffinityPlacement, BalancePlacement,
                                   PlacementPolicy, PlacementView,
                                   lloyd_centroids, make_placement,
                                   repack_proximity)
from repro.store.summaries import (ShardSummaries, SummaryMaintainer,
                                   build_summaries, lower_bounds,
                                   route_shards, summary_invariants,
                                   summary_slack, summary_slack_sampled,
                                   upper_bounds)

__all__ = [
    "MutableStore", "StoreSnapshot", "StoreFullError", "IngestStats",
    "ID_SENTINEL", "CompactionDecision", "evaluate", "redeal_slack",
    "repack", "scatter_operands",
    "AdaptiveMaintainer", "compute_pivots",
    "IndexMaintainer", "ShardIndex", "bucket_keep", "candidate_mask",
    "candidate_fraction",
    "MaintenanceStats", "MaintenanceWorker",
    "PlacementPolicy", "PlacementView", "BalancePlacement",
    "AffinityPlacement", "make_placement", "lloyd_centroids",
    "repack_proximity",
    "ShardSummaries", "SummaryMaintainer", "build_summaries",
    "lower_bounds", "upper_bounds", "route_shards", "summary_invariants",
    "summary_slack", "summary_slack_sampled",
]
