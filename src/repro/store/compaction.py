"""Compaction / rebalance policy and repacking for the mutable store.

Two forces erode a capacity-padded sharded store under streaming
mutations:

* **Tombstones.**  Deletes only flip the ``valid`` bit — the slot stays
  occupied (reusing it in place would make a staged batch's scatter
  order-sensitive and would interleave dead and live rows forever).  Dead
  slots cost nothing per query (every shard scans its full static buffer
  regardless — XLA shapes are fixed), but they consume insert headroom:
  a shard's free space is only its untouched tail.

* **Imbalance.**  Inserts land where the store's placement policy
  (``store/placement.py``) sends them — the emptiest shard under
  ``balance``, the nearest-centroid shard within the guardrail band
  under ``affinity`` — but deletes land wherever the victim lives, so
  live counts drift apart.  Skewed shards hurt twice: per-machine
  candidate quality degrades (the Duan/Qiao/Cheng argument — each
  machine's local answer should be drawn from a comparably-sized
  sample), and a full shard rejects inserts while its neighbors sit
  half empty.

The trigger math (:func:`evaluate`) watches both with one scalar each:

  ``tombstone_density = dead_slots / occupied_slots``     (reclaimable frac)
  ``imbalance         = (max_live - min_live) / capacity`` (skew frac)

Crossing either configured threshold schedules a repack at the next
apply.  :func:`repack` rebuilds the mirrors: live points are dealt
round-robin in ascending-id order, so shard live counts differ by at most
one and every shard's occupied region is a dense prefix (the whole tail
becomes insert headroom again).  Ids are stable across a repack — only
slots move — so a repack is invisible to clients except as a generation
bump (DESIGN.md Section 7).  Stores built with ``redeal="proximity"``
repack through :func:`repro.store.placement.repack_proximity` instead —
same invariants (balance within one, dense prefixes, id stability), but
destinations follow Lloyd centroids so clusters stay shard-coherent
(DESIGN.md Section 9).

A third force — *summary decay* (the covering radii behind pruned
routing inflating under incremental maintenance) — is watched not here
but by the adaptive subsystem (store/adaptive.py): its radius-triggered
split schedules a proximity re-deal through the same repack machinery,
under the same :func:`redeal_slack` quota clamp, so an adaptive re-deal
can never leave a skew that would immediately re-arm :func:`evaluate`'s
imbalance trigger (DESIGN.md Section 10).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class CompactionDecision(NamedTuple):
    compact: bool
    reason: str | None
    tombstone_density: float
    imbalance: float


def evaluate(live: np.ndarray, used: np.ndarray, cap: int, *,
             tombstone_frac: float,
             imbalance_frac: float,
             registry=None) -> CompactionDecision:
    """Decide whether the store should repack.

    ``live``: (k,) live points per shard; ``used``: (k,) occupied slots
    per shard (the high-water mark — live + tombstones); ``cap``: slots
    per shard.  With ``registry`` (an obs MetricsRegistry), the two
    erosion scalars are published as gauges on every evaluation and a
    fired trigger is counted by kind — the compactor's inputs show up
    in ``snapshot()`` instead of only its effects.
    """
    used_total = int(used.sum())
    dead = used_total - int(live.sum())
    density = dead / used_total if used_total else 0.0
    imbalance = (int(live.max()) - int(live.min())) / cap if cap else 0.0
    if registry is not None:
        registry.gauge("store.tombstone_density").set(density)
        registry.gauge("store.imbalance").set(imbalance)
    if density > tombstone_frac:
        if registry is not None:
            registry.counter("store.compact_trigger.tombstone").inc()
        return CompactionDecision(
            True, f"tombstone_density {density:.3f} > {tombstone_frac}",
            density, imbalance)
    if imbalance > imbalance_frac:
        if registry is not None:
            registry.counter("store.compact_trigger.imbalance").inc()
        return CompactionDecision(
            True, f"imbalance {imbalance:.3f} > {imbalance_frac}",
            density, imbalance)
    return CompactionDecision(False, None, density, imbalance)


def redeal_slack(guard_slack: int, imbalance_frac: float, cap: int,
                 k: int) -> int:
    """Quota slack for a proximity re-deal, clamped so the repack cannot
    re-arm the compactor it serves.

    The slack shares the placement guardrail knob, but a re-deal may
    leave a worst-case skew of ``k·(slack+1)``; keeping
    ``slack < imbalance_frac·cap/k − 1`` bounds that below the imbalance
    trigger, so neither a compaction-time proximity re-deal nor an
    adaptive split (store/adaptive.py) can schedule the very repack that
    would immediately follow it.
    """
    return min(int(guard_slack),
               max(0, int(imbalance_frac * cap / k) - 1))


def scatter_operands(slots, points: np.ndarray, ids: np.ndarray,
                     valid: np.ndarray, total: int, dim: int, *,
                     id_sentinel: int):
    """Padded operand block for one batched slot scatter: ``(idx,
    upd_pts, upd_ids, upd_valid)`` carrying the *final* mirror value of
    each touched slot, padded to a power of two (small jit cache across
    flushes of varying size) with out-of-range rows (index ``total``)
    the scatter drops.

    Shared by the store's staged-flush apply (``_scatter_locked``) and
    the background maintenance worker's journal-replay commit
    (store/maintenance.py) — the two paths that scatter mirror deltas
    onto a device generation must build identical operands or the epoch
    swap's mirror-is-authoritative contract splits in two.
    """
    n = len(slots)
    pad = max(8, 1 << max(0, (n - 1).bit_length()))
    idx = np.full(pad, total, np.int32)
    idx[:n] = slots
    upd_pts = np.zeros((pad, dim), np.float32)
    upd_ids = np.full(pad, id_sentinel, np.int32)
    upd_valid = np.zeros(pad, bool)
    upd_pts[:n] = points[slots]
    upd_ids[:n] = ids[slots]
    upd_valid[:n] = valid[slots]
    return idx, upd_pts, upd_ids, upd_valid


def payload_operand(slots, payload: np.ndarray, padded_len: int) -> np.ndarray:
    """The label-payload column of one batched slot scatter, padded to
    the same length (and aligned to the same rows) as the ``idx`` block
    :func:`scatter_operands` built — padding rows carry zeros and are
    dropped with their out-of-range indices."""
    upd = np.zeros(padded_len, payload.dtype)
    upd[:len(slots)] = payload[list(slots)]
    return upd


def remap_payload(payload: np.ndarray, old_ids: np.ndarray,
                  old_valid: np.ndarray, new_ids: np.ndarray,
                  new_valid: np.ndarray) -> np.ndarray:
    """Carry a per-slot payload across a repack: every live id keeps its
    payload, whatever slot the re-deal moved it to.

    Vectorized id join (sort the old live ids once, searchsorted the new
    layout's ids into them) — O(live log live), no per-point dict walk.
    Free/dead slots in the new layout get zeros; they are masked by
    ``new_valid`` everywhere the payload is read.
    """
    out = np.zeros_like(payload)
    old_slots = np.flatnonzero(old_valid)
    if old_slots.size == 0:
        return out
    oid = old_ids[old_slots]
    order = np.argsort(oid)
    oid_sorted = oid[order]
    pay_sorted = payload[old_slots][order]
    new_slots = np.flatnonzero(new_valid)
    pos = np.searchsorted(oid_sorted, new_ids[new_slots])
    out[new_slots] = pay_sorted[pos]
    return out


class RepackResult(NamedTuple):
    points: np.ndarray     # (k*cap, dim) new point mirror
    ids: np.ndarray        # (k*cap,) new id mirror (sentinel in free slots)
    valid: np.ndarray      # (k*cap,) new validity mirror
    slot_of: dict          # id -> new slot
    live: np.ndarray       # (k,) live per shard (balanced to within 1)
    used: np.ndarray       # (k,) new high-water marks (== live)


def repack(points: np.ndarray, ids: np.ndarray, valid: np.ndarray,
           k: int, cap: int, *, id_sentinel: int) -> RepackResult:
    """Pack live slots into dense, balanced per-shard prefixes.

    Live points are dealt round-robin in ascending-id order: point t goes
    to shard ``t % k`` at local offset ``t // k``.  Deterministic (no RNG,
    no dependence on previous layout), balanced to within one point, and
    id-stable.
    """
    dim = points.shape[1]
    total = k * cap
    live_slots = np.flatnonzero(valid)
    order = live_slots[np.argsort(ids[live_slots], kind="stable")]
    n = order.size
    assert n <= total

    new_pts = np.zeros((total, dim), points.dtype)
    new_ids = np.full(total, id_sentinel, np.int32)
    new_valid = np.zeros(total, bool)

    t = np.arange(n)
    dest = (t % k) * cap + t // k
    new_pts[dest] = points[order]
    new_ids[dest] = ids[order]
    new_valid[dest] = True

    slot_of = {int(i): int(s) for i, s in zip(ids[order], dest)}
    live = np.bincount(dest // cap, minlength=k).astype(np.int64)
    return RepackResult(points=new_pts, ids=new_ids, valid=new_valid,
                        slot_of=slot_of, live=live, used=live.copy())
