"""Per-shard pivot summaries — the routing metadata behind ``route="pruned"``.

The paper's Algorithm 2 charges every query a collective over all k
machines.  PANDA-style systems (Patwary et al., 2016) and the k-machine
clustering line (Bandyapadhyay et al., 2018) cut that cost with
partition-level bounding metadata: if a cheap per-shard summary *proves*
a shard cannot contain an l-NN winner, the query need not touch it.  This
module maintains that summary per shard and derives the routing decision;
``core/knn.py`` applies it (whole-shard +inf masking ahead of the fused
distance+top-l kernel) and ``runtime/knn_server.py`` computes the
touched-shard set per micro-batch.

**Summary contents** (one row per shard, host-resident, O(k·(m·dim+r))):

* ``centroids``/``radii`` — the live-point mean and a *covering* radius
  (every live point of shard j lies within ``radii[j]`` of
  ``centroids[j]``).  Triangle inequality gives both sides of the bound:
  ``max(0, |q−c| − r)`` lower-bounds and ``|q−c| + r`` upper-bounds the
  distance from q to any point of the shard.
* ``pivots``/``pivot_radii``/``pivot_count`` (optional; maintained by
  :class:`repro.store.adaptive.AdaptiveMaintainer`) — up to ``m`` pivot
  balls per shard whose *union* covers the shard's live points.  Every
  pivot ball gives the same triangle-inequality bracket as the aggregate
  ball, so ``min_p max(0, |q−pivot_p| − r_p)`` lower-bounds and
  ``max_p (|q−pivot_p| + r_p)`` upper-bounds the distance from q to any
  live point of the shard — tight when one shard hosts two small
  clusters, where the single aggregate ball must span the gap between
  them and proves nothing.  In the default single-pivot form these
  fields are absent and only the aggregate ball applies.
* ``proj_lo``/``proj_hi`` — a small random-projection sketch: for ``r``
  fixed unit directions u, the interval ``[min_p u·p, max_p u·p]`` over
  the shard's live points.  For any unit u, ``|u·q − u·p| <= |q − p|``,
  so the distance from ``u·q`` to the interval is a second, independent
  lower bound (tight for elongated shards where the ball bound is loose).

All bound sources are individually sound, so the routing lower bound
takes their maximum and the upper bound their minimum — the pivot-set
generalization can only tighten the decision, never change an answer.

**Routing decision** (:func:`route_shards`), per query row with its own l:
sort shards by their upper bound, accumulate live counts until >= l — the
upper bound T at which that happens bounds the l-th NN distance from
above.  Any shard whose lower bound exceeds T (with a float-safety slack,
see below) provably holds no winner and is masked.  Shards inside the
cumulative prefix satisfy ``lb <= ub <= T`` and are never masked, so the
active set always contains >= min(l, total live) points — the selection
downstream stays exact.

**Exactness under floating point.**  Bounds are computed here in float64
from exact triangle-inequality math, but the pipeline compares *computed*
float32 distances (``|q|² − 2q·p + |p|²``, clamped at 0), whose error is
**absolute** in the coordinate magnitude — ~dim·2⁻²³·(|q|+|p|)², however
small the true distance (catastrophic cancellation when q ≈ p; for tight
clusters far from the origin, computed distances quantize to multiples of
ulp(|q|²)).  A mathematically-true bound must therefore clear both a
relative and a magnitude-absolute margin before it may prune: a shard is
kept whenever ``lb <= T·(1+slack) + err``, where ``err =
16·(dim+1)·2⁻²³·(|q| + R)²`` and R is the generation's largest live
``|centroid| + radius`` — an upper bound on *twice* the f32 rounding any
(query, live point) distance can carry, so a pruned shard's computed
distances provably exceed the computed l-th-NN threshold, not merely the
true one.  At scales where that quantization swamps the inter-shard gaps
the margin simply disables pruning — looseness only ever costs pruning
efficiency, never exactness.  The property harness
(tests/test_routing.py) holds ``route="pruned"`` bit-identical to
``route="exact"`` across clustered, uniform, far-from-origin, and
adversarial all-equidistant instances, including under mutation.

**Maintenance** (:class:`SummaryMaintainer`): updated incrementally on
ingest/delete (O(dim + r) per op) and rebuilt exactly on compaction.
Incremental updates keep the *covering* property while the centroid
drifts — an insert/delete moves the centroid by δ, so every previously
covered point is still within ``radius + δ`` of the new centroid; deletes
never shrink the radius or the projection intervals (stale-but-valid).
That staleness compounds (~log n radius inflation with per-shard ops);
:mod:`repro.store.adaptive` is the subsystem that re-tightens bounds
between compactions and splits shards whose radii outgrow the layout —
:func:`summary_slack` is the probe that makes the decay observable.
Every generation's summaries are frozen to an immutable
:class:`ShardSummaries` stamped with the snapshot generation, and
``MutableStore.routing_snapshot()`` hands out the (snapshot, summaries)
pair under one lock — routing metadata can never be stale relative to the
epoch that answers (DESIGN.md Sections 8 and 10).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np


class ShardSummaries(NamedTuple):
    """One generation's frozen routing metadata (all host float64).

    ``live``: (k,) live points per shard; ``centroids``: (k, dim) live
    means (zeros for empty shards); ``radii``: (k,) covering radii;
    ``directions``: (r, dim) unit projection directions shared by all
    shards; ``proj_lo``/``proj_hi``: (k, r) per-shard projection
    intervals (+inf/−inf for empty shards).  ``generation`` matches the
    :class:`~repro.store.StoreSnapshot` these summaries describe.

    The optional pivot-set trailing fields (``None`` for single-pivot
    summaries) carry the multi-pivot generalization
    (:mod:`repro.store.adaptive`): ``pivots``: (k, m, dim) ball centers,
    ``pivot_radii``: (k, m) ball radii, ``pivot_count``: (k,) occupied
    pivot slots per shard — the union of shard j's first
    ``pivot_count[j]`` balls covers its live points.  ``pivot_live``:
    (k, m) per-ball live-point credits, maintained as a *safe
    undercount* (every credit is a distinct live point inside its ball;
    some live points may carry no credit after deletes) — what lets the
    routing threshold charge a pivot ball only for points it provably
    still holds instead of the whole shard's live count.
    """

    generation: int
    live: np.ndarray
    centroids: np.ndarray
    radii: np.ndarray
    directions: np.ndarray
    proj_lo: np.ndarray
    proj_hi: np.ndarray
    pivots: np.ndarray | None = None
    pivot_radii: np.ndarray | None = None
    pivot_count: np.ndarray | None = None
    pivot_live: np.ndarray | None = None


def projection_directions(dim: int, num_projections: int,
                          seed: int = 0) -> np.ndarray:
    """(r, dim) fixed unit-norm directions — deterministic given the seed
    (two servers over the same store must route, and therefore answer,
    identically)."""
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(num_projections, dim))
    return d / np.maximum(np.linalg.norm(d, axis=1, keepdims=True), 1e-30)


class SummaryMaintainer:
    """Mutable per-shard summary state, updated op by op under the store
    lock; :meth:`freeze` emits the immutable generation-stamped view."""

    def __init__(self, k: int, dim: int, *, num_projections: int = 8,
                 seed: int = 0):
        self.k, self.dim = int(k), int(dim)
        self.num_projections = int(num_projections)
        self.seed = int(seed)
        self.directions = projection_directions(dim, num_projections, seed)
        r = self.directions.shape[0]
        self._sum = np.zeros((k, dim), np.float64)
        self._n = np.zeros(k, np.int64)
        self._radius = np.zeros(k, np.float64)
        self._lo = np.full((k, r), np.inf)
        self._hi = np.full((k, r), -np.inf)

    def _centroid(self, j: int) -> np.ndarray:
        n = self._n[j]
        return self._sum[j] / n if n else np.zeros(self.dim)

    def insert(self, shard: int, point) -> None:
        j = int(shard)
        p = np.asarray(point, np.float64)
        c_old = self._centroid(j)
        had = self._n[j] > 0
        self._sum[j] += p
        self._n[j] += 1
        c_new = self._centroid(j)
        drift = float(np.linalg.norm(c_new - c_old)) if had else 0.0
        self._radius[j] = max(self._radius[j] + drift,
                              float(np.linalg.norm(p - c_new)))
        pr = self.directions @ p
        np.minimum(self._lo[j], pr, out=self._lo[j])
        np.maximum(self._hi[j], pr, out=self._hi[j])

    def delete(self, shard: int, point) -> None:
        j = int(shard)
        p = np.asarray(point, np.float64)
        c_old = self._centroid(j)
        self._sum[j] -= p
        self._n[j] -= 1
        if self._n[j] <= 0:
            self._reset_shard(j)
            return
        # Covering radius can only grow by the centroid drift; the
        # projection intervals stay as-is (stale but still covering).
        drift = float(np.linalg.norm(self._centroid(j) - c_old))
        self._radius[j] += drift

    def update(self, shard: int, old_point, new_point) -> None:
        self.delete(shard, old_point)
        self.insert(shard, new_point)

    def _reset_shard(self, j: int) -> None:
        self._sum[j] = 0.0
        self._n[j] = 0
        self._radius[j] = 0.0
        self._lo[j] = np.inf
        self._hi[j] = -np.inf

    def rebuild(self, points: np.ndarray, valid: np.ndarray,
                cap: int) -> None:
        """Exact recompute from the store mirrors (compaction path) —
        tightens every bound the incremental path loosened."""
        pts = np.asarray(points, np.float64)
        for j in range(self.k):
            sl = slice(j * cap, (j + 1) * cap)
            pj = pts[sl][np.asarray(valid[sl], bool)]
            if not len(pj):
                self._reset_shard(j)
                continue
            self._rebuild_shard(j, pj)

    def _rebuild_shard(self, j: int, pj: np.ndarray) -> None:
        """Exact per-shard recompute from its live points ``pj`` (nonempty
        float64) — the unit of work one scheduled re-tightening pass pays
        (repro.store.adaptive overrides it to refresh the pivot set too)."""
        self._sum[j] = pj.sum(0)
        self._n[j] = len(pj)
        c = self._centroid(j)
        self._radius[j] = float(
            np.sqrt(((pj - c) ** 2).sum(-1)).max())
        pr = pj @ self.directions.T
        self._lo[j] = pr.min(0)
        self._hi[j] = pr.max(0)

    def placement_view(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(centroids (k, dim), radii (k,), occupied (k,) bool) of the
        applied state — what the affinity placement policy and the
        proximity re-deal consult (store/placement.py; store lock
        held)."""
        n = np.maximum(self._n, 1)[:, None]
        return self._sum / n, self._radius.copy(), self._n > 0

    def freeze(self, generation: int) -> ShardSummaries:
        n = np.maximum(self._n, 1)[:, None]
        return ShardSummaries(
            generation=int(generation),
            live=self._n.copy(),
            centroids=self._sum / n,
            radii=self._radius.copy(),
            directions=self.directions,
            proj_lo=self._lo.copy(),
            proj_hi=self._hi.copy())


def build_summaries(points: np.ndarray, k: int, *, valid=None,
                    num_projections: int = 8, seed: int = 0,
                    generation: int = 0,
                    num_pivots: int = 1) -> ShardSummaries:
    """Summaries for a contiguously sharded static point set.

    ``points``: (n, dim) host array; shard j owns rows
    ``[j·n/k, (j+1)·n/k)`` — the static :class:`KnnServer` layout.
    ``valid`` (optional (n,) bool) masks dead rows (store mirrors).
    ``num_pivots > 1`` builds the multi-pivot form (exact pivot sets —
    repro.store.adaptive; imported lazily, it builds on this module).
    """
    points = np.asarray(points)
    n, dim = points.shape
    if n % k:
        raise ValueError(f"n={n} must be divisible by k={k}")
    cap = n // k
    if num_pivots > 1:
        from repro.store import adaptive as adaptive_mod
        m = adaptive_mod.AdaptiveMaintainer(
            k, dim, num_projections=num_projections, seed=seed,
            num_pivots=num_pivots)
    else:
        m = SummaryMaintainer(k, dim, num_projections=num_projections,
                              seed=seed)
    m.rebuild(points, np.ones(n, bool) if valid is None else valid, cap)
    return m.freeze(generation)


# ---- routing bounds ------------------------------------------------------

def _centroid_distances(s: ShardSummaries, q: np.ndarray) -> np.ndarray:
    """(B, k) float64 query-to-centroid L2 distances (shared by both
    bound directions — computed once per routing decision)."""
    return np.sqrt(((q[:, None, :] - s.centroids[None]) ** 2).sum(-1))


def _pivot_dists(s: ShardSummaries, q: np.ndarray) -> np.ndarray | None:
    """(B, k, m) float64 query-to-pivot distances, or None without a
    pivot set — the shared pass behind both the pivot bound bracket and
    the per-pivot threshold (route_shards computes it once)."""
    if s.pivots is None:
        return None
    return np.sqrt(((q[:, None, None, :] - s.pivots[None]) ** 2).sum(-1))


def _pivot_bounds(s: ShardSummaries, q: np.ndarray,
                  dp: np.ndarray | None = None):
    """(lb, ub) — (B, k) *distance*-unit brackets from the per-shard pivot
    ball sets, or (None, None) when the summaries carry none.

    Shard j's live points lie in the union of its occupied pivot balls,
    so ``min_p max(0, d(q, pivot_p) − r_p)`` lower-bounds and
    ``max_p (d(q, pivot_p) + r_p)`` upper-bounds the distance to any of
    them.  Shards with no occupied pivot contribute nothing (lb 0,
    ub +inf) — never a prune.  ``dp`` (optional) is a precomputed
    :func:`_pivot_dists` result.
    """
    if s.pivots is None:
        return None, None
    m = s.pivots.shape[1]
    if dp is None:
        dp = _pivot_dists(s, q)
    occ = np.arange(m)[None, :] < s.pivot_count[:, None]     # (k, m)
    lb = np.where(occ[None], np.maximum(dp - s.pivot_radii[None], 0.0),
                  np.inf).min(-1)
    ub = np.where(occ[None], dp + s.pivot_radii[None], -np.inf).max(-1)
    has = s.pivot_count > 0
    return (np.where(has[None], lb, 0.0),
            np.where(has[None], ub, np.inf))


def _pivot_threshold(s: ShardSummaries, q: np.ndarray, ls: np.ndarray,
                     dp: np.ndarray | None = None) -> np.ndarray | None:
    """(B,) squared-distance threshold from per-pivot live accounting, or
    None when the summaries carry no pivot set or no per-pivot counts.

    Each occupied pivot ball p of shard j covers the ``pivot_live[j, p]``
    live points credited to it, all at distance <= d(q, pivot) + r from
    the query.  Visiting balls in ascending-upper-bound order until the
    cumulative credit reaches l therefore bounds the l-th NN distance
    from above — exactly the shard-level threshold logic at ball
    granularity.  Because the credits are a safe *undercount* (see
    :class:`ShardSummaries`), the cumulative sum reaches l no earlier
    than the truth, so this threshold can only be >= the exact-count
    one: sound by construction, and routing takes
    ``min(T_shard, T_pivot)`` so it can only tighten the decision.  The
    shard-level pass keeps charging each shard its full live count, so a
    ball-less (or credit-less) shard stays invisible here without ever
    loosening the combined threshold.
    """
    if s.pivots is None or s.pivot_live is None:
        return None
    m = s.pivots.shape[1]
    if dp is None:
        dp = _pivot_dists(s, q)
    B = q.shape[0]
    occ = ((np.arange(m)[None, :] < s.pivot_count[:, None])
           & (s.pivot_live > 0))                             # (k, m)
    pub = np.where(occ[None], (dp + s.pivot_radii[None]) ** 2, np.inf)
    pub_flat = pub.reshape(B, -1)
    plive_flat = np.where(occ, s.pivot_live, 0).reshape(-1)
    order = np.argsort(pub_flat, axis=1, kind="stable")
    csum = np.cumsum(plive_flat[order], axis=1)
    reached = csum >= ls[:, None]
    has = reached.any(axis=1)
    first = np.where(has, reached.argmax(axis=1), 0)
    pub_sorted = np.take_along_axis(pub_flat, order, axis=1)
    return np.where(has, pub_sorted[np.arange(B), first], np.inf)


def lower_bounds(s: ShardSummaries, queries: np.ndarray,
                 dc: np.ndarray | None = None,
                 pb: tuple | None = None) -> np.ndarray:
    """(B, k) *squared*-distance lower bound from each query to each
    shard's nearest live point; +inf for empty shards.  ``dc`` (optional)
    is a precomputed :func:`_centroid_distances` result; ``pb``
    (optional) a precomputed :func:`_pivot_bounds` pair — route_shards
    computes each once and shares them across both bound directions.
    All bound sources — aggregate ball, pivot set, projection sketch —
    are individually sound, so the result is their maximum."""
    q = np.atleast_2d(np.asarray(queries, np.float64))
    if dc is None:
        dc = _centroid_distances(s, q)
    lb = np.maximum(dc - s.radii[None], 0.0)
    plb, _ = _pivot_bounds(s, q) if pb is None else pb
    if plb is not None:
        lb = np.maximum(lb, plb)
    empty = s.live == 0
    if s.directions.size:
        qp = q @ s.directions.T                              # (B, r)
        lo = np.where(empty[:, None], 0.0, s.proj_lo)
        hi = np.where(empty[:, None], 0.0, s.proj_hi)
        gap = np.maximum(np.maximum(lo[None] - qp[:, None, :],
                                    qp[:, None, :] - hi[None]), 0.0)
        lb = np.maximum(lb, gap.max(-1))
    out = lb ** 2
    out[:, empty] = np.inf
    return out


def upper_bounds(s: ShardSummaries, queries: np.ndarray,
                 dc: np.ndarray | None = None,
                 pb: tuple | None = None) -> np.ndarray:
    """(B, k) *squared*-distance upper bound covering every live point of
    each shard; +inf for empty shards.  ``dc``/``pb`` as in
    :func:`lower_bounds`.  Both covers — aggregate ball and pivot-ball
    union — are sound, so the result is their minimum."""
    q = np.atleast_2d(np.asarray(queries, np.float64))
    if dc is None:
        dc = _centroid_distances(s, q)
    ub = dc + s.radii[None]
    _, pub = _pivot_bounds(s, q) if pb is None else pb
    if pub is not None:
        ub = np.minimum(ub, pub)
    out = ub ** 2
    out[:, s.live == 0] = np.inf
    return out


_F32_EPS = float(np.finfo(np.float32).eps)       # 2^-23


def pipeline_error_bound(s: ShardSummaries, queries: np.ndarray) -> np.ndarray:
    """(B,) absolute bound on twice the f32 rounding of any computed
    (query, live point) squared distance this generation.

    The pipeline's ``|q|² − 2q·p + |p|²`` in f32 carries error
    ~dim·2⁻²³·(|q|+|p|)² regardless of how small the true distance is;
    |p| <= R = max live (|centroid| + radius).  The factor 16·(dim+1)
    covers the accumulation constants of all three dot products, the
    three-term cancellation, and the doubling needed because both the
    pruned candidate's distance *and* the threshold-defining winners'
    distances are rounded.
    """
    q = np.atleast_2d(np.asarray(queries, np.float64))
    dim = q.shape[1]
    live = s.live > 0
    if live.any():
        R = float((np.linalg.norm(s.centroids[live], axis=1)
                   + s.radii[live]).max())
    else:
        R = 0.0
    qn = np.linalg.norm(q, axis=1)
    return 16.0 * (dim + 1) * _F32_EPS * (qn + R) ** 2


def routing_detail(s: ShardSummaries, queries: np.ndarray, ls,
                   *, slack: float = 1e-4) -> dict:
    """The routing decision *with its working shown* — the per-shard
    bounds and threshold that :func:`route_shards` computes internally,
    returned as a dict of arrays for the query-explain reports
    (obs/explain.py) and any offline audit:

    * ``lower`` / ``upper`` — (B, k) distance-squared bounds per shard,
    * ``threshold`` — (B,) T_b: the cumulative-live upper-bound walk's
      stopping value (min'd with the ball-granular pivot threshold),
    * ``threshold_eff`` — (B,) T_b·(1+slack) + err_b, the value the
      lower-bound test actually compares against,
    * ``keep`` — (B, k) bool, identical to :func:`route_shards`.

    Deterministic pure-f64 host math over a frozen summaries object:
    calling this again with the same (summaries, queries, ls, slack)
    reproduces the dispatch-time decision bit for bit, which is what
    lets explain reports be assembled lazily instead of taxing the
    dispatch hot path.
    """
    q = np.atleast_2d(np.asarray(queries, np.float64))
    B = q.shape[0]
    ls = np.broadcast_to(np.asarray(ls, np.int64), (B,))
    dc = _centroid_distances(s, q)
    dp = _pivot_dists(s, q)        # (B, k, m) pass — computed once
    pb = _pivot_bounds(s, q, dp)
    lb = lower_bounds(s, q, dc, pb)
    ub = upper_bounds(s, q, dc, pb)
    order = np.argsort(ub, axis=1, kind="stable")
    csum = np.cumsum(s.live[order], axis=1)
    reached = csum >= ls[:, None]
    has = reached.any(axis=1)
    first = np.where(has, reached.argmax(axis=1), 0)
    ub_sorted = np.take_along_axis(ub, order, axis=1)
    T = np.where(has, ub_sorted[np.arange(B), first], np.inf)
    tp = _pivot_threshold(s, q, ls, dp)
    if tp is not None:
        # ball-granular threshold from per-pivot live credits — sound
        # undercounts, so min() can only tighten (never drop a winner)
        T = np.minimum(T, tp)
    T_eff = T * (1.0 + slack) + pipeline_error_bound(s, q)
    keep = ((s.live[None, :] > 0) & (lb <= T_eff[:, None])
            & (ls[:, None] > 0))
    return {"lower": lb, "upper": ub, "threshold": T,
            "threshold_eff": T_eff, "keep": keep}


def route_shards(s: ShardSummaries, queries: np.ndarray, ls,
                 *, slack: float = 1e-4) -> np.ndarray:
    """(B, k) bool — shard j may hold one of row b's ``ls[b]`` winners.

    Exact by construction: T_b is the upper bound at which the cumulative
    live count (shards visited in ascending-upper-bound order) reaches
    ``ls[b]``, so the l-th NN distance is <= T_b; a shard is kept unless
    ``lb > T_b·(1+slack) + err_b`` with ``err_b`` the magnitude-absolute
    f32 rounding bound (:func:`pipeline_error_bound`) — it cannot contain
    a winner even under the computed-distance order the pipeline actually
    ranks by (module docstring).  Rows with ``ls[b] == 0`` (the
    micro-batcher's bucket padding) route nowhere; if the total live
    count is below l, every live shard stays active.
    """
    return routing_detail(s, queries, ls, slack=slack)["keep"]


def summary_invariants(s: ShardSummaries, points: np.ndarray,
                       valid: np.ndarray, cap: int) -> dict:
    """Worst-case violation of the covering invariants over the live set
    (test/bench hook; all values should be <= ~1e-9 for a correct
    maintainer — float64 rounding only)."""
    pts = np.asarray(points, np.float64)
    radius_viol = proj_viol = 0.0
    live_mismatch = 0
    for j in range(s.live.shape[0]):
        sl = slice(j * cap, (j + 1) * cap)
        pj = pts[sl][np.asarray(valid[sl], bool)]
        live_mismatch = max(live_mismatch, abs(len(pj) - int(s.live[j])))
        if not len(pj):
            continue
        d = np.sqrt(((pj - s.centroids[j]) ** 2).sum(-1))
        radius_viol = max(radius_viol, float((d - s.radii[j]).max()))
        pr = pj @ s.directions.T
        proj_viol = max(proj_viol,
                        float((s.proj_lo[j] - pr).max()),
                        float((pr - s.proj_hi[j]).max()))
    return {"radius_violation": radius_viol,
            "projection_violation": proj_viol,
            "live_mismatch": live_mismatch}


def summary_slack(s: ShardSummaries, points: np.ndarray, valid: np.ndarray,
                  cap: int) -> np.ndarray:
    """(k,) covering-radius slack: the maintained radius minus the exact
    live radius about the maintained centroid (0.0 for empty shards).

    The bound-decay observable (ISSUE 5 / ROADMAP "Adaptive placement"):
    incremental maintenance inflates the covering radius ~log n with
    per-shard ops while the live spread stays put, so this gap is exactly
    the pruning power lost since the last exact rebuild — ~0 right after
    a compaction or a scheduled re-tightening, growing with churn
    otherwise.  O(live·dim) host work; a fidelity probe for stats and
    benchmarks (``KnnServer.placement_stats()``), never on the dispatch
    path.
    """
    pts = np.asarray(points, np.float64)
    out = np.zeros(s.live.shape[0])
    for j in range(s.live.shape[0]):
        sl = slice(j * cap, (j + 1) * cap)
        pj = pts[sl][np.asarray(valid[sl], bool)]
        if not len(pj):
            continue
        exact = float(np.sqrt(((pj - s.centroids[j]) ** 2).sum(-1)).max())
        out[j] = float(s.radii[j]) - exact
    return out


def summary_slack_sampled(s: ShardSummaries, points: np.ndarray,
                          valid: np.ndarray, cap: int, *,
                          sample: int = 64, rng=None) -> np.ndarray:
    """(k,) sampled covering-radius slack — the maintenance worker's
    prioritization probe (repro.store.maintenance).

    Like :func:`summary_slack` but evaluates the exact live radius on at
    most ``sample`` uniformly drawn live points per shard, so a planning
    pass over all k shards costs O(k·sample·dim) instead of O(n·dim).
    Sampling can only *under*-estimate the true live radius, so the
    returned slack over-estimates the exact one — safe for picking which
    shard to re-tighten first (the stalest shard still ranks high), never
    used as a bound.  Empty shards report 0.0.
    """
    pts = np.asarray(points, np.float64)
    if rng is None:
        rng = np.random.default_rng(0)
    out = np.zeros(s.live.shape[0])
    for j in range(s.live.shape[0]):
        sl = slice(j * cap, (j + 1) * cap)
        pj = pts[sl][np.asarray(valid[sl], bool)]
        if not len(pj):
            continue
        if len(pj) > sample:
            pj = pj[rng.choice(len(pj), size=sample, replace=False)]
        exact = float(np.sqrt(((pj - s.centroids[j]) ** 2).sum(-1)).max())
        out[j] = float(s.radii[j]) - exact
    return out
