"""Adaptive summary maintenance — keeping routing bounds tight mid-stream.

PR 3's pruned routing and PR 4's locality placement both rest on per-shard
summaries (store/summaries.py) whose incremental maintenance is covering
but *loosening*: every insert/delete inflates the covering radius by the
centroid drift and deletes never shrink anything, so the certified bounds
decay ~log n with per-shard ops and pruning dies mid-stream until a full
compaction re-deal (BENCH_serve.json ``placement`` pre-compact rows).
PANDA (Patwary et al., 2016) gets its distributed-kNN pruning from
partition metadata that is kept *tight*, and the k-machine clustering
line (Bandyapadhyay et al., 2018) shows per-machine coreset-style
summaries can be refreshed cheaply without global rounds.  This module is
that subsystem for the mutable store, three mechanisms deep:

* **Multi-pivot summaries** (:class:`AdaptiveMaintainer`, consumed by the
  bound math in store/summaries.py).  Each shard carries up to ``m``
  pivot balls whose union covers its live points, alongside the aggregate
  centroid/radius and the projection sketch.  One shard hosting two small
  clusters no longer voids its bounds: the aggregate ball must span the
  inter-cluster gap, but the pivot balls hug each cluster, and the
  routing lower bound is the min over pivots — still provably sound
  (every source is an independent triangle-inequality bracket; routing
  takes the max of lower bounds and min of upper bounds) under the
  existing f32 slack machinery, so answers stay bit-identical to
  ``route="exact"`` (tests/test_routing.py).  Pivot centers are *fixed
  points* between exact rebuilds — an insert inflates the ball it joins
  (or claims a free pivot slot when it sits outside every ball), a delete
  leaves the union covering (stale-but-valid) — so per-op cost stays
  O(m·dim) and no drift bookkeeping is needed.

* **Scheduled exact re-tightening** (:meth:`AdaptiveMaintainer.retighten`
  + the per-shard op counters behind :meth:`retighten_due`).  A shard
  whose op count since its last exact rebuild crosses
  ``retighten_every`` becomes due; the store re-tightens **at most one
  due shard per flush**, round-robin, each pass an O(live·dim) host-side
  exact recompute of that shard's aggregate ball, pivot set, and
  projection intervals — no repack, no device work, no flush stall.
  Amortized, every bound is at most ``k`` flushes staler than its
  threshold, and :func:`repro.store.summaries.summary_slack` returns to
  ~0 shard by shard instead of only at the next global compaction.

* **Radius-triggered split scheduling** (:meth:`split_candidate`).  A
  shard whose covering radius outgrows the inter-centroid gap
  (``radius > split_radius_factor · gap-to-nearest-occupied-centroid``)
  is a shard the layout has failed — either it hosts two clusters or its
  members smeared along a drift path — and no amount of re-tightening
  fixes *placement*.  The trigger schedules a quota-bounded proximity
  re-deal through the existing ``redeal="proximity"`` machinery
  (store/placement.py) at the current flush, instead of waiting for the
  tombstone/imbalance compaction trigger that may be far away.  Three
  guards keep it from thrashing or re-arming the compactor it bypasses:
  the re-deal runs under the same clamped quota slack as a normal
  proximity compaction (post-redeal skew stays below the imbalance
  trigger — compaction.redeal_slack); a *growth guard* re-arms the
  trigger only once the shard's radius exceeds its value at the last
  exact rebuild by ``_SPLIT_GROWTH`` (a layout that is merely
  inseparable — more clusters than shards — triggers at most once, since
  a repack it cannot improve leaves radii at their exact values); and the
  store enforces a ``split_cooldown`` of applies between splits.

The store (store/mutable.py) owns the hook points: maintenance runs at
the tail of ``_apply_locked`` under the store lock, after ops replay and
only when no repack already rebuilt everything exactly, and the
maintainer is frozen with every generation exactly like the base class —
adaptive summaries inherit the generation-coupling invariant
(``summaries.generation == snapshot.generation`` always).  Pivot math,
schedule, and the split trigger's non-re-arming argument: DESIGN.md
Section 10.
"""

from __future__ import annotations

import numpy as np

from repro.store import summaries as summaries_mod

# Growth-guard hysteresis: a shard re-arms the split trigger only when
# its covering radius exceeds its last exactly-rebuilt value by this
# factor — radii that a re-deal already failed to shrink cannot re-fire.
_SPLIT_GROWTH = 1.1


def compute_pivots(points: np.ndarray, m: int):
    """Exact pivot set of one shard's live points: (pivots (m, dim),
    radii (m,), count).

    Farthest-point traversal picks up to ``m`` well-spread centers
    (deterministic: argmax takes the first maximum; stops early when
    every point coincides with a chosen pivot), then one assignment pass
    gives each pivot the covering radius of its nearest-pivot members —
    the union of balls covers the input by construction.  Unused slots
    stay zero with radius 0 and are ignored by the bound math
    (``pivot_count`` masks them).  O(m·n·dim).
    """
    pts = np.asarray(points, np.float64)
    n, dim = pts.shape
    pivots = np.zeros((m, dim))
    radii = np.zeros(m)
    if n == 0:
        return pivots, radii, 0
    chosen = [int(np.argmax(((pts - pts.mean(0)) ** 2).sum(-1)))]
    d = ((pts - pts[chosen[0]]) ** 2).sum(-1)
    while len(chosen) < m:
        far = int(np.argmax(d))
        if d[far] <= 0.0:
            break                     # every point already a chosen pivot
        chosen.append(far)
        d = np.minimum(d, ((pts - pts[far]) ** 2).sum(-1))
    count = len(chosen)
    pivots[:count] = pts[chosen]
    dists = np.sqrt(((pts[:, None, :] - pivots[None, :count]) ** 2).sum(-1))
    assign = dists.argmin(1)
    for p in range(count):
        mine = dists[assign == p, p]
        radii[p] = float(mine.max()) if mine.size else 0.0
    return pivots, radii, count


class AdaptiveMaintainer(summaries_mod.SummaryMaintainer):
    """Summary maintainer with a pivot set per shard and a maintenance
    schedule; drop-in for :class:`repro.store.summaries.SummaryMaintainer`
    (the store always builds this one — with ``num_pivots=1`` and both
    triggers at 0 it degrades to one fixed-center ball per shard and no
    scheduled work)."""

    def __init__(self, k: int, dim: int, *, num_projections: int = 8,
                 seed: int = 0, num_pivots: int = 1,
                 retighten_every: int = 0,
                 split_radius_factor: float = 0.0):
        super().__init__(k, dim, num_projections=num_projections, seed=seed)
        if num_pivots < 1:
            raise ValueError(f"num_pivots must be >= 1, got {num_pivots}")
        if retighten_every < 0:
            raise ValueError("retighten_every must be >= 0 (0 disables)")
        if split_radius_factor < 0:
            raise ValueError("split_radius_factor must be >= 0 (0 disables)")
        self.num_pivots = int(num_pivots)
        self.retighten_every = int(retighten_every)
        self.split_radius_factor = float(split_radius_factor)
        m = self.num_pivots
        self._piv = np.zeros((k, m, dim))
        self._piv_r = np.zeros((k, m))
        self._piv_n = np.zeros(k, np.int64)
        # Per-ball live credits — a safe undercount (see delete()):
        # every credit is a distinct live point inside its ball, so the
        # routing threshold may charge balls individually instead of
        # over-crediting a ball that lost points to deletes.
        self._piv_live = np.zeros((k, m), np.int64)
        self._ops_since = np.zeros(k, np.int64)   # ops since exact rebuild
        self._rr = 0                              # round-robin scan cursor
        self._radius_at_rebuild = np.zeros(k)     # split growth guard

    # ---- incremental ops (store lock held) ------------------------------

    def insert(self, shard: int, point) -> None:
        super().insert(shard, point)
        j = int(shard)
        p = np.asarray(point, np.float64)
        c = int(self._piv_n[j])
        if c == 0:
            self._piv[j, 0] = p
            self._piv_r[j, 0] = 0.0
            self._piv_n[j] = 1
            self._piv_live[j, 0] = 1
        else:
            d = np.sqrt(((self._piv[j, :c] - p) ** 2).sum(-1))
            if (d > self._piv_r[j, :c]).all() and c < self.num_pivots:
                # outside every ball with a slot free: claim a new pivot
                self._piv[j, c] = p
                self._piv_r[j, c] = 0.0
                self._piv_n[j] = c + 1
                self._piv_live[j, c] = 1
            else:
                # join the ball needing the least inflation (covering
                # either way; min-inflation keeps the union tight)
                b = int(np.argmin(d - self._piv_r[j, :c]))
                self._piv_r[j, b] = max(self._piv_r[j, b], float(d[b]))
                self._piv_live[j, b] += 1
        self._ops_since[j] += 1

    def delete(self, shard: int, point) -> None:
        # Removing a point leaves the pivot-ball union covering
        # (stale-but-valid, like the aggregate radius); emptied shards
        # reset through _reset_shard.  Live credits must stay a safe
        # undercount, and the ball that originally credited this point
        # is unknown — so debit every occupied ball that contains it
        # (radii never shrink between exact rebuilds, so the crediting
        # ball is among them).  Over-debiting neighbors only undercounts
        # further, which is the safe direction.
        j = int(shard)
        c = int(self._piv_n[j])
        if c:
            p = np.asarray(point, np.float64)
            d = np.sqrt(((self._piv[j, :c] - p) ** 2).sum(-1))
            r = self._piv_r[j, :c]
            inside = d <= r + 1e-9 * (1.0 + r)
            if not inside.any():
                inside[:] = True     # covering says unreachable; stay safe
            row = self._piv_live[j, :c]
            row[inside] -= 1
            np.maximum(row, 0, out=row)
        super().delete(shard, point)
        if self._n[j] > 0:
            self._ops_since[j] += 1

    def _reset_shard(self, j: int) -> None:
        super()._reset_shard(j)
        self._piv[j] = 0.0
        self._piv_r[j] = 0.0
        self._piv_n[j] = 0
        self._piv_live[j] = 0
        self._ops_since[j] = 0
        self._radius_at_rebuild[j] = 0.0

    # ---- exact recompute -------------------------------------------------

    def _rebuild_shard(self, j: int, pj: np.ndarray) -> None:
        super()._rebuild_shard(j, pj)
        piv, rad, cnt = compute_pivots(pj, self.num_pivots)
        self._piv[j] = piv
        self._piv_r[j] = rad
        self._piv_n[j] = cnt
        self._piv_live[j] = 0
        if cnt:
            dists = np.sqrt(
                ((pj[:, None, :] - piv[None, :cnt]) ** 2).sum(-1))
            self._piv_live[j, :cnt] = np.bincount(
                dists.argmin(1), minlength=cnt)
        self._ops_since[j] = 0
        self._radius_at_rebuild[j] = self._radius[j]

    def retighten(self, j: int, points: np.ndarray, valid: np.ndarray,
                  cap: int) -> None:
        """Exact recompute of shard ``j`` only, from the store mirrors —
        one shard's O(live·dim) host work, the unit the flush-path
        schedule pays per trigger."""
        j = int(j)
        sl = slice(j * cap, (j + 1) * cap)
        pts = np.asarray(points, np.float64)
        pj = pts[sl][np.asarray(valid[sl], bool)]
        if not len(pj):
            self._reset_shard(j)
            return
        self._rebuild_shard(j, pj)

    def copy_shard_from(self, j: int, other: "AdaptiveMaintainer",
                        oj: int) -> None:
        """Transplant shard ``oj``'s complete summary state from another
        maintainer into shard ``j`` of this one — the background
        maintenance worker's commit step (store/maintenance.py): the
        exact recompute runs off-lock on a k=1 scratch maintainer, then
        lands here under the store lock in O(m·dim + r)."""
        j, oj = int(j), int(oj)
        self._sum[j] = other._sum[oj]
        self._n[j] = other._n[oj]
        self._radius[j] = other._radius[oj]
        self._lo[j] = other._lo[oj]
        self._hi[j] = other._hi[oj]
        self._piv[j] = other._piv[oj]
        self._piv_r[j] = other._piv_r[oj]
        self._piv_n[j] = other._piv_n[oj]
        self._piv_live[j] = other._piv_live[oj]
        self._ops_since[j] = other._ops_since[oj]
        self._radius_at_rebuild[j] = other._radius_at_rebuild[oj]

    # ---- scheduling (store lock held) ------------------------------------

    def retighten_due(self) -> int | None:
        """The next shard owed an exact re-tightening, or None.

        A shard is due once it has absorbed ``retighten_every`` ops since
        its last exact rebuild; the scan is round-robin from a persistent
        cursor, so under sustained churn every due shard is served within
        k flushes and no shard can starve the others.
        """
        if self.retighten_every <= 0:
            return None
        for step in range(self.k):
            j = (self._rr + step) % self.k
            if self._n[j] > 0 and self._ops_since[j] >= self.retighten_every:
                self._rr = (j + 1) % self.k
                return j
        return None

    def split_candidate(self) -> int | None:
        """The worst shard whose covering radius outgrew the layout, or
        None.

        Trigger: ``radius > split_radius_factor · gap`` where gap is the
        distance to the nearest *other* occupied centroid — a radius that
        spans a neighbor's territory means the summary can no longer
        certify anything near that neighbor, which is a placement
        failure, not a bound-staleness one.  The growth guard
        (module docstring) only arms shards whose radius actually grew
        past its last exactly-rebuilt value, so an inseparable layout
        cannot re-fire the re-deal that already failed to improve it.
        """
        if self.split_radius_factor <= 0:
            return None
        occ = np.flatnonzero(self._n > 0)     # gaps measure ALL occupied
        cand = np.flatnonzero(self._n > 1)    # singletons never fire
        if occ.size < 2 or cand.size == 0:
            return None
        cents = self._sum[occ] / self._n[occ, None]
        cand_cents = self._sum[cand] / self._n[cand, None]
        gaps = np.sqrt(
            ((cand_cents[:, None] - cents[None]) ** 2).sum(-1))
        gaps[cand[:, None] == occ[None, :]] = np.inf       # self-distance
        gap = gaps.min(1)
        r = self._radius[cand]
        armed = r > _SPLIT_GROWTH * self._radius_at_rebuild[cand]
        ratio = r / np.maximum(gap, 1e-30)
        fire = armed & (ratio > self.split_radius_factor)
        if not fire.any():
            return None
        return int(cand[np.argmax(np.where(fire, ratio, -np.inf))])

    def freeze(self, generation: int) -> summaries_mod.ShardSummaries:
        # The single-pivot form freezes WITHOUT pivot fields (the
        # documented default): one fixed-center ball adds nothing over
        # the aggregate bound, and default stores keep the classic
        # summary shape and routing cost.
        if self.num_pivots == 1:
            return super().freeze(generation)
        return super().freeze(generation)._replace(
            pivots=self._piv.copy(),
            pivot_radii=self._piv_r.copy(),
            pivot_count=self._piv_n.copy(),
            pivot_live=self._piv_live.copy())
