"""Background maintenance worker — the store's async serving plane.

Under ``maintenance="inline"`` every flush pays for summary hygiene at
the tail of ``_apply_locked`` while holding the store lock: an exact
re-tightening is O(live·dim) host work, a split or auto-compaction is a
full repack *plus* a full device upload — and every concurrent reader
and writer stalls behind it.  PANDA (Patwary et al., 2016) makes the
case that distributed kNN serving survives scale precisely by
overlapping index maintenance with query service; this module is that
overlap for the mutable store.

One daemon worker thread per store runs a plan / prepare / commit loop:

* **Plan (short lock).**  Priority follows the inline plane's
  precedence: an armed auto-compaction trigger first, then a radius
  split, then the stalest due re-tightening — "stalest" by a *sampled*
  summary-slack probe (:func:`repro.store.summaries.summary_slack_sampled`,
  O(k·sample·dim)) rather than the inline round-robin cursor, so the
  shard whose bounds decayed most gets served first.  Planning also
  opens the **journal**: from here until commit, ``_apply_locked``
  records every applied op
  ``(kind, id, shard, new_point, old_point, label)``.

* **Prepare (no lock).**  Everything expensive happens against captured
  copies: the exact per-shard recompute runs on a k=1 scratch
  maintainer; a repack runs :func:`repro.store.compaction.repack` /
  :func:`repro.store.placement.repack_proximity` on copied mirrors,
  rebuilds a full scratch maintainer, and ``device_put``s the repacked
  buffers — in-flight micro-batches keep serving their snapshot
  throughout, and concurrent flushes keep publishing fresh generations.

* **Commit (short lock).**  If an inline repack invalidated the capture
  (forced repack on a full shard, explicit ``compact()``), the staged
  work is discarded — the store already rebuilt itself exactly.
  Otherwise the journal replays onto the staged state: a re-tightening
  replays the captured shard's ops into the scratch maintainer and
  transplants the result (``AdaptiveMaintainer.copy_shard_from``; the
  summaries re-freeze at the *current* generation — same live set,
  tighter bounds, still atomic under the lock, so the
  (snapshot, summaries) generation-coupling invariant holds); a repack
  replays every journaled op onto the staged mirrors (placement picks
  against the staged layout), scatters the replayed slots onto the
  pre-uploaded device buffers, installs mirrors + maintainer, and
  publishes the epoch swap exactly like a flush does.  Replay is
  journal-order and total, so the committed state is byte-equal to what
  an inline repack at commit time would have produced — live set,
  id→slot map, and live counts all agree with the mirrors that raced it.

Exactness is untouched: every published generation's snapshot is a pure
function of the applied op sequence (layout may differ between planes;
answers may not — selection is layout-independent, and the concurrency
harness tests/test_async_maintenance.py holds every served answer
bit-identical to a quiet-store oracle replayed at its generation).
DESIGN.md Section 11 walks the protocol and its failure cases.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from typing import Optional

import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.store import adaptive as adaptive_mod
from repro.store import compaction
from repro.store import index as index_mod
from repro.store import placement as placement_mod
from repro.store import summaries as summaries_mod


@dataclasses.dataclass
class MaintenanceStats:
    cycles: int = 0          # plans that found work
    retightens: int = 0      # committed background re-tightenings
    repacks: int = 0         # committed background repacks (incl. splits)
    splits: int = 0          # the split-triggered subset of repacks
    commits: int = 0         # total committed cycles
    discards: int = 0        # staged work dropped (invalidated / no room)
    replayed_ops: int = 0    # journal ops replayed across all commits
    errors: int = 0          # cycles that raised (see .error)


class MaintenanceWorker:
    """One store's background maintenance thread; see module docstring.

    Event-driven: the store pokes :meth:`notify` after every apply, and
    the loop also wakes on a short timeout as a belt-and-braces guard.
    All state mutation — the store's *and* this worker's stats — happens
    under the store lock, so ``stats_dict()`` reads are torn-free.
    """

    def __init__(self, store, *, probe_sample: int = 64, seed: int = 0):
        self._store = store
        self.probe_sample = int(probe_sample)
        self._rng = np.random.default_rng(seed)
        self.stats = MaintenanceStats()
        self.error: Optional[str] = None
        self._event = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="knn-store-maintenance", daemon=True)
        self._thread.start()

    # ---- lifecycle -------------------------------------------------------

    def notify(self) -> None:
        """Wake the worker (called by the store after each apply; safe
        under the store lock — this only sets an event)."""
        self._event.set()

    def stop(self) -> None:
        """Stop and join the worker.  A cycle in flight finishes (its
        commit either lands or discards) before the thread exits."""
        self._stop.set()
        self._event.set()
        self._thread.join()

    def stats_dict(self) -> dict:
        d = dataclasses.asdict(self.stats)
        d["probe_sample"] = self.probe_sample
        d["error"] = self.error
        return d

    # ---- worker loop -----------------------------------------------------

    def _run(self) -> None:
        while not self._stop.is_set():
            self._event.wait(timeout=0.1)
            self._event.clear()
            while not self._stop.is_set():
                try:
                    if not self._cycle():
                        break
                except Exception:       # keep serving; surface via stats
                    with self._store._lock:
                        self._store._journal = None
                        self.stats.errors += 1
                        self.error = traceback.format_exc()
                    break

    def _plan_locked(self):
        """Pick the next unit of work (store lock held), or None.

        Same precedence as the inline tail: compaction debt first (it
        rebuilds everything anyway), then a radius split, then the due
        shard with the *largest sampled summary slack* — the probe
        over-estimates true slack (sampling can only shrink the exact
        radius it subtracts) which is safe for prioritization.
        """
        st = self._store
        if st.auto_compact:
            decision = compaction.evaluate(
                st._live, st._used, st.cap,
                tombstone_frac=st.compact_tombstone_frac,
                imbalance_frac=st.compact_imbalance_frac,
                registry=st._obs_registry())
            if decision.compact:
                return ("repack", st.redeal, decision.reason)
        j = st._split_due_locked()
        if j is not None:
            return ("split", "proximity",
                    f"split: shard {j} radius outgrew the centroid gap")
        if st._summ.retighten_every > 0:
            due = np.flatnonzero(
                (st._summ._ops_since >= st._summ.retighten_every)
                & (st._summ._n > 0))
            if due.size:
                slack = summaries_mod.summary_slack_sampled(
                    st._summaries, st._pts, st._valid, st.cap,
                    sample=self.probe_sample, rng=self._rng)
                return ("retighten", int(due[np.argmax(slack[due])]))
        return None

    def _scratch(self, k: int) -> adaptive_mod.AdaptiveMaintainer:
        """A fresh maintainer with the store's exact summary knobs — the
        off-lock workspace whose state transplants into the live one."""
        st = self._store
        return adaptive_mod.AdaptiveMaintainer(
            k, st.dim, num_projections=st._summ.num_projections,
            seed=st._summ.seed, num_pivots=st._summ.num_pivots,
            retighten_every=st._summ.retighten_every,
            split_radius_factor=st._summ.split_radius_factor)

    def _cycle(self) -> bool:
        """One plan / prepare / commit pass; False when no work is due.

        Each working cycle emits one ``maint.cycle`` trace rooted in the
        store's attached obs plane (src/repro/obs/), with ``maint.plan``
        / ``maint.prepare`` / ``maint.commit`` (or ``maint.discard``)
        child spans — so a query trace and the maintenance commit racing
        it are directly comparable on the shared monotonic clock.
        """
        st = self._store
        obs = st._obs
        tracer = obs.tracer if obs is not None else NULL_TRACER
        t0 = time.perf_counter()
        with st._lock:
            plan = self._plan_locked()
            if plan is None:
                return False
            self.stats.cycles += 1
            st._journal = []
            st._journal_invalid = False
            if plan[0] == "retighten":
                j = plan[1]
                sl = slice(j * st.cap, (j + 1) * st.cap)
                pj = np.asarray(st._pts[sl][st._valid[sl]], np.float64)
            else:
                pts = st._pts.copy()
                ids = st._ids.copy()
                valid = st._valid.copy()
                labels = (st._labels.copy() if st.with_labels else None)
                if (plan[1] or st.redeal) == "proximity":
                    centroids, _, occupied = st._summ.placement_view()
                    seed_cents = (centroids[occupied]
                                  if occupied.any() else None)
                slack = compaction.redeal_slack(
                    st.placement_guard_slack, st.compact_imbalance_frac,
                    st.cap, st.k)
        cspan = tracer.begin("maint.cycle", t0=t0, kind=plan[0])
        tracer.record("maint.plan", t0, time.perf_counter(), parent=cspan,
                      kind=plan[0])
        try:
            if plan[0] == "retighten":
                self._retighten(plan[1], pj, tracer=tracer, cspan=cspan)
            else:
                self._repack(plan, pts, ids, valid, labels,
                             seed_cents if plan[1] == "proximity" else None,
                             slack, tracer=tracer, cspan=cspan)
        finally:
            cspan.end()
            if obs is not None:
                obs.metrics.histogram("maint.cycle_s").observe(
                    time.perf_counter() - t0)
        return True

    # ---- re-tightening ---------------------------------------------------

    def _retighten(self, j: int, pj: np.ndarray, *, tracer=NULL_TRACER,
                   cspan=None) -> None:
        st = self._store
        with tracer.span("maint.prepare", parent=cspan, shard=j,
                         live=len(pj)):
            scratch = self._scratch(1)
            if len(pj):                          # off-lock exact rebuild
                scratch._rebuild_shard(0, pj)
        t_commit = time.perf_counter()
        with st._lock:
            journal, st._journal = st._journal, None
            if st._journal_invalid:
                self.stats.discards += 1
                tracer.record("maint.discard", t_commit,
                              time.perf_counter(), parent=cspan,
                              reason="capture invalidated")
                return
            # replay what raced the rebuild — shard j's ops only
            for kind, _pid, shard, new_pt, old_pt, _label in journal:
                if shard != j:
                    continue
                if kind == "insert":
                    scratch.insert(0, new_pt)
                elif kind == "delete":
                    scratch.delete(0, old_pt)
                else:
                    scratch.update(0, old_pt, new_pt)
                self.stats.replayed_ops += 1
            st._summ.copy_shard_from(j, scratch, 0)
            # same data, tighter bounds: re-freeze at the CURRENT
            # generation — no epoch swap, still atomic under the lock
            st._summaries = st._summ.freeze(st._snap.generation)
            st.stats.retightens += 1
            self.stats.retightens += 1
            self.stats.commits += 1
            st._note_maint_commit({
                "kind": "retighten", "shard": int(j),
                "generation": int(st._snap.generation)})
        tracer.record("maint.commit", t_commit, time.perf_counter(),
                      parent=cspan, kind="retighten", shard=j,
                      generation=st._snap.generation)

    # ---- repack / split --------------------------------------------------

    def _repack(self, plan, pts, ids, valid, labels, seed_cents,
                slack: int, *, tracer=NULL_TRACER, cspan=None) -> None:
        from repro.store import mutable as mutable_mod
        st = self._store
        kind, redeal, reason = plan
        # ---- prepare off-lock: repack copies, rebuild a scratch
        # maintainer, upload the repacked buffers ----
        with tracer.span("maint.prepare", parent=cspan, kind=kind,
                         redeal=redeal or st.redeal, reason=reason):
            if (redeal or st.redeal) == "proximity":
                res = placement_mod.repack_proximity(
                    pts, ids, valid, st.k, st.cap,
                    id_sentinel=mutable_mod.ID_SENTINEL,
                    balance_slack=slack, seed_centroids=seed_cents)
            else:
                res = compaction.repack(pts, ids, valid, st.k, st.cap,
                                        id_sentinel=mutable_mod.ID_SENTINEL)
            # label payloads follow their points through the re-deal,
            # remapped against the CAPTURED layout (the journal replays
            # whatever raced this onto the staged mirrors below)
            new_labels = (compaction.remap_payload(
                labels, ids, valid, res.ids, res.valid)
                if labels is not None else None)
            scratch = self._scratch(st.k)
            scratch.rebuild(res.points, res.valid, st.cap)
            # The approximate index tier rebuilds the same way: exact
            # off-lock against the repacked layout, journal-replayed at
            # commit, installed with the epoch swap — so its frozen form
            # stays generation-coupled through background repacks too.
            scratch_idx = None
            if st._index is not None:
                scratch_idx = index_mod.IndexMaintainer(
                    st.k, st.cap, st.dim, st._index.num_buckets)
                scratch_idx.rebuild(res.points, res.valid)
            # upload copies: replay mutates the staged mirrors after
            # this, and the transfer may still be in flight (the same
            # rule as _upload_snapshot_locked)
            import jax
            dev_pts = jax.device_put(res.points.copy(), st._sharding)
            dev_ids = jax.device_put(res.ids.copy(), st._sharding)
            dev_valid = jax.device_put(res.valid.copy(), st._sharding)
            dev_labels = (jax.device_put(new_labels.copy(), st._sharding)
                          if new_labels is not None else None)

        t_commit = time.perf_counter()
        with st._lock:
            journal, st._journal = st._journal, None
            if st._journal_invalid:
                self.stats.discards += 1
                tracer.record("maint.discard", t_commit,
                              time.perf_counter(), parent=cspan,
                              reason="capture invalidated")
                return
            new_pts, new_ids, new_valid = res.points, res.ids, res.valid
            slot_of, live, used = res.slot_of, res.live, res.used
            touched: set[int] = set()
            for kind_op, pid, _shard, new_pt, old_pt, label in journal:
                if kind_op == "insert":
                    if st._placement.uses_centroids:
                        c, r, occ = scratch.placement_view()
                    else:
                        c = r = occ = None
                    j = st._placement.pick(
                        new_pt, placement_mod.PlacementView(
                            live=live, used=used, cap=st.cap,
                            centroids=c, radii=r, occupied=occ))
                    if j < 0:
                        # the staged layout has no tail room for what
                        # raced it — drop the staged work; the store's
                        # own state already has these ops applied
                        self.stats.discards += 1
                        tracer.record("maint.discard", t_commit,
                                      time.perf_counter(), parent=cspan,
                                      reason="no tail room for replay")
                        return
                    slot = j * st.cap + int(used[j])
                    used[j] += 1
                    live[j] += 1
                    scratch.insert(j, new_pt)
                    if scratch_idx is not None:
                        scratch_idx.insert(j, slot, new_pt)
                    new_pts[slot] = new_pt
                    new_ids[slot] = pid
                    new_valid[slot] = True
                    if new_labels is not None:
                        new_labels[slot] = label
                    slot_of[pid] = slot
                    touched.add(slot)
                elif kind_op == "delete":
                    slot = slot_of.pop(pid)
                    live[slot // st.cap] -= 1
                    scratch.delete(slot // st.cap, new_pts[slot])
                    if scratch_idx is not None:
                        scratch_idx.delete(slot)
                    new_valid[slot] = False
                    new_ids[slot] = mutable_mod.ID_SENTINEL
                    touched.add(slot)
                else:  # update
                    slot = slot_of[pid]
                    scratch.update(slot // st.cap, new_pts[slot], new_pt)
                    if scratch_idx is not None:
                        scratch_idx.update(slot, new_pt)
                    new_pts[slot] = new_pt
                    if new_labels is not None and label is not None:
                        new_labels[slot] = label
                    touched.add(slot)
                self.stats.replayed_ops += 1
            if touched:
                idx, up, ui, uv = compaction.scatter_operands(
                    sorted(touched), new_pts, new_ids, new_valid,
                    st.total, st.dim,
                    id_sentinel=mutable_mod.ID_SENTINEL)
                if new_labels is not None:
                    ul = compaction.payload_operand(
                        sorted(touched), new_labels, len(idx))
                    dev_pts, dev_ids, dev_valid, dev_labels = st._apply_fn(
                        dev_pts, dev_ids, dev_valid, dev_labels,
                        idx, up, ui, uv, ul)
                else:
                    dev_pts, dev_ids, dev_valid = st._apply_fn(
                        dev_pts, dev_ids, dev_valid, idx, up, ui, uv)
            # ---- install + epoch swap (identical publish sequence to
            # _apply_locked's repack arm) ----
            st._pts, st._ids, st._valid = new_pts, new_ids, new_valid
            if new_labels is not None:
                st._labels = new_labels
            st._slot_of, st._live, st._used = slot_of, live, used
            gen = st._snap.generation + 1
            st._snap = mutable_mod.StoreSnapshot(
                generation=gen, points=dev_pts, ids=dev_ids,
                valid=dev_valid, live=int(live.sum()),
                labels=dev_labels)
            st._summ = scratch
            st._summaries = scratch.freeze(gen)
            if scratch_idx is not None:
                st._index = scratch_idx
                st._frozen_index = scratch_idx.freeze(gen)
            st.stats.applies += 1
            st.stats.compactions += 1
            st.stats.last_compact_reason = reason
            if kind == "split":
                st.stats.splits += 1
                st._applies_at_split = st.stats.applies
                self.stats.splits += 1
            st._record_history()
            self.stats.repacks += 1
            self.stats.commits += 1
            st._note_maint_commit({
                "kind": str(kind), "redeal": str(redeal or st.redeal),
                "reason": str(reason), "generation": int(gen),
                "replayed": len(journal)})
        tracer.record("maint.commit", t_commit, time.perf_counter(),
                      parent=cspan, kind=kind, generation=gen,
                      replayed=len(journal))
