"""Checkpoint manager: async, atomic, keep-N, elastic restore.

Fault-tolerance contract (DESIGN.md Section 5):
  * **atomic** — writes go to `<dir>/tmp_<step>` and are os.rename'd to
    `<dir>/step_<step>` only when complete; a crash mid-save can never
    corrupt the latest checkpoint;
  * **async** — `save()` snapshots to host memory synchronously (cheap)
    and serializes on a background thread, so the train step resumes
    immediately; `wait()` joins before exit / before the next save;
  * **keep-N** — bounded disk usage, oldest checkpoints pruned after a
    successful save;
  * **elastic restore** — `restore()` reassembles logical arrays and
    device_puts them onto whatever mesh/sharding the *current* run uses
    (serialization.py stores logical indices, not device ids).
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint import serialization


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot `tree` (device -> host) and serialize asynchronously."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                tmp = os.path.join(self.directory, f"tmp_{step}")
                final = os.path.join(self.directory, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                serialization.save_pytree(host_tree, tmp)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._prune()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, *, mesh=None, specs=None):
        """Load step; if (mesh, specs) given, device_put each leaf onto its
        NamedSharding — the elastic path."""
        d = os.path.join(self.directory, f"step_{step}")
        tree = serialization.load_pytree(d, target_tree)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                tree, specs)
        return tree

    def restore_latest(self, target_tree, *, mesh=None, specs=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target_tree, mesh=mesh, specs=specs)

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
