from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint import serialization

__all__ = ["CheckpointManager", "serialization"]
