"""Shard-aware pytree serialization.

Each process writes only its *addressable* shards (the multi-host code
path; on a single CPU process that degenerates to full arrays) into one
.npz per process plus a JSON manifest describing the logical tree: paths,
global shapes, dtypes, and per-entry shard indices.  Restore reassembles
logical arrays from any number of saved shard files and re-shards onto the
*current* mesh via device_put — which is what makes restore elastic: a
checkpoint written on a (16, 16) mesh restores onto (2, 16, 16) or a
single device unchanged.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_pytree(tree, directory: str, *, process_index: int | None = None):
    """Write this process's shards + manifest into `directory`."""
    os.makedirs(directory, exist_ok=True)
    pidx = jax.process_index() if process_index is None else process_index
    flat, _ = _flatten(tree)

    manifest: dict[str, Any] = {"entries": {}, "process": pidx}
    arrays = {}
    for key, leaf in flat.items():
        arr = leaf
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        shards = []
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            for i, sh in enumerate(arr.addressable_shards):
                if sh.replica_id != 0:
                    continue  # one copy per logical shard
                name = f"{key}@@{i}"
                arrays[name] = np.asarray(sh.data)
                shards.append({"name": name,
                               "index": _index_to_json(sh.index)})
        else:
            name = f"{key}@@full"
            arrays[name] = np.asarray(arr)
            shards.append({"name": name, "index": None})
        entry["shards"] = shards
        manifest["entries"][key] = entry

    np.savez(os.path.join(directory, f"shards_{pidx}.npz"), **arrays)
    with open(os.path.join(directory, f"manifest_{pidx}.json"), "w") as f:
        json.dump(manifest, f)


def load_pytree(directory: str, target_tree):
    """Reassemble into the structure of `target_tree` (arrays or
    ShapeDtypeStructs); placement/sharding is the caller's job."""
    flat_t, treedef = _flatten(target_tree)

    manifests = sorted(p for p in os.listdir(directory)
                       if p.startswith("manifest_"))
    entries: dict[str, Any] = {}
    data: dict[str, np.ndarray] = {}
    for mf in manifests:
        with open(os.path.join(directory, mf)) as f:
            m = json.load(f)
        pidx = m["process"]
        z = np.load(os.path.join(directory, f"shards_{pidx}.npz"))
        for k in z.files:
            data[k] = z[k]
        for key, e in m["entries"].items():
            entries.setdefault(key, {"shape": e["shape"],
                                     "dtype": e["dtype"], "shards": []})
            entries[key]["shards"].extend(e["shards"])

    out = {}
    for key, tgt in flat_t.items():
        if key not in entries:
            raise KeyError(f"checkpoint missing leaf {key}")
        e = entries[key]
        full = np.zeros(e["shape"], dtype=e["dtype"])
        for sh in e["shards"]:
            idx = _index_from_json(sh["index"])
            if idx is None:
                full = data[sh["name"]]
            else:
                full[idx] = data[sh["name"]]
        out[key] = full

    leaves = [out[k] for k in flat_t.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _index_to_json(index):
    if index is None:
        return None
    return [[s.start, s.stop, s.step] for s in index]


def _index_from_json(spec):
    if spec is None:
        return None
    return tuple(slice(a, b, c) for a, b, c in spec)
