"""Model configuration — one dataclass covers every assigned architecture.

Families:
  dense   — decoder-only transformer (GQA + RoPE + SwiGLU)
  moe     — dense + mixture-of-experts FFN on a layer period
  hybrid  — Mamba blocks with periodic attention layers (+ optional MoE)
  vlm     — dense backbone consuming a stub patch-embedding prefix
  audio   — encoder-decoder transformer, stub frame-embedding encoder input
  ssm     — xLSTM (alternating mLSTM / sLSTM blocks)

Every field corresponds to a published config (see configs/<arch>.py for the
sources).  `reduced()` derives the family-preserving smoke-test config
mandated by the deliverables: same block structure, tiny dims.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | vlm | audio | ssm

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # physical head padding (EXPERIMENTS.md Section Perf, granite iter 3):
    # dummy never-contributing query heads (hard-masked before the output
    # projection, so they receive no gradients) appended per KV group so
    # the head dim tiles the model mesh axis.  0 = no padding.
    head_pad_to: int = 0
    kv_head_pad_to: int = 0

    # MoE (family moe / hybrid)
    n_experts: int = 0
    moe_top_k: int = 0
    moe_period: int = 1              # MoE FFN every `period` layers
    capacity_factor: float = 1.25
    # physical expert-tensor padding: dummy never-routed experts appended
    # so the expert dim tiles the model mesh axis (40 -> 48 for granite);
    # without it GSPMD replicates expert weights and lowers the dispatch
    # to collective-permute chains (EXPERIMENTS.md Section Perf, granite
    # iteration 2).  0 = no padding.
    expert_pad_to: int = 0

    # hybrid (jamba): one attention layer every `attn_period` layers,
    # the rest are Mamba blocks.
    attn_period: int = 0             # 0 => no mamba layers
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # ssm (xlstm): layer i is sLSTM if i % slstm_period == slstm_offset
    slstm_period: int = 2
    slstm_offset: int = 1
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3334

    # encoder-decoder (audio family)
    n_enc_layers: int = 0            # 0 => decoder-only

    # modality frontend stubs
    num_prefix_embeds: int = 0       # vlm: patch positions prepended
    frontend_frames: int = 0         # audio: encoder input length (frames)

    # training
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, (
            self.n_heads, self.n_kv_heads)

    # ---- derived ----------------------------------------------------------
    @property
    def n_experts_phys(self) -> int:
        return max(self.n_experts, self.expert_pad_to)

    @property
    def n_kv_phys(self) -> int:
        return max(self.n_kv_heads, self.kv_head_pad_to)

    @property
    def n_heads_phys(self) -> int:
        hp = max(self.n_heads, self.head_pad_to)
        assert hp % self.n_kv_phys == 0, (hp, self.n_kv_phys)
        return hp

    @property
    def head_group(self) -> int:
        """Real query heads per real KV head."""
        return self.n_heads // self.n_kv_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid schedule: jamba places one attention layer per period
        (at position attn_period - 1: layers 0..6 Mamba, layer 7 attention)."""
        if self.attn_period <= 0:
            return True
        return (i % self.attn_period) == (self.attn_period - 1)

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts <= 0:
            return False
        return (i % self.moe_period) == (self.moe_period - 1)

    def is_slstm_layer(self, i: int) -> bool:
        return (i % self.slstm_period) == self.slstm_offset

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md Section 4)."""
        return self.family in ("hybrid", "ssm")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), used for
        MODEL_FLOPS = 6 N D in the roofline (dense) / active-N for MoE."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        ffn_dense = 3 * d * self.d_ff
        ffn_moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        mamba = self._mamba_params()
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        n_dec = self.n_layers
        for i in range(n_dec):
            if self.family == "ssm":
                total += self._xlstm_params(i)
                continue
            if self.is_attn_layer(i):
                total += attn
            else:
                total += mamba
            total += ffn_moe if self.is_moe_layer(i) else ffn_dense
            total += 2 * d  # norms
        if self.is_encdec:
            for _ in range(self.n_enc_layers):
                total += attn + ffn_dense + 2 * d
            total += self.n_layers * (attn + d)  # decoder cross-attn + norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.n_experts <= 0:
            return self.param_count()
        d = self.d_model
        full_ffn = self.n_experts * 3 * d * self.d_ff
        active_ffn = self.moe_top_k * 3 * d * self.d_ff
        n_moe = sum(self.is_moe_layer(i) for i in range(self.n_layers))
        return self.param_count() - n_moe * (full_ffn - active_ffn)

    def _mamba_params(self) -> int:
        d = self.d_model
        di = self.mamba_expand * d
        ds = self.mamba_d_state
        return (2 * d * di            # in_proj (x, z)
                + di * self.mamba_d_conv
                + di * (2 * ds + 1)   # B, C, dt from x
                + di + di * ds        # dt_proj bias + A
                + di * d)             # out_proj

    def _xlstm_params(self, i: int) -> int:
        d = self.d_model
        if self.is_slstm_layer(i):
            dp = int(d * self.slstm_proj_factor)
            return 4 * d * d * 1 + 2 * d * dp  # gates (4) + up/down proj
        dp = int(d * self.mlstm_proj_factor)
        return 2 * d * dp + dp * dp * 3 + dp * d

    # ---- smoke-test reduction ---------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        scale = {
            "n_layers": min(self.n_layers, 4 if self.attn_period <= 0
                            else 2 * max(self.attn_period, 2)),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2)
            if self.n_kv_heads < self.n_heads else 4,
            "head_dim": 16,
            "d_ff": 128 if self.d_ff else 0,
            "vocab": 256,
            "n_experts": min(self.n_experts, 4),
            "moe_top_k": min(self.moe_top_k, 2),
            "n_enc_layers": min(self.n_enc_layers, 2),
            "num_prefix_embeds": min(self.num_prefix_embeds, 8),
            "frontend_frames": min(self.frontend_frames, 16),
            "mamba_d_state": min(self.mamba_d_state, 8),
        }
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One cell of the assigned (arch x shape) matrix."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig):
    """The shape set an architecture actually runs (DESIGN.md Section 4):
    long_500k only for sub-quadratic families; every assigned arch has a
    decoder so decode shapes always apply."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


def skipped_shapes_for(cfg: ModelConfig):
    return tuple(s for s in ALL_SHAPES if s not in shapes_for(cfg))
