"""Shared layers: norms, embeddings, RoPE, and the sharded-vocab CE loss."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain


# ---- norms ----------------------------------------------------------------

def rmsnorm_params(create, d: int):
    return {"scale": create("scale", (d,), (None,), init="ones",
                            dtype=jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


# ---- embedding / unembedding -----------------------------------------------

def embedding_params(create, vocab: int, d: int):
    return {"table": create("table", (vocab, d), ("vocab", "embed"),
                            init="normal")}


def embed(params, tokens):
    out = jnp.take(params["table"], tokens, axis=0)
    return constrain(out, "batch", "seq", None)


def unembed(params, x, *, table=None):
    """Project to vocab logits; `table` overrides for tied embeddings."""
    t = table if table is not None else params["table"]
    logits = jnp.einsum("bsd,vd->bsv", x, t)
    return constrain(logits, "batch", "seq", "vocab")


# ---- rotary position embedding ---------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                          # (...,S,1,half)
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---- loss -------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid tokens.  `logits` (B, S, V) stays vocab-sharded:
    the log-sum-exp and label gather partition cleanly over the vocab axis
    (GSPMD inserts the two small all-reduces), so the full unsharded logits
    tensor never exists on any device."""
    logits = logits.astype(jnp.float32)
    m = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    nll = m - label_logit
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
