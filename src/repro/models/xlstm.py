"""xLSTM blocks — mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful-in-structure implementations of arXiv:2405.04517 adapted for TPU:

* mLSTM has a parallelizable form (gated linear attention with matrix
  memory C = sum f..f i v k^T).  We use the standard chunked algorithm:
  intra-chunk quadratic attention with cumulative gate products +
  inter-chunk recurrence on the (B, H, dh, dh) carried state — identical
  in spirit to the Mamba chunked scan (and to the paper's own
  "discard most work cheaply" selection flavor).  Gate products are
  accumulated in log space for stability.

* sLSTM is inherently sequential (exponential gating with a max-stabilizer
  recurrence, Eq. 18-24): a lax.scan over time with a small (B, H, dh)
  state.  Decode is one step — O(1) per token, which is what makes
  xlstm-125m eligible for the long_500k cell.

Block layout follows the paper's pre-LN residual blocks with the block's own
up/down projections (the assigned config has d_ff = 0: no separate FFN).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.sharding import constrain

MLSTM_CHUNK = 64


class MLstmCache(NamedTuple):
    c: jax.Array   # (B, H, dh, dh) matrix memory, f32
    n: jax.Array   # (B, H, dh) normalizer, f32


class SLstmCache(NamedTuple):
    c: jax.Array   # (B, H, dh) cell, f32
    n: jax.Array   # (B, H, dh) normalizer, f32
    h: jax.Array   # (B, H, dh) hidden (recurrent input), f32
    m: jax.Array   # (B, H, dh) max-stabilizer, f32


# ---------------------------------------------------------------- mLSTM ----

def mlstm_params(create, d_model: int, n_heads: int, proj_factor: float):
    dp = _round8(int(d_model * proj_factor))
    dh = dp // n_heads
    del dh
    return {
        "up": create("up", (d_model, 2 * dp), ("embed", "mlp")),
        "wq": create("wq", (dp, dp), ("mlp", None)),
        "wk": create("wk", (dp, dp), ("mlp", None)),
        "wv": create("wv", (dp, dp), ("mlp", None)),
        "w_i": create("w_i", (dp, n_heads), ("mlp", None), init="zeros"),
        "b_i": create("b_i", (n_heads,), (None,), init="zeros"),
        "w_f": create("w_f", (dp, n_heads), ("mlp", None), init="zeros"),
        "b_f": create("b_f", (n_heads,), (None,), init="ones"),
        "down": create("down", (dp, d_model), ("mlp", "embed")),
    }


def _round8(x: int) -> int:
    return max(8, (x // 8) * 8)


def _mlstm_qkvg(params, x, n_heads):
    B, S, _ = x.shape
    xz = x @ params["up"]
    xi, z = jnp.split(xz, 2, axis=-1)                   # (B, S, dp)
    dp = xi.shape[-1]
    dh = dp // n_heads
    q = (xi @ params["wq"]).reshape(B, S, n_heads, dh)
    k = (xi @ params["wk"]).reshape(B, S, n_heads, dh) / jnp.sqrt(
        jnp.float32(dh)).astype(xi.dtype)
    v = (xi @ params["wv"]).reshape(B, S, n_heads, dh)
    # per-head scalar gates; forget gate through sigmoid (bounded decay),
    # input gate through exp with the sigmoid-log trick kept in log space
    logf = jax.nn.log_sigmoid(
        (xi @ params["w_f"]).astype(jnp.float32) + params["b_f"])  # (B,S,H)
    logi = (xi @ params["w_i"]).astype(jnp.float32) + params["b_i"]
    return q, k, v, logf, logi, z


def mlstm_block(params, x, *, n_heads: int, chunk: int = MLSTM_CHUNK):
    """Chunked parallel mLSTM: x (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    q, k, v, logf, logi, z = _mlstm_qkvg(params, x, n_heads)
    dh = q.shape[-1]

    c = chunk if S % chunk == 0 else S
    n_ch = S // c

    def resh(t):
        return jnp.moveaxis(
            t.reshape(B, n_ch, c, *t.shape[2:]), 1, 0)

    qs, ks, vs, lfs, lis = map(resh, (q, k, v, logf, logi))

    # PERF: remat — see mamba._chunked_ssm; keeps only the (C, n) carries
    # across chunks instead of the stacked intra-chunk gate matrices.
    @jax.checkpoint
    def scan_chunk(carry, inp):
        C0, n0 = carry                                  # (B,H,dh,dh),(B,H,dh)
        qc, kc, vc, lf, li = inp                        # (B,c,H,dh)... (B,c,H)
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        # cumulative log forget within chunk: F_t = sum_{s<=t} logf_s
        Fc = jnp.cumsum(lf, axis=1)                     # (B, c, H)
        tot = Fc[:, -1]                                 # (B, H)
        # inter-chunk contribution: q_t (prod f up to t) C0
        decay_q = jnp.exp(Fc)                           # (B, c, H)
        inter = jnp.einsum("bche,bhef->bchf", qc * decay_q[..., None], C0)
        inter_n = jnp.einsum("bche,bhe->bch", qc * decay_q[..., None], n0)
        # intra-chunk: weight(t, s) = exp(F_t - F_s + logi_s), s <= t
        w = Fc[:, :, None, :] - Fc[:, None, :, :] + li[:, None, :, :]
        idx = jnp.arange(c)
        causal = idx[:, None] >= idx[None, :]
        w = jnp.where(causal[None, :, :, None], w, -jnp.inf)
        a = jnp.exp(w)                                  # (B, c, c, H)
        scores = jnp.einsum("bche,bshe->bcsh", qc, kc) * a
        num = inter + jnp.einsum("bcsh,bshe->bche", scores, vc)
        # normalizer: q.n_t = q.(decay n0) + sum_s a(t,s) (q.k_s)
        den = jnp.abs(inter_n + jnp.sum(scores, axis=2))  # (B, c, H)
        y = num / jnp.maximum(den, 1.0)[..., None]       # (B, c, H, dh)
        # carry update
        decay_tot = jnp.exp(tot)                         # (B, H)
        gk = jnp.exp(tot[:, None] - Fc + li)             # (B, c, H)
        C1 = C0 * decay_tot[..., None, None] + jnp.einsum(
            "bche,bchf->bhef", kc * gk[..., None], vc)
        n1 = n0 * decay_tot[..., None] + jnp.sum(kc * gk[..., None], axis=1)
        return (C1, n1), y

    H = n_heads
    init = (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32))
    _, ys = lax.scan(scan_chunk, init, (qs, ks, vs, lfs, lis))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H * dh)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return constrain(y @ params["down"], "batch", "seq", None)


def init_mlstm_cache(create, batch: int, d_model: int, n_heads: int,
                     proj_factor: float):
    dp = _round8(int(d_model * proj_factor))
    dh = dp // n_heads
    return MLstmCache(
        c=create("cache_c", (batch, n_heads, dh, dh),
                 ("batch", "heads", None, None), init="zeros",
                 dtype=jnp.float32),
        n=create("cache_n", (batch, n_heads, dh),
                 ("batch", "heads", None), init="zeros", dtype=jnp.float32),
    )


def mlstm_decode_step(params, x, cache: MLstmCache, *, n_heads: int):
    B, one, D = x.shape
    q, k, v, logf, logi, z = _mlstm_qkvg(params, x, n_heads)
    qc = q[:, 0].astype(jnp.float32)                    # (B, H, dh)
    kc = k[:, 0].astype(jnp.float32)
    vc = v[:, 0].astype(jnp.float32)
    f = jnp.exp(logf[:, 0])[..., None]                  # (B, H, 1)
    i = jnp.exp(logi[:, 0])[..., None]
    C1 = cache.c * f[..., None] + i[..., None] * (
        kc[..., :, None] * vc[..., None, :])
    n1 = cache.n * f + i * kc
    num = jnp.einsum("bhe,bhef->bhf", qc, C1)
    den = jnp.abs(jnp.einsum("bhe,bhe->bh", qc, n1))
    y = (num / jnp.maximum(den, 1.0)[..., None]).reshape(B, 1, -1)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["down"], MLstmCache(c=C1, n=n1)


# ---------------------------------------------------------------- sLSTM ----

def slstm_params(create, d_model: int, n_heads: int, proj_factor: float):
    dh = d_model // n_heads
    del dh
    dp = _round8(int(d_model * proj_factor))
    return {
        # gates take x_t and recurrent h_{t-1} (block-diagonal per head
        # simplified to full d_model -> d_model maps)
        "w_gates": create("w_gates", (d_model, 4 * d_model),
                          ("embed", "mlp")),
        "r_gates": create("r_gates", (d_model, 4 * d_model),
                          ("embed", "mlp")),
        "b_gates": create("b_gates", (4 * d_model,), ("mlp",), init="zeros"),
        "up": create("up", (d_model, dp), ("embed", "mlp")),
        "down": create("down", (dp, d_model), ("mlp", "embed")),
    }


def _slstm_step(params, x_t, state: SLstmCache, n_heads: int):
    """x_t: (B, D) one timestep.  Exponential gating w/ max stabilizer."""
    B, D = x_t.shape
    h_prev = state.h.reshape(B, D)
    gates = (x_t @ params["w_gates"] + h_prev.astype(x_t.dtype)
             @ params["r_gates"]).astype(jnp.float32) + params["b_gates"]
    zi, ii, fi, oi = jnp.split(gates, 4, axis=-1)       # (B, D) each
    zi = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    logf = jax.nn.log_sigmoid(fi)

    shp = (B, n_heads, D // n_heads)
    zi, ii, logf, o = (t.reshape(shp) for t in (zi, ii, logf, o))

    m_new = jnp.maximum(logf + state.m, ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    c_new = f_g * state.c + i_g * zi
    n_new = f_g * state.n + i_g
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1e-6)
    return SLstmCache(c=c_new, n=n_new, h=h_new, m=m_new)


def slstm_block(params, x, *, n_heads: int):
    """Sequential sLSTM over (B, S, D) via lax.scan (inherently serial)."""
    B, S, D = x.shape
    init = init_slstm_state(B, D, n_heads)

    def step(state, x_t):
        new = _slstm_step(params, x_t, state, n_heads)
        return new, new.h.reshape(B, D)

    _, hs = lax.scan(step, init, jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)          # (B, S, D)
    y = jax.nn.silu(y @ params["up"])
    return constrain(y @ params["down"], "batch", "seq", None)


def init_slstm_state(batch: int, d_model: int, n_heads: int):
    shp = (batch, n_heads, d_model // n_heads)
    z = jnp.zeros(shp, jnp.float32)
    return SLstmCache(c=z, n=z, h=z, m=z)


def init_slstm_cache(create, batch: int, d_model: int, n_heads: int):
    shp = (batch, n_heads, d_model // n_heads)
    mk = lambda nm: create(nm, shp, ("batch", "heads", None), init="zeros",
                           dtype=jnp.float32)
    return SLstmCache(c=mk("cache_c"), n=mk("cache_n"), h=mk("cache_h"),
                      m=mk("cache_m"))


def slstm_decode_step(params, x, cache: SLstmCache, *, n_heads: int):
    B, one, D = x.shape
    new = _slstm_step(params, x[:, 0], cache, n_heads)
    y = new.h.reshape(B, 1, D).astype(x.dtype)
    y = jax.nn.silu(y @ params["up"])
    return y @ params["down"], new
