"""Encoder-decoder transformer (seamless-m4t family).

The speech frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, frames, d_model) from `input_specs()`.
The text decoder is a standard causal stack with cross-attention over the
encoder output; decode shapes lower the decoder's single-token step.

Layer stacks are homogeneous, so the scan-over-layers carries no
super-block structure (superblock = 1).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import layers, mlp
from repro.models.config import ModelConfig
from repro.models.transformer import _StackedCreator


def _enc_layer_params(create, cfg: ModelConfig):
    return {
        "ln1": layers.rmsnorm_params(create.scope("ln1"), cfg.d_model),
        "attn": attn.attention_params(
            create.scope("attn"), cfg.d_model, cfg.n_heads_phys,
            cfg.n_kv_phys, cfg.head_dim, cfg.qkv_bias),
        "ln2": layers.rmsnorm_params(create.scope("ln2"), cfg.d_model),
        "ffn": mlp.mlp_params(create.scope("ffn"), cfg.d_model, cfg.d_ff),
    }


def _dec_layer_params(create, cfg: ModelConfig):
    return {
        "ln1": layers.rmsnorm_params(create.scope("ln1"), cfg.d_model),
        "self": attn.attention_params(
            create.scope("self"), cfg.d_model, cfg.n_heads_phys,
            cfg.n_kv_phys, cfg.head_dim, cfg.qkv_bias),
        "lnx": layers.rmsnorm_params(create.scope("lnx"), cfg.d_model),
        "cross": attn.cross_attention_params(
            create.scope("cross"), cfg.d_model, cfg.n_heads_phys,
            cfg.n_kv_phys, cfg.head_dim),
        "ln2": layers.rmsnorm_params(create.scope("ln2"), cfg.d_model),
        "ffn": mlp.mlp_params(create.scope("ffn"), cfg.d_model, cfg.d_ff),
    }


def init_params(create, cfg: ModelConfig):
    enc_sc = _StackedCreator(create.scope("encoder"), cfg.n_enc_layers)
    dec_sc = _StackedCreator(create.scope("decoder"), cfg.n_layers)
    p: dict[str, Any] = {
        "embed": layers.embedding_params(create.scope("embed"), cfg.vocab,
                                         cfg.d_model),
        "enc_blocks": _enc_layer_params(enc_sc, cfg),
        "enc_ln": layers.rmsnorm_params(create.scope("enc_ln"), cfg.d_model),
        "dec_blocks": _dec_layer_params(dec_sc, cfg),
        "final_ln": layers.rmsnorm_params(create.scope("final_ln"),
                                          cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = {"table": create.scope("lm_head")(
            "table", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
            init="normal")}
    return p


def encode(params, cfg: ModelConfig, frames, remat: bool = True):
    """frames: (B, F, D) stub frontend embeddings -> encoder states."""
    B, F, D = frames.shape
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None],
                                 (B, F))
    x = frames

    def body(x, lp):
        h = layers.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        x = x + attn.causal_attention(
            lp["attn"], h, positions, n_heads=cfg.n_heads_phys,
            n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, causal=False,
            head_mask=attn.make_head_mask(cfg))
        h = layers.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp.mlp(lp["ffn"], h)
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(fn, x, params["enc_blocks"])
    return layers.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def _dec_layer(lp, cfg, x, positions, enc_out, cache_j=None, mode="train"):
    h = layers.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if mode == "train":
        y = attn.causal_attention(
            lp["self"], h, positions, n_heads=cfg.n_heads_phys,
            n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, head_mask=attn.make_head_mask(cfg))
        new = None
    elif mode == "prefill":
        y, new = attn.prefill_into_cache(
            lp["self"], h, positions, cache_j, n_heads=cfg.n_heads_phys,
            n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, head_mask=attn.make_head_mask(cfg))
    else:
        y, new = attn.decode_attention(
            lp["self"], h, cache_j, n_heads=cfg.n_heads_phys,
            n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, head_mask=attn.make_head_mask(cfg))
    x = x + y
    h = layers.rmsnorm(lp["lnx"], x, cfg.norm_eps)
    x = x + attn.cross_attention(lp["cross"], h, enc_out,
                                 n_heads=cfg.n_heads_phys,
                                 n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
                                 head_mask=attn.make_head_mask(cfg))
    h = layers.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    x = x + mlp.mlp(lp["ffn"], h)
    return x, new


def forward(params, cfg: ModelConfig, tokens, frames, remat: bool = True):
    """Teacher-forced decode over `tokens` given encoder `frames`."""
    enc_out = encode(params, cfg, frames, remat=remat)
    x = layers.embed(params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))

    def body(x, lp):
        x, _ = _dec_layer(lp, cfg, x, positions, enc_out, mode="train")
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = lax.scan(fn, x, params["dec_blocks"])
    x = layers.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    return layers.unembed({}, x, table=table), jnp.float32(0.0)


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = True):
    logits, aux = forward(params, cfg, batch["tokens"], batch["frames"],
                          remat=remat)
    ce = layers.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce, "aux": aux}


def init_cache(create, cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16):
    sc = _StackedCreator(create.scope("cache"), cfg.n_layers)
    return {
        "self": attn.init_cache(sc, batch, s_max, cfg.n_kv_phys,
                                cfg.head_dim, dtype=dtype),
        "enc_out": create.scope("cache")(
            "enc_out", (batch, cfg.frontend_frames, cfg.d_model),
            ("batch", None, None), init="zeros", dtype=dtype),
    }


def prefill(params, cfg: ModelConfig, tokens, frames, cache):
    enc_out = encode(params, cfg, frames, remat=False).astype(
        cache["enc_out"].dtype)
    x = layers.embed(params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))

    def body(x, inp):
        lp, cache_j = inp
        x, new = _dec_layer(lp, cfg, x, positions, enc_out, cache_j,
                            mode="prefill")
        return x, new

    x, new_self = lax.scan(body, x, (params["dec_blocks"], cache["self"]))
    x = layers.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed({}, x[:, -1:], table=table)[:, 0]
    return logits, {"self": new_self, "enc_out": enc_out}


def decode_step(params, cfg: ModelConfig, token, cache):
    x = layers.embed(params["embed"], token[:, None])
    enc_out = cache["enc_out"]

    def body(x, inp):
        lp, cache_j = inp
        x, new = _dec_layer(lp, cfg, x, None, enc_out, cache_j,
                            mode="decode")
        return x, new

    x, new_self = lax.scan(body, x, (params["dec_blocks"], cache["self"]))
    x = layers.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed({}, x, table=table)[:, 0]
    return logits, {"self": new_self, "enc_out": enc_out}
