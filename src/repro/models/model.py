"""Unified model API: one object per architecture, family-dispatched.

`serve_step` is where the paper's technique is first-class in the LM stack:
the decode logits stay vocab-sharded over the `model` mesh axis and the
next token comes from `core.topk.topk_sample` — the distributed-selection
sampler (DESIGN.md Section 3).  The dry-run lowers exactly this graph, so
the roofline's collective term includes the paper's O(log k)-scalar rounds
instead of a vocab-sized all-gather.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import topk as topk_mod
from repro.models import encdec, transformer
from repro.models.config import InputShape, ModelConfig
from repro.models.creator import InitCreator, ShapeCreator, SpecCreator
from repro.models import sharding as shd
from repro.parallel.compat import shard_map


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig

    # ---- parameters -------------------------------------------------------
    def init_params(self, key, dtype=jnp.float32):
        return self._init(InitCreator(key, dtype=dtype))

    def param_specs(self):
        return self._init(SpecCreator())

    def param_shapes(self, mesh=None, dtype=jnp.bfloat16):
        return self._init(ShapeCreator(dtype=dtype, mesh=mesh))

    def _init(self, create):
        if self.cfg.is_encdec:
            return encdec.init_params(create, self.cfg)
        return transformer.init_params(create, self.cfg)

    # ---- steps ------------------------------------------------------------
    def loss_fn(self, params, batch, remat: bool = True):
        if self.cfg.is_encdec:
            return encdec.loss_fn(params, self.cfg, batch, remat=remat)
        return transformer.loss_fn(params, self.cfg, batch, remat=remat)

    def forward(self, params, batch):
        if self.cfg.is_encdec:
            return encdec.forward(params, self.cfg, batch["tokens"],
                                  batch["frames"])
        return transformer.forward(params, self.cfg, batch["tokens"],
                                   batch.get("prefix_embeds"))

    def prefill(self, params, batch, cache):
        if self.cfg.is_encdec:
            return encdec.prefill(params, self.cfg, batch["tokens"],
                                  batch["frames"], cache)
        return transformer.prefill(params, self.cfg, batch["tokens"], cache,
                                   batch.get("prefix_embeds"))

    def decode_step(self, params, token, cache):
        if self.cfg.is_encdec:
            return encdec.decode_step(params, self.cfg, token, cache)
        return transformer.decode_step(params, self.cfg, token, cache)

    def serve_step(self, params, token, cache, key, *, mesh=None,
                   top_k: int = 50, temperature: float = 0.8,
                   sampler: str = "selection", num_pivots: int = 1):
        """decode_step + the paper's distributed top-k sampler.

        Under a mesh, the (B, V) logits stay model-sharded and the sampler
        runs the distributed-selection pipeline over the vocab shards; on a
        single device it degrades to plain top-k sampling.
        """
        logits, new_cache = self.decode_step(params, token, cache)
        if mesh is None or "model" not in mesh.axis_names:
            scaled, idx = jax.lax.top_k(logits, top_k)
            choice = jax.random.categorical(
                key, scaled / jnp.maximum(temperature, 1e-6), axis=-1)
            nxt = jnp.take_along_axis(idx, choice[..., None], -1)[..., 0]
            return nxt.astype(jnp.int32), new_cache

        # batch axes: follow the current sharding rules, keep only mesh axes
        # that evenly divide the batch (decode batches can be as small as 1).
        rule = shd.current_rules().batch
        rule = rule if isinstance(rule, tuple) else (rule,)
        B, V = logits.shape
        kept, prod = [], 1
        for a in rule:
            n = dict(mesh.shape).get(a, 0)
            if n and B % (prod * n) == 0:
                kept.append(a)
                prod *= n
        bspec = tuple(kept) if kept else None

        # vocab must tile the model axis inside shard_map (no GSPMD padding
        # there): pad with -inf logits, which can never win a top-k slot
        # (49155- and 256206-sized vocabs are not 16-divisible).
        mdl = dict(mesh.shape)["model"]
        pad = (-V) % mdl
        if pad:
            logits = jnp.pad(logits, ((0, 0), (0, pad)),
                             constant_values=-jnp.inf)
        fn = functools.partial(
            topk_mod.topk_sample, k=top_k, temperature=temperature,
            axis_name="model", method=sampler, num_pivots=num_pivots)

        sampled = shard_map(
            lambda lg, kk: fn(lg, key=kk),
            mesh=mesh,
            in_specs=(P(bspec, "model"), P()),
            out_specs=P(bspec),
            check_vma=False,
        )(logits, key)
        return sampled.astype(jnp.int32), new_cache

    # ---- caches -----------------------------------------------------------
    def init_cache(self, key, batch: int, s_max: int, dtype=jnp.bfloat16):
        return self._cache(InitCreator(key, dtype=dtype), batch, s_max,
                           dtype)

    def cache_specs(self, batch: int, s_max: int):
        return self._cache(SpecCreator(), batch, s_max, jnp.bfloat16)

    def cache_shapes(self, batch: int, s_max: int, mesh=None,
                     dtype=jnp.bfloat16):
        return self._cache(ShapeCreator(dtype=dtype, mesh=mesh), batch,
                           s_max, dtype)

    def _cache(self, create, batch, s_max, dtype):
        if self.cfg.is_encdec:
            return encdec.init_cache(create, self.cfg, batch, s_max, dtype)
        return transformer.init_cache(create, self.cfg, batch, s_max, dtype)

    # ---- input specs (ShapeDtypeStructs for the dry-run) --------------------
    def input_specs(self, shape: InputShape, mesh=None,
                    dtype=jnp.bfloat16) -> dict[str, Any]:
        """Stand-ins for every model input of the given (arch x shape) cell.

        Weak-type-correct, shardable, no device allocation.  Modality
        frontends are stubs: precomputed frame/patch embeddings appear here
        directly (the assignment's input_specs contract).
        """
        cfg = self.cfg
        gb, S = shape.global_batch, shape.seq_len

        def arr(shp, dt, *axes):
            if mesh is not None:
                ps = shd.divisible(shd.spec(*axes), shp, mesh)
                ns = jax.sharding.NamedSharding(mesh, ps)
                return jax.ShapeDtypeStruct(shp, dt, sharding=ns)
            return jax.ShapeDtypeStruct(shp, dt)

        if shape.kind == "decode":
            return {"token": arr((gb,), jnp.int32, "batch")}

        specs: dict[str, Any] = {}
        s_text = S
        if cfg.family == "vlm":
            s_text = S - cfg.num_prefix_embeds
            specs["prefix_embeds"] = arr(
                (gb, cfg.num_prefix_embeds, cfg.d_model), dtype,
                "batch", None, None)
        if cfg.is_encdec:
            specs["frames"] = arr((gb, cfg.frontend_frames, cfg.d_model),
                                  dtype, "batch", None, None)
        specs["tokens"] = arr((gb, s_text), jnp.int32, "batch", None)
        if shape.kind == "train":
            specs["labels"] = arr((gb, s_text), jnp.int32, "batch", None)
        return specs


def build_model(cfg: ModelConfig) -> ModelApi:
    return ModelApi(cfg=cfg)
