"""Model stack: configs, layers, families, unified ModelApi."""

from repro.models.config import (ModelConfig, InputShape, ALL_SHAPES,
                                 TRAIN_4K, PREFILL_32K, DECODE_32K,
                                 LONG_500K, shapes_for, skipped_shapes_for)
from repro.models.model import ModelApi, build_model

__all__ = [
    "ModelConfig", "InputShape", "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "shapes_for", "skipped_shapes_for",
    "ModelApi", "build_model",
]
