"""Logical-axis sharding: one rule table maps every tensor dim to mesh axes.

MaxText-style: model code annotates tensors with *logical* axis names; the
rule table (swappable per experiment — the long-context cells override
``kv_seq``) resolves them to mesh axes.  GSPMD propagates the rest.

Mesh axes (launch/mesh.py):
  pod   — data parallelism across pods (multi-pod mesh only)
  data  — FSDP: batch AND parameter/optimizer sharding (ZeRO-3 style)
  model — tensor/expert parallelism: heads, d_ff, vocab, experts

Non-divisible cases (40 heads / 16, 40 experts / 16) rely on GSPMD padding;
the waste shows up in the roofline's MODEL_FLOPS / HLO_FLOPs ratio and is a
recorded hillclimb lever (EXPERIMENTS.md Section Perf).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: tuple | str | None = ("pod", "data")
    seq: Optional[str] = None            # activation sequence axis
    kv_seq: Optional[str] = None         # KV-cache sequence axis ("data" for
                                         # the long-context cells: SP decode)
    embed: Optional[str] = "data"        # parameter d_model axis (FSDP)
    heads: Optional[str] = "model"
    qkv: Optional[str] = "model"         # fused (head, head_dim) param axis
    mlp: Optional[str] = "model"         # d_ff
    vocab: Optional[str] = "model"
    experts: Optional[str] = "model"
    expert_cap: Optional[str] = None
    stack: Optional[str] = None          # stacked-layer leading axis
    none: Optional[str] = None


_CURRENT = Rules()


def current_rules() -> Rules:
    return _CURRENT


@contextlib.contextmanager
def use_rules(rules: Rules):
    global _CURRENT
    prev, _CURRENT = _CURRENT, rules
    try:
        yield
    finally:
        _CURRENT = prev


def _mesh_axis_names():
    from repro.parallel.compat import ambient_mesh_axis_names
    return ambient_mesh_axis_names()


def spec(*logical_axes: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules.

    Mesh axes referenced by the rules but absent from the active mesh are
    dropped (e.g. "pod" on the single-pod mesh), so one rule table serves
    every mesh shape.
    """
    r = _CURRENT
    names = _mesh_axis_names()
    out = []
    for ax in logical_axes:
        resolved = None if ax is None else getattr(r, ax)
        if names is not None and resolved is not None:
            if isinstance(resolved, tuple):
                resolved = tuple(a for a in resolved if a in names) or None
                if resolved is not None and len(resolved) == 1:
                    # 1-tuples and bare names are distinct to old-jax
                    # PartitionSpec equality; normalize to the bare name.
                    resolved = resolved[0]
            elif resolved not in names:
                resolved = None
        out.append(resolved)
    return P(*out)


def divisible(pspec: P, shape, mesh) -> P:
    """Drop mesh axes that do not evenly divide their dimension.

    Input/output placements (ShapeDtypeStruct shardings, device_put) must
    tile evenly — unlike internal with_sharding_constraint, where GSPMD
    pads.  Where a dim is not divisible (40 heads / 16, batch 1, stacked
    layer counts) the offending axes are dropped: the tensor arrives
    replicated on those axes and the first internal constraint reshards it.
    """
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if hasattr(
        mesh, "axis_sizes") else {k: v for k, v in mesh.shape.items()}
    out = []
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            n = sizes.get(a, 1)
            if dim % (prod * n) == 0:
                kept.append(a)
                prod *= n
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    return P(*out)


def batch_shards() -> int:
    """Number of mesh shards the batch ("data"/"pod") axes span under the
    active mesh — the MoE dispatch group count (moe.py)."""
    names = _mesh_axis_names()
    if not names:
        return 1
    from repro.parallel.compat import ambient_mesh_axis_sizes
    sizes = ambient_mesh_axis_sizes()
    if sizes is None:
        return 1
    rule = _CURRENT.batch
    axes = rule if isinstance(rule, tuple) else (rule,)
    out = 1
    for a in axes:
        if a in names:
            out *= sizes.get(a, 1)
    return max(out, 1)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint if we're under a mesh, else a no-op.

    Lets the same model code run in single-device tests and under the
    production mesh.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        empty = mesh.empty if mesh is not None else True
    except Exception:
        empty = True
    if empty:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical_axes))
