"""Mamba (S6) block — selective state-space layer for the hybrid family.

TPU adaptation (DESIGN.md hardware-adaptation): the CUDA selective-scan
kernel streams the (d_inner, d_state) state through SRAM token by token.
The TPU-native equivalent is a *chunked associative scan*: the sequence is
cut into chunks of `chunk` tokens processed by `lax.associative_scan`
(log-depth, VPU-friendly), with the inter-chunk recurrence carried by a
`lax.scan`.  Live memory is (B, chunk, d_inner_local, d_state) — with
d_inner model-sharded this stays in the tens of MB at jamba scale, the
VMEM/HBM analogue of the SRAM streaming trick.

Decode is the exact one-step recurrence on a (B, d_inner, d_state) cache —
O(1) per token, which is what qualifies jamba for the long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.sharding import constrain

DEFAULT_CHUNK = 16  # bounds in-chunk decay so the log-space scan's exp
                    # clip stays inactive (see _chunked_ssm, iteration 3)


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, d_conv - 1, d_inner) — depthwise conv tail
    ssm: jax.Array    # (B, d_inner, d_state) — recurrent state, f32


def mamba_params(create, d_model: int, *, expand: int, d_state: int,
                 d_conv: int):
    d_inner = expand * d_model
    dt_rank = max(16, d_model // 16)
    return {
        "in_proj": create("in_proj", (d_model, 2 * d_inner),
                          ("embed", "mlp")),
        "conv_w": create("conv_w", (d_conv, d_inner), (None, "mlp")),
        "conv_b": create("conv_b", (d_inner,), ("mlp",), init="zeros"),
        "x_proj": create("x_proj", (d_inner, dt_rank + 2 * d_state),
                         ("mlp", None)),
        "dt_proj": create("dt_proj", (dt_rank, d_inner), (None, "mlp")),
        "dt_bias": create("dt_bias", (d_inner,), ("mlp",), init="dt_bias"),
        "a_log": create("a_log", (d_inner, d_state), ("mlp", None),
                        init="mamba_a", dtype=jnp.float32),
        "d_skip": create("d_skip", (d_inner,), ("mlp",), init="ones",
                         dtype=jnp.float32),
        "out_proj": create("out_proj", (d_inner, d_model),
                           ("mlp", "embed")),
    }


def _ssm_inputs(params, xs, *, d_state: int, log_space: bool = False):
    """xs: (..., d_inner) post-conv activations -> (dA | logdA, dBx, C)."""
    dt_rank = params["dt_proj"].shape[0]
    proj = xs @ params["x_proj"]                       # (..., r + 2*ds)
    dt = proj[..., :dt_rank] @ params["dt_proj"] + params["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))       # (..., d_inner)
    Bm = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + d_state:].astype(jnp.float32)
    A = -jnp.exp(params["a_log"])                      # (d_inner, d_state)
    logdA = dt[..., None] * A                          # (..., d_inner, ds)
    dBx = (dt * xs.astype(jnp.float32))[..., None] * Bm[..., None, :]
    if log_space:
        return logdA, dBx, Cm
    return jnp.exp(logdA), dBx, Cm


def _conv1d(params, x, tail=None):
    """Depthwise causal conv over (B, S, d_inner); `tail` is the cached
    (B, d_conv-1, d_inner) prefix for decode continuity."""
    d_conv = params["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], d_conv - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * params["conv_w"][i]
              for i in range(d_conv))
    return out + params["conv_b"], xp[:, -(d_conv - 1):, :]


def _chunked_ssm(params, xs, *, d_state: int, chunk: int):
    """Selective scan over (B, S, d_inner) post-conv activations.

    PERF (EXPERIMENTS.md Section Perf, jamba iteration 1): the (dA, dBx)
    terms have shape (B, S, d_inner, d_state) — 16x the activation size.
    Computing them for the full sequence before the chunk loop materializes
    multi-TB of HBM traffic per step at jamba scale; instead the chunk scan
    receives raw xs chunks and derives its (B, chunk, d_inner, d_state)
    terms *inside* the loop body, so they never exist at full length.
    Returns (y (B, S, d_inner) f32, final state (B, d_inner, d_state)).
    """
    B, S, d_inner = xs.shape
    c = chunk if S % chunk == 0 else S
    n_chunks = S // c

    # PERF iteration 2: rematerialize the chunk body.  Without this the
    # backward pass keeps every chunk's (B, c, dI, dS) cumulative-product
    # tensors stacked across all chunks (the scan's saved residuals) —
    # ~270 MB x 5 tensors per mamba layer at jamba scale, blowing the
    # 16 GB HBM budget and dominating HBM traffic.  Recomputing the chunk
    # body in backward keeps only the (B, dI, dS) carries.
    #
    # PERF iteration 3: the log-depth associative scan expands into ~100
    # fused passes over the (c, dI, dS) working set (fwd + transpose).
    # The in-chunk scan is instead computed in LOG SPACE with two cumsums:
    #     L_t   = cumsum(log dA)                (log decay from chunk start)
    #     h_t   = exp(L_t) * (h0 + cumsum(exp(-L_s) dBx_s))
    # ~8 passes over the working set.  exp(-L) is clipped at e^CLIP; with
    # chunk <= 16 the accumulated in-chunk decay stays within the clip
    # range for any plausible dt, so the clip is inactive in practice
    # (validated against the associative-scan oracle in tests).
    CLIP = 35.0

    @jax.checkpoint
    def scan_chunk(h, cxs):
        logdA, cdBx, cC = _ssm_inputs(params, cxs, d_state=d_state,
                                      log_space=True)
        L = jnp.cumsum(logdA, axis=1)                  # (B, c, dI, ds) <= 0
        w = jnp.exp(jnp.minimum(-L, CLIP)) * cdBx
        hs = jnp.exp(L) * (h[:, None] + jnp.cumsum(w, axis=1))
        y = jnp.einsum("bcds,bcs->bcd", hs, cC)
        return hs[:, -1], y

    xs_c = jnp.moveaxis(xs.reshape(B, n_chunks, c, d_inner), 1, 0)
    h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
    h_fin, ys = lax.scan(scan_chunk, h0, xs_c)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, d_inner), h_fin


def mamba_block(params, x, *, d_state: int, chunk: int = DEFAULT_CHUNK):
    """Train/prefill forward: x (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                  # (B, S, d_inner)
    xs = constrain(xs, "batch", "seq", "mlp")
    xs, _ = _conv1d(params, xs)
    xs = jax.nn.silu(xs)

    y, _ = _chunked_ssm(params, xs, d_state=d_state, chunk=chunk)
    y = y + params["d_skip"] * xs.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = constrain(y, "batch", "seq", "mlp")
    return constrain(y @ params["out_proj"], "batch", "seq", None)


def init_mamba_cache(create, batch: int, d_model: int, *, expand: int,
                     d_state: int, d_conv: int, dtype=jnp.bfloat16):
    d_inner = expand * d_model
    return MambaCache(
        conv=create("cache_conv", (batch, d_conv - 1, d_inner),
                    ("batch", None, "mlp"), init="zeros", dtype=dtype),
        ssm=create("cache_ssm", (batch, d_inner, d_state),
                   ("batch", "mlp", None), init="zeros", dtype=jnp.float32),
    )


def mamba_decode_step(params, x, cache: MambaCache, *, d_state: int):
    """x: (B, 1, D) one token; exact recurrence update."""
    B, one, D = x.shape
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, new_tail = _conv1d(params, xs, tail=cache.conv)
    xs = jax.nn.silu(xs)

    dA, dBx, Cm = _ssm_inputs(params, xs, d_state=d_state)  # (B,1,dI,ds)
    h = dA[:, 0] * cache.ssm + dBx[:, 0]                    # (B, dI, ds)
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])[:, None, :]
    y = y + params["d_skip"] * xs.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    return out, MambaCache(conv=new_tail.astype(cache.conv.dtype), ssm=h)


def mamba_prefill(params, x, cache: MambaCache, *, d_state: int,
                  chunk: int = DEFAULT_CHUNK):
    """Prefill: full forward + final state into the cache."""
    B, S, D = x.shape
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, tail = _conv1d(params, xs)
    xs = jax.nn.silu(xs)

    y, h_fin = _chunked_ssm(params, xs, d_state=d_state, chunk=chunk)
    y = y + params["d_skip"] * xs.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    return out, MambaCache(conv=tail.astype(cache.conv.dtype), ssm=h_fin)
