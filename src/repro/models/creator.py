"""Parameter creators: one module definition yields params, specs, or shapes.

Model modules declare parameters through a `Creator` callback:

    w = create("wq", (d_model, n_heads * head_dim), ("embed", "qkv"),
               init="fan_in")

Running the same definition with different creators produces
  * real initialized arrays            (InitCreator — training / tests)
  * jax.sharding PartitionSpec trees   (SpecCreator — pjit in/out shardings)
  * jax.ShapeDtypeStruct trees         (ShapeCreator — the multi-pod dry-run
    lowers the 398B-parameter configs without allocating a byte)

so init/spec/shape can never drift apart.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import sharding


class InitCreator:
    """Materializes parameters; deterministic per-path key derivation."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self._dtype = dtype
        self._path: list[str] = []

    def scope(self, name: str):
        creator = InitCreator.__new__(InitCreator)
        creator._key = self._key
        creator._dtype = self._dtype
        creator._path = self._path + [name]
        return creator

    def _key_for(self, name: str) -> jax.Array:
        k = self._key
        for part in self._path + [name]:
            k = jax.random.fold_in(k, _stable_hash(part))
        return k

    def __call__(self, name: str, shape, axes, init: str = "fan_in",
                 dtype=None):
        dtype = dtype or self._dtype
        key = self._key_for(name)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            return (0.02 * jax.random.normal(key, shape)).astype(dtype)
        if init == "fan_in":
            # Exclude a leading super-block "stack" axis from fan-in so
            # stacked layers are scaled like their unstacked counterparts.
            dims = shape[1:] if (axes and axes[0] == "stack") else shape
            fan_in = dims[0] if len(dims) == 1 else math.prod(dims[:-1])
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            return (scale * jax.random.normal(key, shape)).astype(dtype)
        if init == "mamba_a":
            # S4/Mamba A init: -log-spaced negative reals, stored as log(-A);
            # shape (..., d_inner, d_state).
            d_state = shape[-1]
            a = jnp.broadcast_to(
                jnp.arange(1, d_state + 1, dtype=jnp.float32), shape)
            return jnp.log(a).astype(dtype)
        if init == "dt_bias":
            # softplus^-1 of U[1e-3, 1e-1] — mamba dt init
            u = jax.random.uniform(key, shape, minval=math.log(1e-3),
                                   maxval=math.log(1e-1))
            dt = jnp.exp(u)
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
        raise ValueError(f"unknown init {init!r}")


class SpecCreator:
    """Produces PartitionSpecs under the current sharding rules."""

    def scope(self, name: str):
        return self

    def __call__(self, name: str, shape, axes, init: str = "fan_in",
                 dtype=None):
        assert len(axes) == len(shape), (name, shape, axes)
        return sharding.spec(*axes)


class ShapeCreator:
    """Produces ShapeDtypeStructs (+sharding) — allocation-free dry-run."""

    def __init__(self, dtype=jnp.bfloat16, mesh=None):
        self._dtype = dtype
        self._mesh = mesh

    def scope(self, name: str):
        return self

    def __call__(self, name: str, shape, axes, init: str = "fan_in",
                 dtype=None):
        dtype = dtype or self._dtype
        if self._mesh is not None:
            ps = sharding.divisible(sharding.spec(*axes), shape, self._mesh)
            ns = jax.sharding.NamedSharding(self._mesh, ps)
            return jax.ShapeDtypeStruct(shape, dtype, sharding=ns)
        return jax.ShapeDtypeStruct(shape, dtype)


Creator = Callable


def _stable_hash(s: str) -> int:
    """Deterministic across processes (hash() is salted per process)."""
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 % (1 << 32)
    return h
