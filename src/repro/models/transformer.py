"""Decoder-only model assembly: dense / moe / hybrid / vlm / ssm families.

Compile economy + pipeline-friendliness: layers are grouped into repeating
**super-blocks** — the smallest period of the layer pattern (dense/moe: 1
layer; jamba: 8 = 7 Mamba + 1 attention with MoE on even positions; xlstm:
2 = mLSTM + sLSTM).  Parameters are stacked over super-blocks and the stack
is driven by `lax.scan` with rematerialization, so the HLO contains each
distinct layer body once regardless of depth (jamba's 72 layers compile as
one 8-layer body scanned 9 times).

Caches mirror the parameter stacking: a decode step scans over
(param-slice, cache-slice) pairs and emits updated cache slices.
"""

from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import layers, mamba, mlp, moe, xlstm
from repro.models.config import ModelConfig
from repro.models.sharding import constrain


# ---- super-block structure ---------------------------------------------------

def superblock_size(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return cfg.slstm_period
    p = 1
    if cfg.attn_period > 0:
        p = math.lcm(p, cfg.attn_period)
    if cfg.n_experts > 0:
        p = math.lcm(p, cfg.moe_period)
    return p


def n_superblocks(cfg: ModelConfig) -> int:
    p = superblock_size(cfg)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


class _StackedCreator:
    """Wraps a creator to prepend the super-block stack dimension."""

    def __init__(self, create, n_stack: int):
        self._c = create
        self._n = n_stack

    def scope(self, name):
        return _StackedCreator(self._c.scope(name), self._n)

    def __call__(self, name, shape, axes, init="fan_in", dtype=None):
        return self._c(name, (self._n, *shape), ("stack", *axes), init=init,
                       dtype=dtype)


def _sub_params(create, cfg: ModelConfig, j: int):
    """Parameters of position j inside a super-block."""
    c = create.scope(f"sub{j}")
    d = cfg.d_model
    sub: dict[str, Any] = {}
    if cfg.family == "ssm":
        if cfg.is_slstm_layer(j):
            sub["slstm"] = xlstm.slstm_params(
                c.scope("slstm"), d, cfg.n_heads, cfg.slstm_proj_factor)
        else:
            sub["mlstm"] = xlstm.mlstm_params(
                c.scope("mlstm"), d, cfg.n_heads, cfg.mlstm_proj_factor)
        sub["ln"] = layers.rmsnorm_params(c.scope("ln"), d)
        return sub

    sub["ln1"] = layers.rmsnorm_params(c.scope("ln1"), d)
    if cfg.is_attn_layer(j):
        sub["attn"] = attn.attention_params(
            c.scope("attn"), d, cfg.n_heads_phys, cfg.n_kv_phys,
            cfg.head_dim, cfg.qkv_bias)
    else:
        sub["mamba"] = mamba.mamba_params(
            c.scope("mamba"), d, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv)
    if cfg.d_ff > 0:
        sub["ln2"] = layers.rmsnorm_params(c.scope("ln2"), d)
        if cfg.is_moe_layer(j):
            sub["moe"] = moe.moe_params(c.scope("moe"), d, cfg.d_ff,
                                        cfg.n_experts,
                                        n_experts_phys=cfg.n_experts_phys)
        else:
            sub["ffn"] = mlp.mlp_params(c.scope("ffn"), d, cfg.d_ff)
    return sub


def init_params(create, cfg: ModelConfig):
    """Full parameter tree via any creator (init / spec / shape)."""
    p: dict[str, Any] = {
        "embed": layers.embedding_params(create.scope("embed"), cfg.vocab,
                                         cfg.d_model),
        "final_ln": layers.rmsnorm_params(create.scope("final_ln"),
                                          cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = {
            "table": create.scope("lm_head")(
                "table", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                init="normal")}
    sc = _StackedCreator(create.scope("blocks"), n_superblocks(cfg))
    p["blocks"] = {f"sub{j}": _sub_params(sc, cfg, j)
                   for j in range(superblock_size(cfg))}
    return p


# ---- sub-layer application ---------------------------------------------------

def _apply_sub_train(sub, cfg: ModelConfig, j: int, x, positions):
    """One layer (train/prefill without cache); returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        h = layers.rmsnorm(sub["ln"], x, cfg.norm_eps)
        if cfg.is_slstm_layer(j):
            x = x + xlstm.slstm_block(sub["slstm"], h, n_heads=cfg.n_heads)
        else:
            x = x + xlstm.mlstm_block(sub["mlstm"], h, n_heads=cfg.n_heads)
        return x, aux

    h = layers.rmsnorm(sub["ln1"], x, cfg.norm_eps)
    if cfg.is_attn_layer(j):
        x = x + attn.causal_attention(
            sub["attn"], h, positions, n_heads=cfg.n_heads_phys,
            n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, head_mask=attn.make_head_mask(cfg))
    else:
        x = x + mamba.mamba_block(sub["mamba"], h, d_state=cfg.mamba_d_state)
    if cfg.d_ff > 0:
        h = layers.rmsnorm(sub["ln2"], x, cfg.norm_eps)
        if cfg.is_moe_layer(j):
            y, aux = moe.moe_ffn(sub["moe"], h, n_experts=cfg.n_experts,
                                 top_k=cfg.moe_top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 n_experts_phys=cfg.n_experts_phys)
            x = x + y
        else:
            x = x + mlp.mlp(sub["ffn"], h)
    return x, aux


def _init_sub_cache(create, cfg: ModelConfig, j: int, batch: int,
                    s_max: int, dtype):
    c = create.scope(f"sub{j}")
    if cfg.family == "ssm":
        if cfg.is_slstm_layer(j):
            return xlstm.init_slstm_cache(c, batch, cfg.d_model, cfg.n_heads)
        return xlstm.init_mlstm_cache(c, batch, cfg.d_model, cfg.n_heads,
                                      cfg.mlstm_proj_factor)
    if cfg.is_attn_layer(j):
        return attn.init_cache(c, batch, s_max, cfg.n_kv_phys, cfg.head_dim,
                               dtype=dtype)
    return mamba.init_mamba_cache(c, batch, cfg.d_model,
                                  expand=cfg.mamba_expand,
                                  d_state=cfg.mamba_d_state,
                                  d_conv=cfg.mamba_d_conv, dtype=dtype)


def init_cache(create, cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16):
    sc = _StackedCreator(create.scope("cache"), n_superblocks(cfg))
    return {f"sub{j}": _init_sub_cache(sc, cfg, j, batch, s_max, dtype)
            for j in range(superblock_size(cfg))}


def _apply_sub_step(sub, cache_j, cfg: ModelConfig, j: int, x, *,
                    mode: str, positions=None):
    """One layer in cached mode: mode in {"prefill", "decode"}."""
    if cfg.family == "ssm":
        h = layers.rmsnorm(sub["ln"], x, cfg.norm_eps)
        if cfg.is_slstm_layer(j):
            if mode == "decode":
                y, new = xlstm.slstm_decode_step(sub["slstm"], h,
                                                 cache_j, n_heads=cfg.n_heads)
            else:
                # prefill: run the scan, rebuild final state by stepping is
                # equivalent; reuse block then recompute final state cheaply
                y, new = _slstm_prefill(sub["slstm"], h, cache_j, cfg)
            return x + y, new
        if mode == "decode":
            y, new = xlstm.mlstm_decode_step(sub["mlstm"], h, cache_j,
                                             n_heads=cfg.n_heads)
        else:
            y, new = _mlstm_prefill(sub["mlstm"], h, cache_j, cfg)
        return x + y, new

    h = layers.rmsnorm(sub["ln1"], x, cfg.norm_eps)
    if cfg.is_attn_layer(j):
        if mode == "decode":
            y, new = attn.decode_attention(
                sub["attn"], h, cache_j, n_heads=cfg.n_heads_phys,
                n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta,
                head_mask=attn.make_head_mask(cfg))
        else:
            y, new = attn.prefill_into_cache(
                sub["attn"], h, positions, cache_j, n_heads=cfg.n_heads_phys,
                n_kv=cfg.n_kv_phys, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta,
                head_mask=attn.make_head_mask(cfg))
        x = x + y
    else:
        if mode == "decode":
            y, new = mamba.mamba_decode_step(sub["mamba"], h, cache_j,
                                             d_state=cfg.mamba_d_state)
        else:
            y, new = mamba.mamba_prefill(sub["mamba"], h, cache_j,
                                         d_state=cfg.mamba_d_state)
        x = x + y
    if cfg.d_ff > 0:
        h = layers.rmsnorm(sub["ln2"], x, cfg.norm_eps)
        if cfg.is_moe_layer(j):
            y, _ = moe.moe_ffn(sub["moe"], h, n_experts=cfg.n_experts,
                               top_k=cfg.moe_top_k,
                               capacity_factor=cfg.capacity_factor,
                               n_experts_phys=cfg.n_experts_phys)
            x = x + y
        else:
            x = x + mlp.mlp(sub["ffn"], h)
    return x, new


def _slstm_prefill(params, h, cache_j, cfg):
    B, S, D = h.shape
    def step(state, x_t):
        new = xlstm._slstm_step(params, x_t, state, cfg.n_heads)
        return new, new.h.reshape(B, D)
    final, hs = lax.scan(step, cache_j, jnp.moveaxis(h, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(h.dtype)
    y = jax.nn.silu(y @ params["up"])
    return y @ params["down"], final


def _mlstm_prefill(params, h, cache_j, cfg):
    # Parallel chunked forward; final (C, n) state recovered by the same
    # chunk recurrence (mlstm_block recomputation shares the scan).
    y = xlstm.mlstm_block(params, h, n_heads=cfg.n_heads)
    # recompute final state via one pass of the inter-chunk recurrence
    q, k, v, logf, logi, _ = xlstm._mlstm_qkvg(params, h, cfg.n_heads)
    del q
    kc = k.astype(jnp.float32)
    vc = v.astype(jnp.float32)
    Fc = jnp.cumsum(logf, axis=1)
    tot = Fc[:, -1]                                     # (B, H)
    gk = jnp.exp(tot[:, None] - Fc + logi)              # (B, S, H)
    C1 = cache_j.c * jnp.exp(tot)[..., None, None] + jnp.einsum(
        "bshe,bshf->bhef", kc * gk[..., None], vc)
    n1 = cache_j.n * jnp.exp(tot)[..., None] + jnp.sum(
        kc * gk[..., None], axis=1)
    return y, xlstm.MLstmCache(c=C1, n=n1)


# ---- whole-model entry points -------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, tokens, prefix_embeds):
    x = layers.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        x = constrain(x, "batch", "seq", None)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    return x, positions


def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            remat: bool = True):
    """Full-sequence forward -> (logits (B, S, V), aux_loss)."""
    x, positions = _embed_inputs(params, cfg, tokens, prefix_embeds)
    p = superblock_size(cfg)

    def block_body(carry, block_p):
        x, aux = carry
        for j in range(p):
            x, a = _apply_sub_train(block_p[f"sub{j}"], cfg, j, x, positions)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(block_body) if remat else block_body
    (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = layers.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed({}, x, table=table)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = True):
    """batch: {tokens, labels, mask?, prefix_embeds?} -> scalar loss."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_embeds"), remat=remat)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vlm prefix positions: no loss
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    ce = layers.cross_entropy(logits, labels, batch.get("mask"))
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def _cached_stack_scan(params, cfg: ModelConfig, x, cache, mode,
                       positions=None):
    """Scan over super-blocks with the cache stack in the scan CARRY.

    PERF (qwen2.5 decode iteration 3): passing caches as scan xs/ys means
    XLA cannot alias the input and output stacks — every decode step
    copied and rewrote the full multi-GB cache per layer iteration.  As a
    carry, the stack is aliased in place and each iteration touches only
    its own layer's slice (dynamic_index / dynamic_update_index).
    """
    p = superblock_size(cfg)

    def block_body(carry, inp):
        x, caches = carry
        block_p, idx = inp
        for j in range(p):
            cache_j = jax.tree.map(
                lambda c: lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False),
                caches[f"sub{j}"])
            x, new = _apply_sub_step(block_p[f"sub{j}"], cache_j, cfg, j, x,
                                     mode=mode, positions=positions)
            caches = dict(caches)
            caches[f"sub{j}"] = jax.tree.map(
                lambda full, nw: lax.dynamic_update_index_in_dim(
                    full, nw.astype(full.dtype), idx, 0),
                caches[f"sub{j}"], new)
        return (x, caches), None

    n_sb = n_superblocks(cfg)
    (x, new_cache), _ = lax.scan(
        block_body, (x, cache),
        (params["blocks"], jnp.arange(n_sb, dtype=jnp.int32)))
    return x, new_cache


def prefill(params, cfg: ModelConfig, tokens, cache, prefix_embeds=None):
    """Prompt phase: returns (last-position logits (B, V), updated cache)."""
    x, positions = _embed_inputs(params, cfg, tokens, prefix_embeds)
    x, new_cache = _cached_stack_scan(params, cfg, x, cache, "prefill",
                                      positions=positions)
    x = layers.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed({}, x[:, -1:], table=table)[:, 0]
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """One decode step: token (B,) -> (logits (B, V), updated cache)."""
    x = layers.embed(params["embed"], token[:, None])     # (B, 1, D)
    x, new_cache = _cached_stack_scan(params, cfg, x, cache, "decode")
    x = layers.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed({}, x, table=table)[:, 0]
    return logits, new_cache
