"""GQA attention: chunked-causal train/prefill, cached decode, cross-attn.

Memory discipline (DESIGN.md Section 5): the (S, S) score matrix is never
materialized — queries are processed in chunks of `q_chunk` via lax.scan
(Rabe & Staats style), bounding live attention memory at
(B, H, q_chunk, S).  Heads are model-sharded; the KV cache's sequence axis
is shardable via the `kv_seq` logical rule (the long_500k cells set it to
"data": sequence-parallel decode, with GSPMD inserting the partial-softmax
combine — the flash-decode pattern).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rope
from repro.models.sharding import constrain

DEFAULT_Q_CHUNK = 512


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_max, KV, hd)
    v: jax.Array        # (B, S_max, KV, hd)
    length: jax.Array   # () int32 — tokens currently valid


def make_head_mask(cfg):
    """(H_phys,) 0/1 mask of real query heads, kv-major layout.

    Padded configs (head_pad_to / kv_head_pad_to) carry dummy heads so the
    head dim tiles the model mesh axis; the mask hard-zeros their attention
    output before the output projection, which both preserves the real
    model's function and blocks every gradient path into the dummy
    parameters.  Returns None when no padding is configured.
    """
    if cfg.n_heads_phys == cfg.n_heads and cfg.n_kv_phys == cfg.n_kv_heads:
        return None
    g_phys = cfg.n_heads_phys // cfg.n_kv_phys
    h = jnp.arange(cfg.n_heads_phys)
    kv, j = h // g_phys, h % g_phys
    real = (kv < cfg.n_kv_heads) & (j < cfg.head_group)
    return real.astype(jnp.float32)


def attention_params(create, d_model: int, n_heads: int, n_kv: int,
                     head_dim: int, qkv_bias: bool):
    p = {
        "wq": create("wq", (d_model, n_heads * head_dim), ("embed", "qkv")),
        "wk": create("wk", (d_model, n_kv * head_dim), ("embed", "qkv")),
        "wv": create("wv", (d_model, n_kv * head_dim), ("embed", "qkv")),
        "wo": create("wo", (n_heads * head_dim, d_model), ("qkv", "embed")),
    }
    if qkv_bias:
        p["bq"] = create("bq", (n_heads * head_dim,), ("qkv",), init="zeros")
        p["bk"] = create("bk", (n_kv * head_dim,), ("qkv",), init="zeros")
        p["bv"] = create("bv", (n_kv * head_dim,), ("qkv",), init="zeros")
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    return (constrain(q, "batch", "seq", "heads", None),
            constrain(k, "batch", "seq", "heads", None),
            constrain(v, "batch", "seq", "heads", None))


def _mask_heads(o, head_mask, n_heads, head_dim):
    """Zero dummy-head outputs; o is (..., H, hd) or (..., H*hd)."""
    if head_mask is None:
        return o
    if o.shape[-1] == n_heads * head_dim:
        o = o.reshape(*o.shape[:-1], n_heads, head_dim)
        return (o * head_mask[..., None]).reshape(
            *o.shape[:-2], n_heads * head_dim)
    return o * head_mask[..., None]


def _repeat_kv(kv, n_heads):
    """(B, S, KV, hd) -> (B, S, H, hd) by group broadcast.

    Keeping the einsums 4-D with the full H dim model-sharded avoids the
    (KV, group) split-dim shardings that force GSPMD into involuntary
    full-rematerialization copies (caught by the trip-aware roofline; see
    EXPERIMENTS.md Section Perf, iteration 0).  XLA fuses the broadcast
    into the consuming dot, so no materialized g-fold copy remains.
    """
    B, S, KV, hd = kv.shape
    g = n_heads // KV
    if g == 1:
        return kv
    return jnp.repeat(kv, g, axis=2)


def _gqa_scores(q, k):
    """q: (B, Sq, H, hd), k: (B, Sk, KV, hd) -> (B, H, Sq, Sk), f32."""
    H = q.shape[2]
    hd = q.shape[-1]
    kf = _repeat_kv(k, H)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   kf.astype(jnp.float32))
    return s / math.sqrt(hd)


def _gqa_mix(probs, v):
    """probs: (B, H, Sq, Sk), v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    H = probs.shape[1]
    vf = _repeat_kv(v, H)
    return jnp.einsum("bhqs,bshd->bqhd", probs, vf.astype(jnp.float32))


def _gqa_scores_grouped(q, k):
    """Decode-path scores without the KV->H repeat.

    PERF (qwen2.5 iteration 2): with decode heads unsharded, repeating the
    cache to H heads in f32 reads H/KV x 2 more bytes than the cache holds
    (5.4 GB/layer at qwen2.5 decode).  The grouped einsum contracts
    directly against the (B, S, KV, hd) cache in bf16 with f32
    accumulation.  q: (B, 1, H, hd) -> (B, H, 1, S).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, H, Sq, k.shape[1]) / math.sqrt(hd)


def _gqa_mix_grouped(probs, v):
    """probs: (B, H, Sq, Sk) f32, v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    B, H, Sq, Sk = probs.shape
    KV = v.shape[2]
    g = H // KV
    pg = probs.reshape(B, KV, g, Sq, Sk).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pg, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, v.shape[-1])


def _chunked_softmax_attend(q, k, v, positions, *, q_chunk, causal,
                            n_heads, head_dim):
    """Chunked-query attention core; returns (B, S, H*hd) in f32->input dtype."""
    B, S = q.shape[0], q.shape[1]
    c = min(q_chunk, S)
    if S % c != 0:  # static shapes: fall back to one chunk
        c = S
    n_chunks = S // c
    qs = q.reshape(B, n_chunks, c, n_heads, head_dim)
    pos_q = positions.reshape(B, n_chunks, c)

    # PERF: remat the chunk body — otherwise every chunk's (B, H, c, S)
    # score/prob tensors are stacked across chunks as scan residuals for
    # the backward pass, i.e. the full S^2 attention matrix lands in HBM
    # anyway.  Recompute-in-backward keeps S^2 tensors transient (the
    # flash-attention memory discipline at the XLA level).
    @jax.checkpoint
    def one_chunk(carry, inp):
        qc, pq = inp                       # (B, c, H, hd), (B, c)
        s = _gqa_scores(qc, k)             # (B, H, c, S)
        if causal:
            mask = pq[:, None, :, None] >= positions[:, None, None, :]
            s = jnp.where(mask, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = _gqa_mix(p, v)                 # (B, c, H, hd)
        return carry, o

    _, outs = lax.scan(one_chunk, None,
                       (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(pos_q, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, n_heads * head_dim)


def causal_attention(params, x, positions, *, n_heads, n_kv, head_dim,
                     rope_theta, q_chunk: int = DEFAULT_Q_CHUNK,
                     causal: bool = True, head_mask=None):
    """Train/prefill attention over the full sequence, chunked over queries.

    x: (B, S, D); positions: (B, S) absolute positions (for RoPE + mask).
    """
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    # PERF (granite iteration 4): repeat KV->H once per layer, OUTSIDE the
    # chunk scan.  Inside the (remat'd) chunk body the repeat's backward is
    # a per-chunk group-reduction across the model-sharded head axis —
    # a collective-permute storm; hoisted, it happens once per layer.
    k = constrain(_repeat_kv(k, n_heads), "batch", "seq", "heads", None)
    v = constrain(_repeat_kv(v, n_heads), "batch", "seq", "heads", None)
    out = _chunked_softmax_attend(q, k, v, positions, q_chunk=q_chunk,
                                  causal=causal, n_heads=n_heads,
                                  head_dim=head_dim)
    out = _mask_heads(out, head_mask, n_heads, head_dim)
    out = out.astype(x.dtype) @ params["wo"]
    return constrain(out, "batch", "seq", None)


def init_cache(create, batch: int, s_max: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16):
    """KV cache buffers through a creator (real zeros or ShapeDtypeStruct)."""
    return KVCache(
        k=create("cache_k", (batch, s_max, n_kv, head_dim),
                 ("batch", "kv_seq", "heads", None), init="zeros",
                 dtype=dtype),
        v=create("cache_v", (batch, s_max, n_kv, head_dim),
                 ("batch", "kv_seq", "heads", None), init="zeros",
                 dtype=dtype),
        length=create("cache_len", (), (), init="zeros", dtype=jnp.int32),
    )


def prefill_into_cache(params, x, positions, cache: KVCache, *, n_heads,
                       n_kv, head_dim, rope_theta,
                       q_chunk: int = DEFAULT_Q_CHUNK, head_mask=None):
    """Run causal attention AND write k/v into the cache (prompt phase)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    kf = constrain(_repeat_kv(k, n_heads), "batch", "seq", "heads", None)
    vf = constrain(_repeat_kv(v, n_heads), "batch", "seq", "heads", None)
    out = _chunked_softmax_attend(q, kf, vf, positions, q_chunk=q_chunk,
                                  causal=True, n_heads=n_heads,
                                  head_dim=head_dim)
    out = _mask_heads(out, head_mask, n_heads, head_dim)
    out = constrain(out.astype(x.dtype) @ params["wo"], "batch", "seq", None)
    new_k = lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                     (0, 0, 0, 0))
    new_v = lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                     (0, 0, 0, 0))
    return out, KVCache(k=constrain(new_k, "batch", "kv_seq", "heads", None),
                        v=constrain(new_v, "batch", "kv_seq", "heads", None),
                        length=jnp.int32(S))


def decode_attention(params, x, cache: KVCache, *, n_heads, n_kv, head_dim,
                     rope_theta, head_mask=None):
    """One-token decode: x (B, 1, D) attends to the cache.

    The new k/v are written at `cache.length`; attention spans the whole
    (static-size) buffer with a validity mask — when the cache's sequence
    axis is sharded ("kv_seq": "data"), the softmax reductions become the
    sequence-parallel flash-decode combine.
    """
    B, one, D = x.shape
    pos = jnp.full((B, 1), cache.length, jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    q = rope(q, pos, rope_theta)
    k = rope(k, pos, rope_theta)

    new_k = lax.dynamic_update_slice(
        cache.k, k.astype(cache.k.dtype), (0, cache.length, 0, 0))
    new_v = lax.dynamic_update_slice(
        cache.v, v.astype(cache.v.dtype), (0, cache.length, 0, 0))
    new_k = constrain(new_k, "batch", "kv_seq", "heads", None)
    new_v = constrain(new_v, "batch", "kv_seq", "heads", None)

    s = _gqa_scores_grouped(q.astype(new_k.dtype), new_k)  # (B, H, 1, S)
    # under the decode rules the score's sequence axis is model-sharded;
    # the softmax reductions become the flash-decode partial combine
    s = constrain(s, "batch", "heads", None, "kv_seq")
    s_pos = jnp.arange(new_k.shape[1])
    mask = (s_pos <= cache.length)[None, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = _mask_heads(_gqa_mix_grouped(p, new_v), head_mask, n_heads,
                    head_dim)
    o = o.reshape(B, 1, n_heads * head_dim)
    out = o.astype(x.dtype) @ params["wo"]
    return out, KVCache(k=new_k, v=new_v, length=cache.length + 1)


# ---- cross attention (encoder-decoder) --------------------------------------

def cross_attention_params(create, d_model: int, n_heads: int, n_kv: int,
                           head_dim: int):
    return attention_params(create, d_model, n_heads, n_kv, head_dim,
                            qkv_bias=False)


def cross_attention(params, x, enc_kv, *, n_heads, n_kv, head_dim,
                    head_mask=None):
    """x: (B, Sq, D) queries over precomputed encoder states (B, Se, D).

    No positional rotation (positions live in the encoder states); no mask
    (full visibility of the encoder output).
    """
    B, Sq, D = x.shape
    q = (x @ params["wq"]).reshape(B, Sq, n_heads, head_dim)
    Se = enc_kv.shape[1]
    k = (enc_kv @ params["wk"]).reshape(B, Se, n_kv, head_dim)
    v = (enc_kv @ params["wv"]).reshape(B, Se, n_kv, head_dim)
    s = _gqa_scores(q, k)
    p = jax.nn.softmax(s, axis=-1)
    o = _mask_heads(_gqa_mix(p, v), head_mask, n_heads, head_dim)
    o = o.reshape(B, Sq, n_heads * head_dim)
    return constrain(o.astype(x.dtype) @ params["wo"], "batch", "seq", None)
