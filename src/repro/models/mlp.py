"""SwiGLU feed-forward (llama/qwen family)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import constrain


def mlp_params(create, d_model: int, d_ff: int):
    return {
        "w_gate": create("w_gate", (d_model, d_ff), ("embed", "mlp")),
        "w_up": create("w_up", (d_model, d_ff), ("embed", "mlp")),
        "w_down": create("w_down", (d_ff, d_model), ("mlp", "embed")),
    }


def mlp(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(h @ params["w_down"], "batch", "seq", None)
