"""Mixture-of-Experts FFN: token-choice top-k routing, capacity-bounded,
gather-based dispatch, expert-parallel over the `model` mesh axis.

Dispatch strategy (DESIGN.md Section 5): the classic GShard dense dispatch
tensor (tokens, experts, capacity) is O(N*E*C) — terabytes at our shapes.
Instead we build an (E, C) *token-index table* (O(E*C) int32) and dispatch
with a gather:

  1. router logits -> softmax -> top-k gate weights per token
     (renormalized over the selected k, mixtral-style);
  2. position-in-expert via a token-major cumulative count; tokens beyond
     an expert's capacity C are dropped (standard capacity-factor policy,
     the residual path carries them — dropped tokens simply pass through);
  3. token ids scattered into the (E, C) table, gathered into the
     (E, C, D) expert batch — sharded ("experts" -> model) so each mesh
     slice computes only its experts (EP);
  4. expert SwiGLU via einsum with the E batch dim;
  5. weighted scatter-add back to (N, D).

Note the selection connection (DESIGN.md Section 3): top-k routing over
E <= 48 experts is the paper's selection problem at trivial scale; the
candidate set is local and tiny, so `lax.top_k` is the right tool — the
distributed machinery pays off on vocab/datastore-sized candidate sets.

MoE top-k routing uses an auxiliary load-balancing loss (Switch/GShard) —
returned alongside so the trainer can weight it in.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.sharding import constrain


def moe_params(create, d_model: int, d_ff: int, n_experts: int,
               n_experts_phys: int | None = None):
    ep = n_experts_phys or n_experts
    return {
        "router": create("router", (d_model, n_experts), ("embed", None)),
        "w_gate": create("w_gate", (ep, d_model, d_ff),
                         ("experts", "embed", None)),
        "w_up": create("w_up", (ep, d_model, d_ff),
                       ("experts", "embed", None)),
        "w_down": create("w_down", (ep, d_ff, d_model),
                         ("experts", None, "embed")),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * capacity_factor / n_experts))
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for layout friendliness


def moe_ffn(params, x, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25,
            n_experts_phys: int | None = None):
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar).

    PERF (EXPERIMENTS.md Section Perf, granite iteration 1): tokens are
    first reshaped into G = batch_shards() *groups* (the GShard/MaxText
    group trick).  Routing, the position-in-expert cumsum, and the
    dispatch gather are then all group-local — shard-local under the mesh,
    no cross-shard cumsum (which GSPMD lowers to collective-permute
    chains) and no global gather of activations.  The ONLY cross-shard
    movement is the (G, E, Cg, D) expert batch's group->expert resharding:
    one all-to-all each way, the canonical MoE schedule.
    """
    B, S, D = x.shape
    N = B * S
    # dummy experts beyond n_experts are never routed to (the router only
    # produces n_experts logits); they exist so the expert tensor dim
    # tiles the mesh's model axis.
    ep = n_experts_phys or n_experts

    G = sharding.batch_shards()
    while N % G:
        G //= 2
    if N // max(G, 1) < 64:
        # decode-sized batches: per-group capacity rounding dominates and
        # the group<->expert resharding overhead outweighs dispatch
        # locality (measured on jamba decode_32k) — single group instead.
        G = 1
    Ng = N // G
    gax = "batch" if G > 1 else None  # never shard a size-1 group dim
    xt = constrain(x.reshape(G, Ng, D), gax, None, None)

    logits = (xt @ params["router"]).astype(jnp.float32)      # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # (G, Ng, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance auxiliary loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    assign = jax.nn.one_hot(expert_idx[..., 0], n_experts)    # top-1 fraction
    ce = jnp.mean(assign, axis=(0, 1))
    aux = n_experts * jnp.sum(me * ce)

    C = capacity(Ng, n_experts, top_k, capacity_factor)

    # position of each (token, k) assignment within its expert — cumsum is
    # over the group-local token axis only.
    onehot = jax.nn.one_hot(expert_idx, ep, dtype=jnp.int32)
    flat = onehot.reshape(G, Ng * top_k, ep)
    pos = jnp.cumsum(flat, axis=1) - flat                     # (G, Ng*K, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, Ng, top_k)
    keep = pos < C

    e_flat = expert_idx.reshape(G, -1)
    p_flat = jnp.where(keep, pos, C).reshape(G, -1)           # C => dropped
    tok_flat = jnp.broadcast_to(jnp.arange(Ng)[:, None],
                                (Ng, top_k)).reshape(1, -1)
    tok_flat = jnp.broadcast_to(tok_flat, (G, Ng * top_k))
    g_flat = jnp.where(keep, gate_vals, 0.0).reshape(G, -1)

    # (G, E, C) token table and gate table; slot C is the drop bucket.
    grows = jnp.broadcast_to(jnp.arange(G)[:, None], e_flat.shape)
    table = jnp.full((G, ep, C + 1), Ng, jnp.int32)
    table = table.at[grows, e_flat, p_flat].set(tok_flat, mode="drop")
    gates = jnp.zeros((G, ep, C + 1), jnp.float32)
    gates = gates.at[grows, e_flat, p_flat].set(g_flat, mode="drop")
    table, gates = table[..., :C], gates[..., :C]

    # group-local dispatch gather, then the group->expert all-to-all.
    # PERF (granite iteration 5): gather/scatter must see group-local
    # layouts on BOTH operands — if the updates arrive expert-sharded,
    # GSPMD materializes the scatter as partial results + a full-size
    # all-reduce of the (G, Ng, D) token buffer (2 x 805 MB per layer at
    # granite scale).  The expert<->group resharding is therefore staged
    # explicitly, outside the gather/scatter.
    xpad = jnp.concatenate(
        [xt, jnp.zeros((G, 1, D), xt.dtype)], axis=1)         # (G, Ng+1, D)
    ex_in = jnp.take_along_axis(
        xpad, table.reshape(G, ep * C)[..., None], axis=1
    ).reshape(G, ep, C, D)
    ex_in = constrain(ex_in, gax, None, None, None)           # local gather
    ex_in = constrain(ex_in, gax, "experts", "expert_cap", None)      # a2a

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ex_in, params["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", ex_in, params["w_up"])
    ex_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    ex_out = constrain(ex_out, gax, "experts", "expert_cap", None)
    ex_out = constrain(ex_out, gax, None, None, None)         # a2a back

    # combine: group-local weighted scatter-add back to tokens
    w = (ex_out * gates[..., None].astype(ex_out.dtype)).reshape(
        G, ep * C, D)
    y = jnp.zeros((G, Ng + 1, D), ex_out.dtype)
    y = y.at[grows[:, :1].repeat(ep * C, 1),
             table.reshape(G, ep * C)].add(w, mode="drop")
    y = constrain(y[:, :Ng].reshape(B, S, D), "batch", "seq", None)
    return y.astype(x.dtype), aux
