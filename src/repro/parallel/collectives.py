"""Small collective utilities used across the framework.

JAX's varying-manual-axes (vma) checker does not infer `all_gather` outputs
as replicated, even though they are identical on every shard.  `replicate`
re-derives provable invariance with one psum of shard 0's copy — O(size)
flops, no extra bytes beyond the psum itself — so library functions can hand
back replicated results to shard_maps running with full vma checking.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def replicate(x: jax.Array, axis_name) -> jax.Array:
    """Make a semantically-replicated value *provably* invariant over axis.

    Correct only if ``x`` already holds the same value on every shard of
    ``axis_name`` (true for anything derived from all_gather-ed data through
    shard-independent computation).  Handles +/-inf and bool payloads.
    """
    if x.dtype == jnp.bool_:
        return replicate(x.astype(jnp.int32), axis_name).astype(jnp.bool_)
    picked = jnp.where(lax.axis_index(axis_name) == 0, x, jnp.zeros_like(x))
    return lax.psum(picked, axis_name)


def axis_size(axis_name) -> int:
    """Static size of a bound mesh axis (works across jax generations)."""
    if hasattr(lax, "axis_size"):
        return int(lax.axis_size(axis_name))
    from jax.core import trace_ctx
    return int(trace_ctx.axis_env.axis_size(axis_name))
