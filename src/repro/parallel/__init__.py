"""Mesh/collective helpers shared by the core algorithms and the model stack."""

from repro.parallel.collectives import replicate

__all__ = ["replicate"]
