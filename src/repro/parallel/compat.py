"""jax version-compatibility shims for the mesh/shard_map API surface.

The framework is written against the modern jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``).  Older jax
releases (< 0.5) expose the same functionality under different names and
signatures (``jax.experimental.shard_map.shard_map`` with ``check_rep``,
``make_mesh`` without ``axis_types``, ``Mesh`` as a plain context manager).
Every mesh/shard_map call site in the repo routes through this module so the
whole stack — core algorithms, serving runtime, benchmarks, tests — runs on
either generation with no behavioral difference.

Only signature/name differences are papered over here; semantics shims
belong next to the code that needs them (e.g. the ``lax.pcast`` guard in
core/selection.py).
"""

from __future__ import annotations

import contextlib

import jax

# Modern jax promotes shard_map out of experimental; use its presence as the
# API-generation probe for the whole surface.
IS_MODERN = hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` on modern jax, experimental shard_map on old.

    ``check_vma`` toggles the "outputs claimed replicated must be provably
    replicated" verifier.  The old-generation equivalent (``check_rep``) has
    no replication rule for ``while_loop`` — which Algorithm 1 is built on —
    so on old jax the verifier is always off; it remains a modern-jax-only
    safety net.
    """
    if IS_MODERN:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(axis_shapes, axis_names):
    """Mesh of host devices with fully-automatic axis types everywhere."""
    if IS_MODERN:
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(axis_shapes, axis_names)


def ambient_mesh_axis_names():
    """Axis names of the mesh installed by :func:`set_mesh`, or None.

    Modern jax exposes the ambient mesh abstractly
    (``jax.sharding.get_abstract_mesh``); old jax keeps the physical mesh in
    a thread-local resource env.
    """
    mesh = _ambient_mesh()
    return set(mesh.axis_names) if mesh is not None else None


def ambient_mesh_axis_sizes():
    """{axis name: size} of the ambient mesh, or None if no mesh is set."""
    mesh = _ambient_mesh()
    if mesh is None:
        return None
    if hasattr(mesh, "axis_sizes"):
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    return dict(mesh.shape)


def _ambient_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except AttributeError:
        try:
            from jax._src.mesh import thread_resources
            mesh = thread_resources.env.physical_mesh
            if mesh is not None and not mesh.empty:
                return mesh
        except (ImportError, AttributeError):
            pass
    return None


def set_mesh(mesh):
    """Context manager binding ``mesh`` as the ambient mesh.

    Modern jax: ``jax.set_mesh``.  Old jax: ``Mesh`` is itself a context
    manager that installs the axis environment; ``None`` means "no mesh".
    """
    if mesh is None:
        return contextlib.nullcontext()
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
