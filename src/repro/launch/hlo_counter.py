"""Trip-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE
(verified against a scan-vs-unroll control, EXPERIMENTS.md Section Dry-run)
— useless for scan-over-layers programs where >95% of work sits inside
nested loops (layer scan x grad-accum scan).  This module re-derives
trip-weighted totals from the post-optimization HLO text:

  1. split the module into computations;
  2. per computation, count dot FLOPs (2 x result x contraction — the MXU
     convention), top-level HBM bytes (operands + result of scheduled ops;
     fusion bodies are register-resident), and collective wire bytes
     (ring-algorithm factors, hlo_analysis.collective_wire_bytes);
  3. build the call multigraph — while bodies weighted by their
     `known_trip_count` annotation, fusions/calls/conditionals by 1 —
     and propagate multipliers from ENTRY;
  4. totals = sum_comp multiplier(comp) x local(comp).

Shapes in a post-SPMD module are per-device, so all outputs are per-device
quantities (matching the roofline convention in hlo_analysis.py).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

from repro.launch import hlo_analysis as ha

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_COMP_START2 = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_TYPE = re.compile(r"^(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*(.*)$")
_OPNAME = re.compile(r"^([a-z][\w\-]*)\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_CALLS = re.compile(r"(?:calls=|to_apply=|body=)%?([\w.\-]+)")
_COND_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIPS = re.compile(r'known_trip_count[^\d]*(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIMS = re.compile(r"\[([\d,]*)\]")


def _operand_names(operand_str: str):
    """Instruction names referenced in an HLO operand list.

    Old-jax HLO prints operands with type prefixes
    (``dot(f32[128,128]{1,0} %gte.5, ...)``), modern HLO prints bare names;
    %-prefixed tokens disambiguate, with a plain comma split as fallback.
    """
    names = re.findall(r"%([\w.\-]+)", operand_str)
    if names:
        return names
    return [o.strip() for o in operand_str.split(",")]


def _dims_of(type_str: str):
    m = _DIMS.search(type_str)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x]


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    wire_counts: dict = dataclasses.field(default_factory=dict)
    edges: list = dataclasses.field(default_factory=list)  # (callee, mult)
    is_fusion_body: bool = False
    root_op: str = ""
    # fusion call sites: (callee, result_bytes, [operand_bytes]) — resolved
    # after the whole module is parsed (the callee's root op decides the
    # traffic model: a dus-rooted fusion only writes its update window).
    fusion_sites: list = dataclasses.field(default_factory=list)


def _parse(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    symbols: dict[str, str] = {}
    entry: str | None = None
    fusion_callees: list[str] = []

    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_START2.match(line)
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                cur = comps.setdefault(name, _Comp(name))
                symbols = {}
                if m.group(1):
                    entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        res_name, rest = mi.group(1), mi.group(2)
        mt = _TYPE.match(rest)
        if not mt:
            continue
        type_str, op_rest = mt.group(1), mt.group(2)
        symbols[res_name] = type_str
        mo = _OPNAME.match(op_rest)
        opname = mo.group(1) if mo else ""
        if line.lstrip().startswith("ROOT"):
            cur.root_op = opname

        # --- call edges -------------------------------------------------
        if opname == "while":
            trips = 1
            t = _TRIPS.search(line)
            if t:
                trips = int(t.group(1))
            for what in ("body", "condition"):
                mm = re.search(what + r"=%?([\w.\-]+)", line)
                if mm:
                    cur.edges.append((mm.group(1),
                                      trips if what == "body" else trips + 1))
        elif opname == "conditional":
            mb = _COND_BRANCHES.search(line)
            if mb:
                for b in mb.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        cur.edges.append((b, 1))
            for mm in re.finditer(r"(?:true|false)_computation=%?([\w.\-]+)",
                                  line):
                cur.edges.append((mm.group(1), 1))
        else:
            for mm in _CALLS.finditer(line):
                callee = mm.group(1)
                cur.edges.append((callee, 1))

        # --- flops: dots anywhere --------------------------------------
        if opname == "dot":
            ops = _OPERANDS.search(op_rest)
            contract = _CONTRACT.search(line)
            out_elems = math.prod(_dims_of(type_str)) if _dims_of(
                type_str) else 1
            k = 1
            if ops and contract is not None:
                first = _operand_names(ops.group(1))[0]
                lhs_type = symbols.get(first, "")
                lhs_dims = _dims_of(lhs_type)
                idxs = [int(x) for x in contract.group(1).split(",") if x]
                for i in idxs:
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
            cur.flops += 2.0 * out_elems * k
        elif opname in ("convolution",):
            # not used by this model zoo; approximate by result size
            cur.flops += 2.0 * math.prod(_dims_of(type_str) or [1])

        # --- bytes: scheduled (non-fusion-body) top-level ops -------------
        # Aliasing/windowed ops only touch their window, and control-flow
        # ops' operands/results alias their bodies' buffers (the bodies are
        # counted separately x trips) — charging them at full tensor size
        # inflated jamba's memory term ~100x (EXPERIMENTS.md, Dry-run notes).
        if opname in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "while", "conditional", "call"):
            pass
        elif opname in ("dynamic-slice", "slice", "gather"):
            cur.bytes += 2 * ha._shape_bytes(type_str)   # read + write window
        elif opname in ("dynamic-update-slice", "scatter"):
            ops = _OPERANDS.search(op_rest)
            upd_bytes = 0
            if ops:
                names = _operand_names(ops.group(1))
                idx = 1 if opname == "dynamic-update-slice" else 2
                if len(names) > idx and names[idx] in symbols:
                    upd_bytes = ha._shape_bytes(symbols[names[idx]])
            cur.bytes += 2 * upd_bytes                   # RMW of the window
        elif opname == "fusion":
            ops = _OPERANDS.search(op_rest)
            operand_bytes = []
            if ops:
                for o in _operand_names(ops.group(1)):
                    if o in symbols:
                        operand_bytes.append(ha._shape_bytes(symbols[o]))
            mm = _CALLS.search(line)
            cur.fusion_sites.append((mm.group(1) if mm else "",
                                     ha._shape_bytes(type_str),
                                     operand_bytes))
        else:
            nbytes = ha._shape_bytes(type_str)
            ops = _OPERANDS.search(op_rest)
            if ops:
                for o in _operand_names(ops.group(1)):
                    if o in symbols:
                        nbytes += ha._shape_bytes(symbols[o])
            cur.bytes += nbytes

        # --- collectives --------------------------------------------------
        mcoll = ha._COLLECTIVE_RE.search(line)
        if mcoll:
            kind = mcoll.group(3).lower()
            result_type = mcoll.group(1) if mcoll.group(1) else mcoll.group(2)
            nbytes = ha._shape_bytes(result_type)
            g = ha._GROUPS_RE.search(line)
            if g:
                n = max(1, len([x for x in g.group(1).split(",")
                                if x.strip()]))
            else:
                g2 = ha._GROUPS_ALT_RE.search(line)
                n = int(g2.group(2)) if g2 else 2
            if n > 1 and nbytes > 0:
                factor = {
                    "all-reduce": 2.0 * (n - 1) / n,
                    "all-gather": (n - 1) / n,
                    "reduce-scatter": float(n - 1),
                    "all-to-all": (n - 1) / n,
                    "collective-permute": 1.0,
                }[kind]
                cur.wire += factor * nbytes
                cur.wire_counts[kind] = cur.wire_counts.get(kind, 0) + 1

        # fusion bodies: bytes inside are register/VMEM traffic — remember
        # the callee name and mark after the full module is parsed (the
        # callee's definition usually appears later in the text).
        if opname == "fusion":
            mm = _CALLS.search(line)
            if mm:
                fusion_callees.append(mm.group(1))

    # second pass: mark fusion bodies (and anything they call) register-only
    stack = list(fusion_callees)
    seen = set()
    while stack:
        n = stack.pop()
        if n in seen or n not in comps:
            continue
        seen.add(n)
        comps[n].is_fusion_body = True
        stack.extend(c for c, _ in comps[n].edges)

    # third pass: resolve fusion call-site traffic by the callee's root op.
    for comp in comps.values():
        for callee, result_bytes, operand_bytes in comp.fusion_sites:
            root = comps[callee].root_op if callee in comps else ""
            big = [b for b in operand_bytes if b > 64]
            if root in ("dynamic-update-slice", "scatter"):
                # writes only its update window; the accumulator operand
                # aliases the result.  The update is the smallest non-
                # scalar operand.
                comp.bytes += 2 * (min(big) if big else result_bytes)
            elif root in ("dynamic-slice", "slice", "gather"):
                comp.bytes += 2 * result_bytes
            else:
                comp.bytes += result_bytes + sum(operand_bytes)

    comps["__entry__"] = comps.get(entry, _Comp("__missing__"))
    return comps


def analyze(text: str) -> dict:
    comps = _parse(text)
    entry = comps.pop("__entry__")

    # mark fusion bodies reachable only through fusion edges
    fusion_callees = set()
    for c in comps.values():
        pass

    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    # propagate in topological-ish order: iterate until fixpoint (HLO
    # computation graphs are DAGs; bounded passes)
    for _ in range(64):
        changed = False
        snapshot = dict(mult)
        mult = defaultdict(float)
        mult[entry.name] = 1.0
        for name, m in snapshot.items():
            comp = comps.get(name)
            if comp is None:
                continue
            for callee, k in comp.edges:
                mult[callee] += m * k
        mult[entry.name] = 1.0
        if dict(mult) == snapshot:
            break

    flops = bytes_ = wire = 0.0
    wire_counts: dict[str, float] = defaultdict(float)
    for name, m in mult.items():
        comp = comps.get(name)
        if comp is None:
            continue
        flops += m * comp.flops
        wire += m * comp.wire
        for k, v in comp.wire_counts.items():
            wire_counts[k] += m * v
        if not comp.is_fusion_body:
            bytes_ += m * comp.bytes
    return {"flops": flops, "hbm_bytes": bytes_, "wire_bytes": wire,
            "collective_counts": dict(wire_counts)}


def roofline_from_text(text: str, chips: int, model_flops: float = 0.0):
    res = analyze(text)
    return ha.Roofline(flops=res["flops"], hbm_bytes=res["hbm_bytes"],
                       wire_bytes=res["wire_bytes"], chips=chips,
                       model_flops=model_flops,
                       collective_counts=res["collective_counts"])
