"""Serving driver: LM generation with the distributed-selection sampler,
or the paper's standalone distributed l-NN service.

  # LM decode (reduced config on CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --tokens 32 --batch 4 --sampler selection

  # the paper's artifact — distributed l-NN queries over a sharded corpus:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch knn-service --knn-k 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.configs as configs
import repro.core as core
from repro.data import gaussian_clusters
from repro.models import build_model
from repro.models import sharding as shd
from repro.runtime import ServeConfig, Server
from repro.parallel.compat import make_mesh, set_mesh, shard_map


def serve_lm(args):
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(0, cfg.vocab,
                                    (args.batch, args.prompt)).astype(
                                        np.int32)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = rng.normal(
            size=(args.batch, cfg.num_prefix_embeds, cfg.d_model)).astype(
            np.float32)
    if cfg.is_encdec:
        batch["frames"] = rng.normal(
            size=(args.batch, cfg.frontend_frames, cfg.d_model)).astype(
            np.float32)

    scfg = ServeConfig(max_seq=args.prompt + args.tokens + 8,
                       top_k=args.top_k, sampler=args.sampler,
                       num_pivots=args.num_pivots)

    ctx = set_mesh(mesh) if mesh is not None else _null()
    with ctx:
        params = api.init_params(jax.random.PRNGKey(args.seed))
        if mesh is not None:
            from jax.sharding import NamedSharding
            specs = api.param_specs()
            params = jax.tree.map(
                lambda x, s: jax.device_put(
                    x, NamedSharding(mesh, shd.divisible(s, x.shape, mesh))),
                params, specs)
        server = Server(api, params, scfg, mesh=mesh,
                        cache_dtype=jnp.float32)
        gen, stats = server.generate(batch, args.tokens,
                                     key=jax.random.PRNGKey(args.seed + 1))
    print("generated tokens:\n", gen)
    print({k: round(v, 4) for k, v in stats.items()})


def serve_knn(args):
    """The paper's own service: l-NN queries against a sharded point set."""
    kcfg = configs.get("knn-service")
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("model",))
    n = min(kcfg.n_points, args.knn_points)
    n -= n % n_dev
    pts, labels = gaussian_clusters(n, kcfg.dim, kcfg.num_classes,
                                    seed=args.seed)
    ids = np.arange(n, dtype=np.int32)
    l = args.knn_k

    def query(points, pids, plabels, q, key):
        res = core.knn_query(points, pids, q, l, key, axis_name="model",
                             num_pivots=args.num_pivots,
                             gather_results=True)
        # labels aligned with the local top-l buffer via local row mapping
        m = points.shape[0]
        start = jax.lax.axis_index("model") * m
        rows = jnp.clip(res.local_ids - start, 0, m - 1)
        lab = plabels[rows]
        pred, hist = core.knn_classify(res.mask, lab, kcfg.num_classes,
                                       axis_name="model")
        return res.dists, res.ids, pred, res.selection.iterations

    fn = jax.jit(shard_map(
        query, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model"), P(None), P(None)),
        out_specs=(P(None), P(None), P(None), P()),
        check_vma=False))

    rng = np.random.default_rng(args.seed + 7)
    qs = rng.normal(scale=8.0, size=(kcfg.query_batch, kcfg.dim)).astype(
        np.float32)
    t0 = time.perf_counter()
    d, i, pred, iters = fn(pts, ids, labels, qs, jax.random.PRNGKey(3))
    d.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"l-NN over {n} points sharded {n_dev} ways: l={l} "
          f"iterations={int(iters)} wall={dt*1e3:.1f}ms")
    print("predicted classes:", np.asarray(pred))
    print("nearest distances (q0):", np.sort(np.asarray(d)[0])[:5])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--top-k", type=int, default=50)
    ap.add_argument("--sampler", default="selection",
                    choices=["selection", "gather"])
    ap.add_argument("--num-pivots", type=int, default=1)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--knn-k", type=int, default=8)
    ap.add_argument("--knn-points", type=int, default=1 << 16)
    args = ap.parse_args()

    if args.arch in ("knn-service", "knn_service"):
        serve_knn(args)
    else:
        serve_lm(args)


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
