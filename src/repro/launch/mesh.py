"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before the first
jax device query).
"""

from __future__ import annotations

import jax
from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = (data, model) single pod; (2, 16, 16) = (pod, data, model)
    across two pods — 256 / 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 4, model: int = 2):
    """Small host-device mesh for tests (needs
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return make_mesh((data, model), ("data", "model"))
