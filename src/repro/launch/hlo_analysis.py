"""Roofline-term extraction from compiled XLA artifacts.

Three terms per (arch x shape x mesh) cell — EXPERIMENTS.md Section Roofline.
CONVENTION (calibrated against a sharded matmul, see EXPERIMENTS.md
Section Dry-run): compiled.cost_analysis() on an SPMD module reports
**per-device** FLOPs (2 per MAC) and bytes.  All three terms are therefore
per-device seconds — numerically identical to the prompt's
global/(chips x rate) formulation since global = per-device x chips under
SPMD:

  compute    = HLO_FLOPs_per_dev / 197e12 bf16 FLOP/s          [v5e MXU]
  memory     = HLO_bytes_per_dev / 819e9 B/s                   [v5e HBM]
  collective = wire_bytes_per_dev / 50e9 B/s                   [v5e ICI]

Collective wire bytes are NOT in cost_analysis: we parse the
post-partitioning module text (per-device shapes) and apply ring-algorithm
wire factors per op:

  all-reduce       2 (n-1)/n x bytes   (reduce-scatter + all-gather phases)
  all-gather       (n-1)/n x result
  reduce-scatter   (n-1) x result      (operand = n x result)
  all-to-all       (n-1)/n x bytes
  collective-permute  1 x bytes

Known limitation (recorded in EXPERIMENTS.md): collectives inside while
loops (the selection sampler's data-dependent rounds) are counted once —
the static per-iteration cost; the dynamic round count is measured by the
round-complexity benchmarks instead.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12       # bf16 per chip, TPU v5e
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per link (1 active link/chip assumed)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ALT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    counts: dict = dataclasses.field(default_factory=dict)

    def add(self, kind: str, nbytes: float):
        self.wire_bytes += nbytes
        self.counts[kind] = self.counts.get(kind, 0) + 1


def collective_wire_bytes(hlo_text: str) -> CollectiveStats:
    """Parse a post-SPMD HLO module; returns fleet-global wire bytes."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m is None:
            continue
        kind = m.group(3).lower()
        result_type = m.group(1) if m.group(1) else m.group(2)
        nbytes = _shape_bytes(result_type)
        if nbytes == 0:
            continue
        # group size n
        g = _GROUPS_RE.search(line)
        if g:
            n = max(1, len([x for x in g.group(1).split(",") if x.strip()]))
        else:
            g2 = _GROUPS_ALT_RE.search(line)
            n = int(g2.group(2)) if g2 else 2
        if n <= 1:
            continue
        factor = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": (n - 1) / n,
            "reduce-scatter": float(n - 1),  # operand = n x result
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
        }[kind]
        stats.add(kind, factor * nbytes)  # per-device wire bytes
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    chips: int
    model_flops: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS          # per-device numbers

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs, both per-device: > 1 means the compiled
        program does *less* arithmetic than the 6ND estimate (e.g. GQA
        decode), < 1 means remat/padding/dispatch overhead."""
        if self.model_flops and self.flops:
            return (self.model_flops / self.chips) / self.flops
        return None

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "collective_counts": self.collective_counts,
        }


def roofline_from_compiled(compiled, chips: int,
                           model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    stats = collective_wire_bytes(text)
    return Roofline(flops=flops, hbm_bytes=hbm, wire_bytes=stats.wire_bytes,
                    chips=chips, model_flops=model_flops,
                    collective_counts=stats.counts)
