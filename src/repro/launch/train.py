"""Training driver.

Single-process launcher (multi-host initialization is a
jax.distributed.initialize call away — see README "Scaling out"):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 200 --batch 8 --seq 64 --reduced --ckpt-dir /tmp/ckpt

Wires together: config registry -> ModelApi -> sharded params (debug mesh
optional) -> synthetic Markov pipeline with prefetch -> microbatched
train_step -> fault-tolerant loop with async checkpoints.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import MarkovTokens, Prefetcher
from repro.models import build_model
from repro.models import sharding as shd
from repro.optim import AdamW
from repro.parallel.compat import make_mesh, set_mesh
from repro.runtime import (MetricLogger, TrainConfig, init_opt_state,
                           train_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving tiny config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4x2' to train on a data x model debug mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg)

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    def build_state():
        params = api.init_params(jax.random.PRNGKey(args.seed))
        if mesh is not None:
            from jax.sharding import NamedSharding
            specs = api.param_specs()
            params = jax.tree.map(
                lambda x, s: jax.device_put(
                    x, NamedSharding(mesh, shd.divisible(s, x.shape, mesh))),
                params, specs)
        return params

    tcfg = TrainConfig(grad_accum=args.grad_accum, peak_lr=args.lr,
                       warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps,
                       compress_grads=args.compress_grads)
    optimizer = AdamW()

    data = MarkovTokens(cfg.vocab, seed=args.seed, branch=2, n_contexts=13)
    rng = np.random.default_rng(args.seed)

    def make_batch(step):
        t, l = data.batch(step, args.batch, args.seq)
        b = {"tokens": t, "labels": l}
        if cfg.family == "vlm":
            b["prefix_embeds"] = rng.normal(
                size=(args.batch, cfg.num_prefix_embeds, cfg.d_model)
            ).astype(np.float32)
        if cfg.is_encdec:
            b["frames"] = rng.normal(
                size=(args.batch, cfg.frontend_frames, cfg.d_model)
            ).astype(np.float32)
        return b

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    logger = MetricLogger()

    ctx = set_mesh(mesh) if mesh is not None else _nullcontext()
    with ctx:
        params = build_state()
        opt_state = init_opt_state(api, tcfg, optimizer, params)
        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            start, state = mgr.restore_latest(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            logger.log(start, event="resumed from checkpoint")
        params, opt_state, step = train_loop(
            api=api, tcfg=tcfg, optimizer=optimizer, params=params,
            opt_state=opt_state, make_batch=make_batch,
            num_steps=args.steps, ckpt_manager=mgr,
            ckpt_every=args.ckpt_every, start_step=start, logger=logger)
    losses = [r["loss"] for r in logger.history if "loss" in r]
    print(f"done: steps={step} first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f}")


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
