import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE proof of distribution coherence without hardware (deliverable e):
for each assigned architecture and each of its input shapes, this script

  1. builds the production mesh — (16,16) single pod and (2,16,16)
     multi-pod — out of 512 placeholder host devices (the XLA_FLAGS line
     above MUST precede every jax import, hence the module layout);
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
     caches / batch (creator.ShapeCreator — zero allocation even for the
     398B-parameter jamba config);
  3. jits the real step function (train_step with grad-accum scan, prefill,
     or serve_step with the paper's distributed-selection sampler),
     .lower()s and .compile()s it;
  4. records compiled.memory_analysis() (fits-on-device proof),
     cost_analysis() FLOPs/bytes, and the parsed collective wire bytes
     (launch/hlo_analysis.py) into one JSON per cell for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --results-dir results/
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.launch import hlo_analysis, hlo_counter
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, shapes_for, skipped_shapes_for
from repro.models.config import ALL_SHAPES
from repro.models.sharding import Rules, use_rules
from repro.optim import AdamW
from repro.runtime import TrainConfig, make_train_step
from repro.parallel.compat import set_mesh

ARCHS = [
    "qwen2.5-14b", "qwen1.5-4b", "qwen2-0.5b", "yi-6b",
    "phi3.5-moe-42b-a6.6b", "granite-moe-3b-a800m", "jamba-1.5-large-398b",
    "pixtral-12b", "seamless-m4t-large-v2", "xlstm-125m",
]


def rules_for_shape(shape, cfg=None, model_ways: int = 16):
    """Per-shape sharding-rule overrides (DESIGN.md Section 5)."""
    if shape.name == "long_500k":
        # batch=1: unshardable; shard the KV/state sequence axis instead
        # (sequence-parallel decode with flash-decode softmax combine).
        return Rules(batch=None, kv_seq="data")
    if shape.kind == "decode" and cfg is not None:
        # flash-decode (EXPERIMENTS.md Section Perf, qwen2.5 iteration 1):
        # when the KV heads cannot tile the model axis a head-sharded cache
        # degenerates to fully replicated (26x the bytes at qwen2.5 scale);
        # shard the cache SEQUENCE over `model` instead — each shard scores
        # its slice and GSPMD combines the partial softmaxes.  Archs whose
        # (physical) KV heads DO tile the axis (seamless 16, qwen1.5 padded
        # to 32) keep classic head-parallel decode; so does the hybrid
        # (jamba): its 1:7-minority attention doesn't repay trading the
        # projections' head parallelism away (measured regression,
        # EXPERIMENTS.md Section Perf).
        flash = (cfg.n_kv_phys % model_ways != 0
                 and cfg.family != "hybrid")
        if flash:
            return Rules(kv_seq="model", heads=None)
    return Rules()


def grad_accum_for(cfg, shape, mesh) -> int:
    """Microbatch count: keep per-microbatch tokens bounded so activations
    (and the vocab-sharded logits) fit; at least one sequence per data
    shard."""
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            data_ways *= mesh.shape[ax]
    max_accum = max(1, shape.global_batch // data_ways)
    target = 8 if cfg.d_model <= 6000 else 16
    return min(target, max_accum)


def build_cell(api, shape, mesh, *, sampler: str, num_pivots: int,
               grad_accum: int | None = None):
    """Returns (fn, example_args, donate) for the cell's step function."""
    cfg = api.cfg
    params = api.param_shapes(mesh, dtype=jnp.bfloat16)
    inputs = api.input_specs(shape, mesh)

    if shape.kind == "train":
        # >100B-parameter configs only fit the pod with bf16 moments and a
        # bf16 accumulation buffer (EXPERIMENTS.md Section Perf, jamba
        # iteration 4); smaller models keep full f32 state.
        big = cfg.param_count() > 100e9
        optimizer = AdamW(moment_dtype=jnp.bfloat16 if big else jnp.float32)
        ga = grad_accum or grad_accum_for(cfg, shape, mesh)
        tcfg = TrainConfig(
            grad_accum=ga, total_steps=10000,
            accum_dtype=jnp.bfloat16 if big else jnp.float32)
        step = make_train_step(api, tcfg, optimizer)
        opt_state = (optimizer.state_shapes(params),
                     None,
                     jax.ShapeDtypeStruct((), jnp.int32))
        fn = lambda p, o, b: step(p, o, b)
        return fn, (params, opt_state, inputs), (0, 1)

    if shape.kind == "prefill":
        cache = api.cache_shapes(shape.global_batch, shape.seq_len,
                                 mesh=mesh)
        fn = lambda p, b, c: api.prefill(p, b, c)
        return fn, (params, inputs, cache), (2,)

    # decode: one new token against a seq_len-deep cache, sampled with the
    # paper's distributed top-k over the vocab shards.
    cache = api.cache_shapes(shape.global_batch, shape.seq_len, mesh=mesh)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    fn = lambda p, t, c, k: api.serve_step(
        p, t, c, k, mesh=mesh, top_k=64, sampler=sampler,
        num_pivots=num_pivots)
    return fn, (params, inputs["token"], cache, key), (2,)


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def run_cell(arch: str, shape, multi_pod: bool, *, sampler="selection",
             num_pivots=1, grad_accum=None, results_dir=None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}|{shape.name}|{mesh_name}"
    cfg = configs.get(arch)
    api = build_model(cfg)

    if shape not in shapes_for(cfg):
        rec = {"cell": cell_id, "status": "SKIP",
               "reason": "full-attention arch: long_500k requires a "
                         "sub-quadratic backbone (DESIGN.md Section 4)"}
        _save(rec, results_dir, cell_id)
        print(json.dumps(rec))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.perf_counter()
    try:
        model_ways = dict(mesh.shape).get("model", 1)
        with set_mesh(mesh), use_rules(
                rules_for_shape(shape, cfg, model_ways=model_ways)):
            fn, args, donate = build_cell(
                api, shape, mesh, sampler=sampler, num_pivots=num_pivots,
                grad_accum=grad_accum)
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            mem = compiled.memory_analysis()
            # trip-aware roofline (hlo_counter); cost_analysis kept as the
            # body-once secondary signal.
            roof = hlo_counter.roofline_from_text(
                compiled.as_text(), chips,
                model_flops=model_flops(cfg, shape))
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            rec = {
                "cell": cell_id,
                "status": "OK",
                "chips": chips,
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "memory": {
                    "argument_bytes_per_device": getattr(
                        mem, "argument_size_in_bytes", None),
                    "output_bytes_per_device": getattr(
                        mem, "output_size_in_bytes", None),
                    "temp_bytes_per_device": getattr(
                        mem, "temp_size_in_bytes", None),
                    "peak_ok_16gb": _peak_ok(mem),
                },
                "roofline": roof.summary(),
                "cost_analysis_body_once": {
                    "flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0)),
                },
            }
    except Exception as e:  # a failing cell is a bug — record loudly
        rec = {"cell": cell_id, "status": "FAIL",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    _save(rec, results_dir, cell_id)
    print(json.dumps({k: v for k, v in rec.items() if k != "trace"}))
    return rec


def _peak_ok(mem) -> bool | None:
    try:
        tot = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes)
        return bool(tot < 16 * 2**30)
    except Exception:
        return None


def _save(rec, results_dir, cell_id):
    if results_dir:
        os.makedirs(results_dir, exist_ok=True)
        safe = cell_id.replace("|", "__").replace(".", "_")
        with open(os.path.join(results_dir, f"{safe}.json"), "w") as f:
            json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    choices=ARCHS + [None])
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sampler", default="selection",
                    choices=["selection", "gather"])
    ap.add_argument("--num-pivots", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--results-dir", default="results/dryrun")
    args = ap.parse_args()

    archs = args.arch or (ARCHS if args.all else ["qwen2-0.5b"])
    shape_names = args.shape or [s.name for s in ALL_SHAPES]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for s in ALL_SHAPES:
            if s.name not in shape_names:
                continue
            for mp in meshes:
                run_cell(arch, s, mp, sampler=args.sampler,
                         num_pivots=args.num_pivots,
                         grad_accum=args.grad_accum,
                         results_dir=args.results_dir)


if __name__ == "__main__":
    main()
