from repro.data.synthetic import (MarkovTokens, uniform_points,
                                  gaussian_clusters, sharded_clusters,
                                  drifting_clusters, labeled_mixture,
                                  bayes_labels)
from repro.data.pipeline import Prefetcher, lm_batch_specs

__all__ = ["MarkovTokens", "uniform_points", "gaussian_clusters",
           "sharded_clusters", "drifting_clusters", "labeled_mixture",
           "bayes_labels", "Prefetcher", "lm_batch_specs"]
