from repro.data.synthetic import (MarkovTokens, uniform_points,
                                  gaussian_clusters, sharded_clusters,
                                  drifting_clusters)
from repro.data.pipeline import Prefetcher, lm_batch_specs

__all__ = ["MarkovTokens", "uniform_points", "gaussian_clusters",
           "sharded_clusters", "drifting_clusters", "Prefetcher",
           "lm_batch_specs"]
