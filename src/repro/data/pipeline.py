"""Sharded input pipeline with prefetch — the straggler-absorbing layer.

At thousand-node scale the input pipeline is where stragglers first show:
one slow host stalls the synchronous step.  Mitigations implemented here:

  * background prefetch thread with a bounded queue (depth `prefetch`):
    transient host hiccups are absorbed by the buffer instead of the step;
  * per-batch produce-time telemetry with a p95 watchdog hook — the
    runtime's `StepWatchdog` (runtime/metrics.py) consumes it and flags
    hosts whose produce time degrades (the documented eviction trigger);
  * device placement (`jax.device_put` with the batch NamedSharding)
    happens on the consumer side so H2D transfer overlaps the previous
    step's compute (double buffering).
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import jax
from jax.sharding import NamedSharding

from repro.models import sharding as shd


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], dict], *,
                 start_step: int = 0, prefetch: int = 2,
                 mesh=None, batch_specs: Optional[dict] = None):
        self._make = make_batch
        self._mesh = mesh
        self._specs = batch_specs
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._step = start_step
        self._stop = threading.Event()
        self.produce_times: list[float] = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            t0 = time.perf_counter()
            batch = self._make(step)
            self.produce_times.append(time.perf_counter() - t0)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        if self._mesh is not None and self._specs is not None:
            batch = {
                k: jax.device_put(
                    v, NamedSharding(self._mesh, self._specs[k]))
                for k, v in batch.items()
            }
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def lm_batch_specs():
    """PartitionSpecs for the standard LM batch dict."""
    return {
        "tokens": shd.spec("batch", None),
        "labels": shd.spec("batch", None),
    }
