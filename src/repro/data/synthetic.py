"""Deterministic synthetic data: token streams (LM) and point clouds (kNN).

The LM stream is a learnable order-2 Markov chain over the vocab (seeded,
reproducible across restarts — resuming from a checkpoint at step s
regenerates exactly the batches after s, which the fault-tolerance tests
rely on).  The kNN point generator mirrors the paper's experiment
(Section 3: uniform points in [0, 2^32)), generalized to d dimensions.
"""

from __future__ import annotations

import numpy as np


class MarkovTokens:
    """Order-2 Markov token stream: p(x_t | x_{t-1}, x_{t-2}) concentrated
    on a few successors, so a small LM's loss falls quickly below the
    uniform baseline (the train-smoke criterion)."""

    def __init__(self, vocab: int, seed: int = 0, branch: int = 4,
                 n_contexts: int = 61):
        self.vocab = vocab
        self.branch = branch
        self.n_contexts = n_contexts
        rng = np.random.default_rng(seed)
        # successor table: for each (prev mixed hash) a few allowed tokens
        self._succ = rng.integers(0, vocab, size=(n_contexts, branch),
                                  dtype=np.int64)

    def batch(self, step: int, batch: int, seq_len: int):
        """Returns (tokens, labels) int32 of shape (batch, seq_len)."""
        rng = np.random.default_rng((step << 20) + 17)
        out = np.empty((batch, seq_len + 1), np.int64)
        out[:, 0] = rng.integers(0, self.vocab, batch)
        out[:, 1] = rng.integers(0, self.vocab, batch)
        choices = rng.integers(0, self.branch, size=(batch, seq_len + 1))
        for t in range(2, seq_len + 1):
            h = (out[:, t - 1] * 31 + out[:, t - 2]) % self.n_contexts
            out[:, t] = self._succ[h, choices[:, t]]
        tokens = out[:, :-1].astype(np.int32)
        labels = out[:, 1:].astype(np.int32)
        return tokens, labels

    @property
    def entropy_floor(self) -> float:
        """Ideal CE of the stream (log branch) — the learnability target."""
        return float(np.log(self.branch))


def uniform_points(n: int, dim: int, seed: int = 0,
                   high: float = 2**32 - 1) -> np.ndarray:
    """The paper's dataset: n points uniform in [0, high)^dim (f32)."""
    rng = np.random.default_rng(seed)
    return (rng.random((n, dim)) * high).astype(np.float32)


def gaussian_clusters(n: int, dim: int, num_classes: int, seed: int = 0):
    """Labeled clusters for the kNN classification example."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(scale=8.0, size=(num_classes, dim))
    labels = rng.integers(0, num_classes, n)
    pts = centers[labels] + rng.normal(size=(n, dim))
    return pts.astype(np.float32), labels.astype(np.int32)


def labeled_mixture(n: int, dim: int, num_classes: int, *,
                    separation: float = 6.0, seed: int = 0):
    """Equal-prior isotropic Gaussian mixture with known Bayes-optimal
    labels — the prediction plane's benchmark workload.

    ``num_classes`` unit-variance isotropic components at mutual
    distance ~``separation``, equal priors: for that family the Bayes
    rule is exactly "nearest component center" (equal covariances and
    priors cancel in the likelihood ratio), so :func:`bayes_labels`
    gives the true optimum any predictor is scored against, and
    ``separation`` dials the Bayes error from coin-flip (0) to
    negligible (>= 8).  Returns ``(points (n, dim) f32, labels (n,)
    int32, centers (num_classes, dim) f64)`` — labels are the
    *component* assignments (identical to the Bayes label for all but
    the overlap-region points).  Seeded and deterministic: every
    (n, dim, num_classes, separation, seed) tuple replays the same
    instance, so the bench, the CI gate, and the property harness all
    score against the same ground truth.
    """
    rng = np.random.default_rng(seed)
    # Centers: random directions pushed to ~separation from the
    # centroid, so pairwise gaps scale with `separation`, not dim.
    raw = rng.normal(size=(num_classes, dim))
    raw = raw - raw.mean(axis=0)
    centers = raw / np.maximum(
        np.linalg.norm(raw, axis=1, keepdims=True), 1e-30) * separation
    labels = rng.integers(0, num_classes, n)
    pts = centers[labels] + rng.normal(size=(n, dim))
    return pts.astype(np.float32), labels.astype(np.int32), centers


def bayes_labels(points, centers) -> np.ndarray:
    """The Bayes-optimal label of each point under the
    :func:`labeled_mixture` family: the nearest component center
    (equal priors + equal isotropic covariances ⇒ the likelihood-ratio
    rule reduces to nearest-center; f64 host math, ties broken toward
    the lowest class like every vote in this repo)."""
    pts = np.asarray(points, np.float64)
    d = ((pts[:, None, :] - np.asarray(centers)[None]) ** 2).sum(-1)
    return d.argmin(axis=1).astype(np.int32)


def drifting_clusters(k: int, per_step: int, dim: int, *, steps: int,
                      drift: float = 4.0, scale: float = 12.0,
                      seed: int = 0):
    """Drifting-cluster stream: k gaussian clusters whose centers take a
    length-``drift`` random-walk step between emissions — the workload
    where reactive affinity placement goes stale and incremental summary
    radii inflate along the walked path (the adaptive-maintenance A/B,
    benchmarks/bench_serve.py; also driven by tests/test_adaptive.py).

    Yields ``steps`` pairs of (points (k·per_step, dim) f32 cluster-major
    — rows [c·per_step, (c+1)·per_step) near that step's centers[c] —
    and centers (k, dim) f64 *as used for that batch*).  Seeded and
    deterministic: the same (k, per_step, dim, steps, drift, scale, seed)
    always replays the same stream, so benchmark variants and tests can
    ingest the identical points under different store policies.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(scale=scale, size=(k, dim))
    for _ in range(steps):
        pts = np.concatenate(
            [centers[c] + rng.normal(size=(per_step, dim))
             for c in range(k)])
        yield pts.astype(np.float32), centers.copy()
        step = rng.normal(size=(k, dim))
        centers = centers + drift * step / np.maximum(
            np.linalg.norm(step, axis=1, keepdims=True), 1e-30)


def sharded_clusters(k: int, per_shard: int, dim: int, *, scale: float = 8.0,
                     shift: float = 0.0, seed: int = 0, rng=None):
    """One gaussian cluster per shard, laid out contiguously — the
    routing-friendly workload (shard j owns rows [j·m, (j+1)·m), all near
    centers[j]).  Used by the exactness harness (tests/test_routing.py)
    and the routing benches, which must measure the same instance family.

    ``shift`` pushes every center away from the origin (the f32
    catastrophic-cancellation stress).  Returns (points (k·m, dim) f32,
    centers (k, dim) f64).
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    centers = rng.normal(scale=scale, size=(k, dim)) + shift
    pts = np.concatenate(
        [centers[j] + rng.normal(size=(per_shard, dim)) for j in range(k)])
    return pts.astype(np.float32), centers
