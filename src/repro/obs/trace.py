"""Flight-recorder tracing: monotonic-clock spans in a ring buffer.

Zero-dependency (stdlib only) by design — this module is imported by the
hot serving path (`runtime/knn_server.py`), the mutable store, and the
background maintenance worker, so it must never pull jax/numpy into a
layer that doesn't already have them and must cost ~nothing when
disabled.

Model
-----
A **span** is one timed operation: ``(trace_id, span_id, parent_id,
name, t0, t1, attrs)``.  Times are ``time.perf_counter()`` floats (the
monotonic clock — immune to wall-clock steps; every span in one process
shares the clock, so cross-thread interleavings are directly
comparable).  Spans form trees through ``parent_id``; a span with
``parent_id=None`` roots a new trace and its ``trace_id`` is its own
``span_id``.  Cross-tree references (a request span pointing at the
micro-batch dispatch span that carried it) go through *attributes*, not
parent links, so every tree stays single-rooted and well-formed.

Two ways to produce a span:

* ``begin(name, ...)`` / ``Span.end(...)`` — for operations that start
  and finish in different stack frames (or different threads: a request
  span begins in ``submit()`` on the caller's thread and ends in the
  micro-batcher's resolve loop).
* ``record(name, t0, t1, ...)`` — retroactive: for intervals whose
  endpoints were already measured (the queued interval is
  ``t_enqueue → t_dispatch``, both captured anyway).
* ``span(name, ...)`` — context-manager sugar over begin/end for
  same-frame intervals.

The recorder is a fixed-capacity ring (`collections.deque(maxlen=...)`):
a long-running server never grows without bound, the newest spans win —
flight-recorder semantics.  ``export_jsonl()`` dumps the ring, one JSON
object per line, for offline assembly into trees.

``NULL_TRACER`` is the disabled plane: every call funnels to a shared
no-op span, no lock, no allocation — the `obs=off` arm the ≤10%
overhead guard (tests/test_obs.py) compares against.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Optional

_ids = itertools.count(1)      # process-wide: span ids unique across tracers


class Span:
    """One in-flight (or finished) span.  End it exactly once."""

    __slots__ = ("tracer", "name", "span_id", "trace_id", "parent_id",
                 "t0", "t1", "attrs")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 trace_id: int, parent_id: Optional[int], t0: float,
                 attrs: dict):
        self.tracer = tracer
        self.name = name
        self.span_id = span_id
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    def end(self, **attrs) -> "Span":
        """Finish the span (idempotent: a second end is ignored)."""
        if self.t1 is None:
            self.t1 = time.perf_counter()
            if attrs:
                self.attrs.update(attrs)
            self.tracer._finish(self)
        return self

    def annotate(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    # context-manager sugar (see Tracer.span)
    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self.end()
        return False


class _NullSpan:
    """Shared no-op span: the disabled tracer hands this out everywhere."""

    __slots__ = ()
    span_id = 0
    trace_id = 0
    parent_id = None

    def end(self, **attrs):
        return self

    def annotate(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffer span recorder; see module docstring.

    Thread-safe: ``begin``/``record`` may race from the submitting
    thread, the micro-batcher, the maintenance worker, and mutators —
    the ring append and the active-span accounting share one lock.
    """

    enabled = True

    def __init__(self, capacity: int = 8192):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._active = 0          # begun, not yet ended (torn-span probe)
        self.dropped = 0          # spans evicted by the ring

    # ---- producing spans -------------------------------------------------

    def begin(self, name: str, *, parent=None, t0: Optional[float] = None,
              **attrs) -> Span:
        """Start a span now (or at ``t0``).  ``parent`` is a Span (or
        None to root a new trace)."""
        sid = next(_ids)
        if parent is None or parent.span_id == 0:
            trace_id, parent_id = sid, None
        else:
            trace_id, parent_id = parent.trace_id, parent.span_id
        span = Span(self, name, sid, trace_id, parent_id,
                    time.perf_counter() if t0 is None else t0, attrs)
        with self._lock:
            self._active += 1
        return span

    def span(self, name: str, *, parent=None, **attrs) -> Span:
        """``with tracer.span("kernel", parent=dspan): ...``"""
        return self.begin(name, parent=parent, **attrs)

    def record(self, name: str, t0: float, t1: float, *, parent=None,
               **attrs) -> Span:
        """Retroactive span: both endpoints already measured."""
        span = self.begin(name, parent=parent, t0=t0, **attrs)
        span.t1 = t1
        self._finish(span)
        return span

    def _finish(self, span: Span) -> None:
        rec = {"trace": span.trace_id, "span": span.span_id,
               "parent": span.parent_id, "name": span.name,
               "t0": span.t0, "t1": span.t1}
        if span.attrs:
            rec["attrs"] = span.attrs
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(rec)
            self._active -= 1

    # ---- reading ---------------------------------------------------------

    def spans(self) -> list:
        """Snapshot of the finished-span ring (oldest first)."""
        with self._lock:
            return list(self._ring)

    def active_count(self) -> int:
        """Spans begun but not yet ended — 0 after a clean quiesce (the
        no-torn-spans probe tests/test_obs.py asserts on)."""
        with self._lock:
            return self._active

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped = 0

    def stats(self) -> dict:
        with self._lock:
            return {"enabled": True, "capacity": self.capacity,
                    "recorded": len(self._ring), "dropped": self.dropped,
                    "active": self._active}

    def export_jsonl(self, path_or_file) -> int:
        """Write the ring as JSONL (one span object per line); returns
        the number of spans written."""
        recs = self.spans()
        if hasattr(path_or_file, "write"):
            for r in recs:
                path_or_file.write(json.dumps(r) + "\n")
        else:
            with open(path_or_file, "w") as f:
                for r in recs:
                    f.write(json.dumps(r) + "\n")
        return len(recs)


class NullTracer:
    """The disabled plane: every producer call returns the shared no-op
    span.  No lock, no allocation — obs=off costs one attribute load and
    one call per instrumentation point."""

    enabled = False
    capacity = 0
    dropped = 0

    def begin(self, name, *, parent=None, t0=None, **attrs):
        return _NULL_SPAN

    def span(self, name, *, parent=None, **attrs):
        return _NULL_SPAN

    def record(self, name, t0, t1, *, parent=None, **attrs):
        return _NULL_SPAN

    def spans(self):
        return []

    def active_count(self):
        return 0

    def clear(self):
        pass

    def stats(self):
        return {"enabled": False, "capacity": 0, "recorded": 0,
                "dropped": 0, "active": 0}

    def export_jsonl(self, path_or_file):
        return 0


NULL_TRACER = NullTracer()


def build_trees(records: list) -> dict:
    """Assemble exported span records into ``{trace_id: [records]}`` and
    verify well-formedness; offline helper for tests and the obs-smoke
    checker.  Raises ValueError on a malformed forest (orphaned parent,
    unfinished span, child outside its parent's interval)."""
    by_id = {r["span"]: r for r in records}
    trees: dict = {}
    eps = 5e-4          # perf_counter jitter guard between threads
    for r in records:
        if r["t1"] is None:
            raise ValueError(f"unfinished span exported: {r}")
        if r["t1"] + eps < r["t0"]:
            raise ValueError(f"span ends before it starts: {r}")
        if r["parent"] is not None:
            parent = by_id.get(r["parent"])
            if parent is None:
                raise ValueError(f"orphaned span (parent evicted?): {r}")
            if parent["trace"] != r["trace"]:
                raise ValueError(f"span crosses traces: {r}")
            if (r["t0"] + eps < parent["t0"]
                    or r["t1"] > parent["t1"] + eps):
                raise ValueError(
                    f"child outside parent interval: {r} vs {parent}")
        trees.setdefault(r["trace"], []).append(r)
    return trees
