"""Config-declared SLO engine: multi-window burn-rate alerting over the
metrics registry's sliding windows.

The flight recorder (trace ring, histograms, auditors) answers "what
happened"; this module answers the operator's standing question — "are
we *currently* violating what we promised?" — for five promises the
config can declare (configs/knn_service.py ``slo_*`` knobs):

* ``latency_p99`` — per-request end-to-end latency bound (seconds),
* ``recall_min`` — shadow-audited minimum recall@l floor (approx tier),
* ``label_agreement`` — shadow-audited ensemble-vs-exact label
  agreement floor (ensemble prediction tier),
* ``staleness`` — answer generation lag behind the store head
  (generations; an epoch-swapped server normally serves lag 0/1),
* ``contract`` — Theorem-1 round/message envelope verdicts (any
  violation is bad).

Mechanics are the standard SRE multi-window burn rate: every
measurement becomes a good/bad event in a :class:`~repro.obs.metrics.
Window` (``slo.events.<name>``), the bad fraction over a window divided
by the error ``budget`` is the burn rate, and an alert **fires** only
when both the fast and the slow window burn above ``threshold`` (fast
window for responsiveness, slow window so a single bad blip can't
page) with at least ``_MIN_EVENTS`` events each — and **clears** when
the fast window's burn drops back under threshold (or drains empty).
Alert transitions are emitted as spans into the existing trace ring —
``slo.fire`` / ``slo.clear`` as zero-length marks at the transition,
plus one ``slo.alert`` span covering the whole fired interval on clear
— so alert history rides the same flight recorder as everything else,
and as ``slo.alerts_fired`` / ``slo.alerts_cleared`` counters in the
registry.  ``snapshot()`` (surfaced via ``KnnServer.obs_snapshot()
["slo"]``) evaluates first, so a read is never stale.

Clocks: observations and evaluation share one monotonic timebase;
every entry point takes an explicit ``now``/``t`` so tests replay a
synthetic stream deterministically (tests/test_operator.py drives a
fake clock through fire and clear).  Stdlib-only, like the rest of the
obs plane's hot-path modules.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

from repro.obs.metrics import MetricsRegistry

_MIN_EVENTS = 4          # windows thinner than this can't page


class SloObjective:
    """One declared promise: ``value`` is bad when it crosses ``bound``
    in the ``kind`` direction ("upper": bad above; "lower": bad below).
    """

    __slots__ = ("name", "kind", "bound")

    def __init__(self, name: str, kind: str, bound: float):
        if kind not in ("upper", "lower"):
            raise ValueError(f"kind must be 'upper' or 'lower', "
                             f"got {kind!r}")
        self.name = name
        self.kind = kind
        self.bound = float(bound)

    def is_bad(self, value: float) -> bool:
        return (value > self.bound if self.kind == "upper"
                else value < self.bound)


class SloEngine:
    """Burn-rate evaluator over declared objectives; see module
    docstring.  Thread-safe: ``measure`` races from the micro-batcher
    and callers' flushes; the fire/clear state machine runs under one
    lock."""

    def __init__(self, registry: MetricsRegistry, tracer, objectives,
                 *, fast_window_s: float = 60.0,
                 slow_window_s: float = 300.0,
                 burn_threshold: float = 1.0,
                 budget: float = 0.01):
        if budget <= 0.0:
            raise ValueError(f"budget must be > 0, got {budget}")
        if not objectives:
            raise ValueError("an SloEngine needs at least one objective "
                             "(use from_config, which returns None when "
                             "nothing is declared)")
        self.registry = registry
        self.tracer = tracer
        self.fast_window_s = float(fast_window_s)
        self.slow_window_s = float(slow_window_s)
        self.burn_threshold = float(burn_threshold)
        self.budget = float(budget)
        self._objectives = {o.name: o for o in objectives}
        retain = max(self.slow_window_s, self.fast_window_s) * 3.0
        self._windows = {}
        for name in self._objectives:
            w = registry.window(f"slo.events.{name}")
            w.max_age_s = max(w.max_age_s, retain)
            self._windows[name] = w
        self._fired: dict = {}            # name -> fired_at (monotonic)
        self._lock = threading.Lock()
        self._fired_total = registry.counter("slo.alerts_fired")
        self._cleared_total = registry.counter("slo.alerts_cleared")

    # ---- construction ----------------------------------------------------

    @classmethod
    def from_config(cls, cfg, registry: MetricsRegistry,
                    tracer) -> Optional["SloEngine"]:
        """The declared engine, or None when no ``slo_*`` knob enables
        an objective (the common case — SLOs are opt-in)."""
        objectives = []
        if getattr(cfg, "slo_latency_p99_s", 0.0) > 0.0:
            objectives.append(SloObjective(
                "latency_p99", "upper", cfg.slo_latency_p99_s))
        if getattr(cfg, "slo_recall_floor", 0.0) > 0.0:
            objectives.append(SloObjective(
                "recall_min", "lower", cfg.slo_recall_floor))
        if getattr(cfg, "slo_label_agreement_floor", 0.0) > 0.0:
            objectives.append(SloObjective(
                "label_agreement", "lower",
                cfg.slo_label_agreement_floor))
        if getattr(cfg, "slo_staleness_generations", 0) > 0:
            objectives.append(SloObjective(
                "staleness", "upper", cfg.slo_staleness_generations))
        if getattr(cfg, "slo_contract_violations", False):
            objectives.append(SloObjective("contract", "upper", 0.0))
        if not objectives:
            return None
        return cls(
            registry, tracer, objectives,
            fast_window_s=getattr(cfg, "slo_fast_window_s", 60.0),
            slow_window_s=getattr(cfg, "slo_slow_window_s", 300.0),
            burn_threshold=getattr(cfg, "slo_burn_threshold", 1.0),
            budget=getattr(cfg, "slo_budget", 0.01))

    # ---- producing -------------------------------------------------------

    def measure(self, name: str, value: float,
                now: Optional[float] = None) -> None:
        """Feed one measurement to objective ``name`` (unknown names are
        ignored — producers report what they have, the config decides
        what is promised)."""
        obj = self._objectives.get(name)
        if obj is None:
            return
        self._windows[name].observe(
            1.0 if obj.is_bad(float(value)) else 0.0, t=now)

    # ---- evaluating ------------------------------------------------------

    def _burn(self, win: dict) -> float:
        """Burn rate of one window aggregate: bad fraction over budget
        (0.0 for an empty window — no evidence is not a violation)."""
        if win["count"] == 0:
            return 0.0
        return (win["sum"] / win["count"]) / self.budget

    def evaluate(self, now: Optional[float] = None) -> list:
        """Run the fire/clear state machine once; returns the list of
        transition events this evaluation produced (empty when nothing
        changed)."""
        now = time.monotonic() if now is None else float(now)
        events = []
        with self._lock:
            for name, obj in sorted(self._objectives.items()):
                w = self._windows[name]
                fast = w.window(self.fast_window_s, now)
                slow = w.window(self.slow_window_s, now)
                burn_fast = self._burn(fast)
                burn_slow = self._burn(slow)
                fired_at = self._fired.get(name)
                breach = (fast["count"] >= _MIN_EVENTS
                          and slow["count"] >= _MIN_EVENTS
                          and burn_fast > self.burn_threshold
                          and burn_slow > self.burn_threshold)
                if fired_at is None and breach:
                    self._fired[name] = now
                    self._fired_total.inc()
                    self.tracer.record(
                        "slo.fire", now, now, objective=name,
                        bound=obj.bound, kind=obj.kind,
                        burn_fast=burn_fast, burn_slow=burn_slow,
                        fast_events=fast["count"],
                        slow_events=slow["count"])
                    events.append({"objective": name, "event": "fire",
                                   "burn_fast": burn_fast,
                                   "burn_slow": burn_slow, "at": now})
                elif fired_at is not None and (
                        fast["count"] == 0
                        or burn_fast <= self.burn_threshold):
                    del self._fired[name]
                    self._cleared_total.inc()
                    self.tracer.record(
                        "slo.clear", now, now, objective=name,
                        burn_fast=burn_fast,
                        fired_for_s=now - fired_at)
                    # the whole fired interval as one span, so trace
                    # tooling sees alert duration without event pairing
                    self.tracer.record(
                        "slo.alert", fired_at, now, objective=name,
                        bound=obj.bound, kind=obj.kind)
                    events.append({"objective": name, "event": "clear",
                                   "burn_fast": burn_fast, "at": now,
                                   "fired_for_s": now - fired_at})
        return events

    def snapshot(self, now: Optional[float] = None) -> dict:
        """Evaluate, then report per-objective state plus lifetime alert
        counters — the ``obs_snapshot()["slo"]`` payload."""
        now = time.monotonic() if now is None else float(now)
        self.evaluate(now)
        with self._lock:
            objectives = {}
            for name, obj in sorted(self._objectives.items()):
                w = self._windows[name]
                fast = w.window(self.fast_window_s, now)
                slow = w.window(self.slow_window_s, now)
                objectives[name] = {
                    "bound": obj.bound,
                    "kind": obj.kind,
                    "firing": name in self._fired,
                    "burn_fast": self._burn(fast),
                    "burn_slow": self._burn(slow),
                    "fast_events": fast["count"],
                    "slow_events": slow["count"],
                    "bad_fast": fast["sum"],
                    "bad_slow": slow["sum"],
                }
            return {
                "budget": self.budget,
                "burn_threshold": self.burn_threshold,
                "fast_window_s": self.fast_window_s,
                "slow_window_s": self.slow_window_s,
                "alerts_fired": self._fired_total.snapshot(),
                "alerts_cleared": self._cleared_total.snapshot(),
                "firing": sorted(self._fired),
                "objectives": objectives,
            }
