"""Standard exporters for the metrics registry: Prometheus text
exposition, OTLP-ish JSON, and a tiny stdlib HTTP endpoint.

Naming is stable and mechanical: registry name ``serve.latency_s``
becomes ``knn_serve_latency_s`` (``knn_`` prefix, dots → underscores),
counters get the conventional ``_total`` suffix.  The repo's geometric
histograms (obs/metrics.py) export losslessly: each occupied bucket's
exclusive upper edge becomes a cumulative ``le`` bound, the underflow
(observations <= 0) folds into every cumulative count (it sorts below
every positive edge), and ``+Inf`` equals the observation count — so
:func:`parse_prometheus_text` can re-derive count/sum per metric and
verify bucket monotonicity, which is exactly what the golden-format
round-trip test and the obs-smoke gate do.  Sliding windows
(obs/slo.py's event streams) are point-in-time constructs, not
cumulative series, so the exporters skip them.

The OTLP-ish JSON mirrors the opentelemetry metrics data model
(resourceMetrics → scopeMetrics → metrics with sum/gauge/histogram
data points, cumulative temporality, non-cumulative bucketCounts with
``len(explicitBounds) + 1`` entries) closely enough for a collector to
ingest, without pretending to be a pinned proto rev.

:class:`ObsHttpServer` serves ``/metrics`` (Prometheus text),
``/metrics.json`` (OTLP-ish), and ``/obs`` (a full snapshot callback —
the server wires ``obs_snapshot`` in) on ``cfg.obs_http_port`` via a
daemonized stdlib ``ThreadingHTTPServer``; port 0 binds ephemerally
(tests) and the knob's default 0 means "don't serve".
"""

from __future__ import annotations

import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

PREFIX = "knn_"


def metric_name(name: str) -> str:
    """Registry name -> exposition name (stable, mechanical)."""
    return PREFIX + name.replace(".", "_").replace("-", "_")


def _fmt(v: float) -> str:
    """Prometheus float formatting that round-trips through float()."""
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


# ---- Prometheus text exposition ------------------------------------------


def prometheus_text(registry: MetricsRegistry) -> str:
    """The registry as Prometheus text exposition format v0.0.4."""
    lines = []
    for name, metric in registry.items():
        pname = metric_name(name)
        if isinstance(metric, Counter):
            lines.append(f"# TYPE {pname}_total counter")
            lines.append(f"{pname}_total {metric.snapshot()}")
        elif isinstance(metric, Gauge):
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_fmt(metric.snapshot())}")
        elif isinstance(metric, Histogram):
            edges, underflow = metric.bucket_counts()
            with metric._lock:
                count, total = metric.count, metric.total
            lines.append(f"# TYPE {pname} histogram")
            cum = underflow
            for edge, c in edges:
                cum += c
                lines.append(
                    f'{pname}_bucket{{le="{_fmt(edge)}"}} {cum}')
            lines.append(f'{pname}_bucket{{le="+Inf"}} {count}')
            lines.append(f"{pname}_sum {_fmt(total)}")
            lines.append(f"{pname}_count {count}")
        # Window: sliding event stream, not a cumulative series — skip.
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Parse an exposition back into ``{name: payload}`` and *validate*
    it: known TYPE per sample, cumulative ``le`` buckets monotone
    non-decreasing in both bound and count, ``+Inf`` bucket equal to
    ``_count``.  Raises ValueError on any malformation — this is the
    round-trip gate, not a lenient scraper."""
    types: dict = {}
    out: dict = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        name_part, _, value_part = line.rpartition(" ")
        if not name_part:
            raise ValueError(f"line {lineno}: no sample value: {line!r}")
        value = float(value_part)
        if "{" in name_part:
            base, _, label = name_part.partition("{")
            label = label.rstrip("}")
            if not base.endswith("_bucket") or not label.startswith('le="'):
                raise ValueError(f"line {lineno}: unsupported labeled "
                                 f"sample: {line!r}")
            hist = base[:-len("_bucket")]
            le = label[len('le="'):].rstrip('"')
            bound = math.inf if le == "+Inf" else float(le)
            out.setdefault(hist, {"type": "histogram", "buckets": []})
            out[hist]["buckets"].append((bound, value))
        elif name_part.endswith("_sum") and name_part[:-4] in out:
            out[name_part[:-4]]["sum"] = value
        elif name_part.endswith("_count") and name_part[:-6] in out:
            out[name_part[:-6]]["count"] = value
        else:
            t = types.get(name_part) or (
                "counter" if name_part.endswith("_total") else None)
            if t is None:
                raise ValueError(f"line {lineno}: sample {name_part!r} "
                                 f"has no TYPE declaration")
            out[name_part] = {"type": t, "value": value}
    for name, payload in out.items():
        if payload.get("type") != "histogram":
            continue
        if "count" not in payload or "sum" not in payload:
            raise ValueError(f"histogram {name!r} missing _sum/_count")
        buckets = payload["buckets"]
        if not buckets:
            raise ValueError(f"histogram {name!r} has no buckets")
        prev_bound, prev_cum = -math.inf, -math.inf
        for bound, cum in buckets:
            if bound <= prev_bound:
                raise ValueError(
                    f"histogram {name!r}: bounds not increasing at "
                    f"le={bound}")
            if cum < prev_cum:
                raise ValueError(
                    f"histogram {name!r}: cumulative count decreases at "
                    f"le={bound}")
            prev_bound, prev_cum = bound, cum
        if buckets[-1][0] != math.inf:
            raise ValueError(f"histogram {name!r}: missing +Inf bucket")
        if buckets[-1][1] != payload["count"]:
            raise ValueError(
                f"histogram {name!r}: +Inf bucket {buckets[-1][1]} != "
                f"count {payload['count']}")
    return out


# ---- OTLP-ish JSON -------------------------------------------------------


def otlp_json(registry: MetricsRegistry,
              service_name: str = "repro-knn") -> dict:
    """The registry in the opentelemetry metrics JSON shape (see module
    docstring for the fidelity disclaimer)."""
    metrics = []
    for name, metric in registry.items():
        pname = metric_name(name)
        if isinstance(metric, Counter):
            metrics.append({
                "name": pname + "_total",
                "sum": {"dataPoints": [{"asInt": int(metric.snapshot())}],
                        "isMonotonic": True,
                        "aggregationTemporality": 2}})
        elif isinstance(metric, Gauge):
            metrics.append({
                "name": pname,
                "gauge": {"dataPoints": [
                    {"asDouble": float(metric.snapshot())}]}})
        elif isinstance(metric, Histogram):
            edges, underflow = metric.bucket_counts()
            with metric._lock:
                count, total = metric.count, metric.total
            # bounds[0] = 0.0 so the first bucketCounts entry is exactly
            # the underflow (observations <= 0); every occupied
            # geometric bucket contributes (bounds[i-1], bounds[i]]
            # non-cumulatively; the final entry is the (empty) overflow.
            bounds = [0.0] + [e for e, _ in edges]
            bucket_counts = [underflow] + [c for _, c in edges] + [0]
            metrics.append({
                "name": pname,
                "histogram": {
                    "dataPoints": [{
                        "count": count,
                        "sum": total,
                        "explicitBounds": bounds,
                        "bucketCounts": bucket_counts}],
                    "aggregationTemporality": 2}})
    return {"resourceMetrics": [{
        "resource": {"attributes": [
            {"key": "service.name",
             "value": {"stringValue": service_name}}]},
        "scopeMetrics": [{
            "scope": {"name": "repro.obs", "version": "1"},
            "metrics": metrics}]}]}


# ---- HTTP endpoint -------------------------------------------------------


class ObsHttpServer:
    """Stdlib HTTP exposition endpoint; see module docstring.  Construct
    with ``port=0`` for an ephemeral port (``.port`` reports the bound
    one); ``close()`` is idempotent and joins the serving thread."""

    def __init__(self, registry: MetricsRegistry, *, port: int = 0,
                 host: str = "127.0.0.1",
                 snapshot_fn: Optional[Callable[[], dict]] = None):
        self.registry = registry
        self._snapshot_fn = snapshot_fn

        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                try:
                    if self.path == "/metrics":
                        body = prometheus_text(outer.registry).encode()
                        ctype = "text/plain; version=0.0.4"
                    elif self.path == "/metrics.json":
                        body = json.dumps(
                            otlp_json(outer.registry)).encode()
                        ctype = "application/json"
                    elif self.path == "/obs":
                        snap = (outer._snapshot_fn()
                                if outer._snapshot_fn is not None
                                else outer.registry.snapshot())
                        body = json.dumps(snap, default=str).encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404)
                        return
                except Exception as exc:    # surface, don't kill the thread
                    self.send_error(500, str(exc))
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):   # stay silent in tests/benches
                pass

        self._httpd = ThreadingHTTPServer((host, int(port)), _Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-http", daemon=True)
        self._thread.start()
        self._closed = False

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "ObsHttpServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
