"""Per-query explain reports: "why was *this* query slow / broad /
approximate?" answered from data the pipeline already computed.

The serving path (runtime/knn_server.py ``_dispatch``) captures one
:class:`BatchCapture` per micro-batch — cheap references to the frozen
objects the dispatch consumed (routing summaries, bucket index, the
padded query block) plus the scalars it produced (touched-shard count,
candidate fraction, stage timestamps, the maintenance-commit clock
before and after) — and hands every resolved request an
:class:`ExplainRecord` pointing at it.  Nothing heavy happens on the
hot path: the full report is assembled lazily by ``build()``, which
*recomputes* the per-shard lower/upper bounds and the routing threshold
T through :func:`repro.store.summaries.routing_detail` and the
per-bucket keep rule through :func:`repro.store.index.bucket_keep` —
both deterministic pure-f64 host math over the same frozen generation
the dispatch used, so the report shows the decision's working without
ever having taxed the dispatch that made it.

Report schema (``SCHEMA`` = ``knn.explain.v1``) is a plain dict of
python scalars/lists: ``batch`` (id, bucket, generation, touched,
contract verdict), ``request`` (row, l, recall_mode, content digests),
``routing`` (per-shard bounds + threshold + keep), ``index``
(per-bucket keep, recompute cross-check, candidate fraction),
``predict`` (the label answer, its mode and confidence, and — for
ensemble mode — the per-shard vote table and local-k split),
``timings`` (queue/snapshot/route/kernel/resolve stage seconds), and
``maintenance`` (whether a store commit raced the request, and which).
:func:`deterministic_json` serializes the *stable* subset — timings,
maintenance, and the batch id are run-volatile by nature — so the
same query at the same key and generation produces a byte-identical
string (tests/test_operator.py pins this).

Import discipline: this module is imported by ``repro.obs.__init__``,
which the mutable store's trace import makes a dependency of
``repro.store`` — so at import time this file is stdlib-only; numpy
and the store modules load lazily inside ``build()``.
"""

from __future__ import annotations

import hashlib
import json
from typing import Optional

SCHEMA = "knn.explain.v1"

# Report keys that legitimately differ between two otherwise-identical
# runs (wall-clock stage timings, the maintenance-commit clock) and the
# one batch field that does (the monotonically-assigned batch id).
_VOLATILE_KEYS = ("timings", "maintenance")


def _digest(arr) -> str:
    """Short content digest of an array-like (anything with tobytes())."""
    return hashlib.sha1(arr.tobytes()).hexdigest()[:16]


class BatchCapture:
    """Dispatch-time facts shared by every request in one micro-batch.

    Built once per ``_dispatch`` after the kernel returns; fields are
    references (frozen summaries/index, the dispatch's own padded query
    block) and scalars — no array copies, no recomputation.  ``timings``
    is filled in as the dispatch tail stamps its stages (reports are
    only built after the dispatch completes, so late fills are safe).
    """

    __slots__ = ("batch_id", "bucket", "n_real", "generation", "route",
                 "route_compute", "search", "slack", "oversample",
                 "queries", "ls", "summaries", "index", "active",
                 "keep_any", "touched", "candidate_fraction", "timings",
                 "maint_before", "maint_after", "maint_last",
                 "contract_ok", "predict", "predict_mode", "labels",
                 "confidences", "local_k", "shard_answers", "votes")

    def __init__(self, **kw):
        for name in self.__slots__:
            setattr(self, name, kw.pop(name, None))
        if kw:
            raise TypeError(f"unknown capture fields: {sorted(kw)}")


class ExplainRecord:
    """One request's handle into its batch capture; ``build()`` is the
    lazy, cached report assembly."""

    __slots__ = ("capture", "row", "l", "dists", "ids", "queued_s",
                 "latency_s", "_report")

    def __init__(self, capture: BatchCapture, row: int, *, l: int,
                 dists, ids, queued_s: float, latency_s: float):
        self.capture = capture
        self.row = int(row)
        self.l = int(l)
        self.dists = dists
        self.ids = ids
        self.queued_s = float(queued_s)
        self.latency_s = float(latency_s)
        self._report: Optional[dict] = None

    # ---- assembly --------------------------------------------------------

    def build(self) -> dict:
        if self._report is None:
            self._report = self._build()
        return self._report

    def _build(self) -> dict:
        import numpy as np      # lazy: module must import stdlib-only

        cap = self.capture
        routing, shard_keep = self._routing_section(np)
        report = {
            "schema": SCHEMA,
            "batch": {
                "id": int(cap.batch_id),
                "bucket": int(cap.bucket),
                "n_real": int(cap.n_real),
                "generation": int(cap.generation),
                "shards_touched": int(cap.touched),
                "contract_ok": bool(cap.contract_ok),
            },
            "request": {
                "row": self.row,
                "l": self.l,
                "recall_mode": ("approx" if cap.search == "approx"
                                else "exact"),
                "query_sha1": _digest(np.ascontiguousarray(
                    cap.queries[self.row])),
                "result_ids_sha1": _digest(np.ascontiguousarray(self.ids)),
                "result_dists_sha1": _digest(np.ascontiguousarray(
                    self.dists)),
            },
            "routing": routing,
            "index": self._index_section(np, shard_keep),
            "predict": self._predict_section(np),
            "timings": {
                "queued_s": self.queued_s,
                "latency_s": self.latency_s,
                **{k: v for k, v in (cap.timings or {}).items()},
            },
            "maintenance": {
                "commits_before": int(cap.maint_before or 0),
                "commits_after": int(cap.maint_after or 0),
                "raced_commit": bool((cap.maint_after or 0)
                                     > (cap.maint_before or 0)),
                "last_commit": cap.maint_last,
            },
        }
        return report

    def _routing_section(self, np):
        """(section dict, per-row shard-keep matrix or None).

        The bounds/threshold are *recomputed* through
        ``summaries.routing_detail`` — deterministic f64 host math over
        the frozen summaries the dispatch captured, so this is the
        dispatch-time decision with its working shown, not a new
        decision.  The batch's realized ``active`` union is reported
        beside it (identical for the host route; the device route's f32
        mask is parity-tested, tests/test_routing.py).
        """
        cap = self.capture
        sec = {"mode": cap.route, "compute": cap.route_compute,
               "slack": float(cap.slack or 0.0)}
        if cap.route != "pruned" or cap.summaries is None:
            sec.update(threshold=None, threshold_eff=None, shards=[],
                       kept_shards=[])
            return sec, None
        from repro.store import summaries as summaries_mod
        detail = summaries_mod.routing_detail(
            cap.summaries, cap.queries, cap.ls, slack=cap.slack)
        r = self.row
        keep_row = detail["keep"][r]
        sec["threshold"] = float(detail["threshold"][r])
        sec["threshold_eff"] = float(detail["threshold_eff"][r])
        sec["shards"] = [
            {"shard": int(j),
             "lower": float(detail["lower"][r, j]),
             "upper": float(detail["upper"][r, j]),
             "kept": bool(keep_row[j])}
            for j in range(keep_row.shape[0])]
        sec["kept_shards"] = [int(j) for j in np.flatnonzero(keep_row)]
        if cap.active is not None:
            sec["batch_active_shards"] = [
                int(j) for j in np.flatnonzero(np.asarray(cap.active))]
        return sec, detail["keep"]

    def _index_section(self, np, shard_keep):
        cap = self.capture
        if cap.search != "approx" or cap.index is None:
            return {"enabled": False}
        from repro.store import index as index_mod
        idx = cap.index
        keep = index_mod.bucket_keep(
            idx, cap.queries, cap.ls, shard_keep=shard_keep,
            oversample=cap.oversample)
        row_kept = [[int(s), int(b)]
                    for s, b in zip(*np.nonzero(keep[self.row]))]
        recomputed_any = keep.any(axis=0)
        sec = {
            "enabled": True,
            "num_buckets": int(idx.num_buckets),
            "oversample": float(cap.oversample),
            "candidate_fraction": (None if cap.candidate_fraction is None
                                   else float(cap.candidate_fraction)),
            "kept_buckets": row_kept,
            "recomputed_batch_kept": [
                [int(s), int(b)]
                for s, b in zip(*np.nonzero(recomputed_any))],
        }
        if cap.keep_any is not None:
            actual = np.asarray(cap.keep_any, bool)
            sec["batch_kept_buckets"] = [
                [int(s), int(b)] for s, b in zip(*np.nonzero(actual))]
            # Host path: the recompute IS the dispatch rule, so this is
            # an equality invariant.  Device path: the f32 kernel mirror
            # is allowed to differ (both are measured, DESIGN.md §13) —
            # the flag then honestly reports whether it did.
            sec["kept_matches_recompute"] = bool(
                (actual == recomputed_any).all())
        return sec

    def _predict_section(self, np):
        """The label answer with its working: mode, label, confidence;
        for ensemble mode additionally this row's local-k split, the
        per-shard answer table (class histogram per shard for "vote",
        [sum, count] per shard for "regress") and the shard-vote tally
        the majority was taken over — all captured from the dispatch's
        own aggregation inputs, no recomputation."""
        cap = self.capture
        if not cap.predict or cap.predict == "none":
            return {"enabled": False}
        r = self.row
        sec = {
            "enabled": True,
            "predict": cap.predict,
            "mode": cap.predict_mode,
            "label": float(np.asarray(cap.labels)[r]),
            "confidence": float(np.asarray(cap.confidences)[r]),
        }
        if cap.local_k is not None:
            sec["local_k"] = int(np.asarray(cap.local_k)[r])
        if cap.shard_answers is not None:
            table = np.asarray(cap.shard_answers)[:, r]      # (k, C|2)
            cast = int if cap.predict == "vote" else float
            sec["shard_answers"] = [[cast(v) for v in row]
                                    for row in table]
        if cap.votes is not None:
            sec["shard_votes"] = [int(v)
                                  for v in np.asarray(cap.votes)[r]]
        return sec


# ---- serialization -------------------------------------------------------


def deterministic_json(report: dict) -> str:
    """The stable subset of a report as canonical JSON: drop the
    run-volatile keys (stage timings, the maintenance clock) and the
    batch id, serialize sorted/compact.  Same query, same key, same
    generation ⇒ byte-identical string."""
    stable = {k: v for k, v in report.items() if k not in _VOLATILE_KEYS}
    batch = dict(stable.get("batch", {}))
    batch.pop("id", None)
    stable["batch"] = batch
    return json.dumps(stable, sort_keys=True, separators=(",", ":"))


def export_jsonl(reports, path_or_file) -> int:
    """Write explain reports (dicts or ExplainRecords) as JSONL; returns
    the number of lines written."""
    lines = []
    for r in reports:
        if isinstance(r, ExplainRecord):
            r = r.build()
        lines.append(json.dumps(r, sort_keys=True) + "\n")
    if hasattr(path_or_file, "write"):
        path_or_file.writelines(lines)
    else:
        with open(path_or_file, "w") as f:
            f.writelines(lines)
    return len(lines)
