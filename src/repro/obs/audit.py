"""Paper-contract auditors: turn Theorem 1's quantitative claims and the
bit-identical routing invariant into always-on production signals.

Two auditors, both feeding counters in a :class:`~repro.obs.metrics.
MetricsRegistry` (the serving layer wires them in `runtime/knn_server.py`;
``KnnServer.obs_snapshot()`` surfaces the verdicts; `make obs-smoke`
asserts both stay clean):

**ContractAuditor** — the round/message envelope.  The paper's headline
(arXiv 2005.07373) is O(log K) rounds and O(k·log K) messages per query
w.h.p., *regardless of n*, via the Lemma 2.3 sample-and-prune.  Every
dispatched micro-batch is checked against

    rounds   <= c · (log2(L+1) + log2(log2(n+2)+2)) + b
    messages <= (k−1) · rounds_bound

where L is the batch's largest request l and n the live point count of
the answering generation.  The ``log log n`` term is the honest cost of
the w.h.p. qualifier (sample-and-prune leaves Θ(L·poly(log n)) survivors
and selection concentration has Θ(√log)-scale tails); the defaults
``c=6, b=24`` sit ≥3× above the observed envelope on every benchmark
workload while staying ~5× *below* the deterministic iteration cap
(8·log2(n)+16 → ~276 rounds at the bench sizes), so a selection that
stops converging, a sampling prune that silently stops firing, or an
accounting regression trips the audit instead of hiding in a mean.
With ``use_sampling=False`` the claim degrades to Theorem 2.2's
O(log n), and the envelope follows (``c·log2(n+2)+b``); the gather
sampler has exact known costs (1 round, (k−1)·l_max messages) and is
checked against them directly.

**ShadowAuditor** — sampled exact replay.  The repo-wide invariant is
that pruned/device-routed answers are *bit-identical* to the exact
collective (tests/test_routing.py proves it offline).  This auditor
makes it a production signal: every Nth routed micro-batch is replayed
through the same executable with the all-shards-active mask — the exact
collective at the same generation, same key — and any byte divergence
in dists/ids is counted and detailed.  Sampling keeps the cost at
1/N extra datastore passes; N comes from the ``obs_audit_every`` knob.
Under ``search="approx"`` (DESIGN.md Section 13) bit-identity is no
longer the contract — the auditor's ``mode="recall"`` instead measures
recall@l of the served answer against the exact replay and flags any
batch whose minimum row recall dips below the configured floor.  Under
ensemble prediction (DESIGN.md Section 15) the served answer is a label
from one-message-per-shard local votes — ``mode="accuracy"`` measures
its agreement with the exact-fold replay's label and flags any batch
whose agreement fraction dips below the accuracy floor.

Zero-dependency: stdlib only (answers are compared through
``.tobytes()``, which any array provides).
"""

from __future__ import annotations

import math
import threading
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry

_MAX_DETAILS = 8          # violation/divergence details kept for debugging


class ContractAuditor:
    """Per-micro-batch Theorem-1 round/message envelope check."""

    def __init__(self, registry: MetricsRegistry, *, k: int,
                 c: float = 6.0, b: float = 24.0):
        self.k = int(k)
        self.c = float(c)
        self.b = float(b)
        self._checks = registry.counter("audit.contract.checks")
        self._violations = registry.counter("audit.contract.violations")
        self._lock = threading.Lock()
        self.details: list = []

    def rounds_bound(self, l_max: int, n_live: int, *,
                     use_sampling: bool, sampler: str) -> float:
        if sampler == "gather":
            return 1.0                      # one all-gather, exactly
        n = max(int(n_live), 0)
        if use_sampling:
            base = (math.log2(l_max + 1)
                    + math.log2(math.log2(n + 2) + 2))
        else:
            base = math.log2(n + 2)         # Theorem 2.2 regime
        return self.c * base + self.b

    def messages_bound(self, l_max: int, n_live: int, *,
                       use_sampling: bool, sampler: str) -> float:
        if sampler == "gather":
            return (self.k - 1) * l_max     # the simple method, exactly
        return (self.k - 1) * self.rounds_bound(
            l_max, n_live, use_sampling=use_sampling, sampler=sampler)

    def check(self, *, l_max: int, n_live: int, rounds: int, messages: int,
              use_sampling: bool, sampler: str, generation: int = -1) -> bool:
        """Audit one dispatched batch; returns True when within envelope.
        Counts every check; a violation is counted and detailed (bounded
        ring of the first/last few, for the snapshot)."""
        rb = self.rounds_bound(l_max, n_live, use_sampling=use_sampling,
                               sampler=sampler)
        mb = self.messages_bound(l_max, n_live, use_sampling=use_sampling,
                                 sampler=sampler)
        self._checks.inc()
        ok = rounds <= rb and messages <= mb
        if not ok:
            self._violations.inc()
            with self._lock:
                if len(self.details) >= _MAX_DETAILS:
                    self.details.pop(0)
                self.details.append({
                    "l_max": int(l_max), "n_live": int(n_live),
                    "rounds": int(rounds), "rounds_bound": rb,
                    "messages": int(messages), "messages_bound": mb,
                    "sampler": sampler, "generation": int(generation)})
        return ok

    def snapshot(self) -> dict:
        with self._lock:
            return {"checks": self._checks.snapshot(),
                    "violations": self._violations.snapshot(),
                    "c": self.c, "b": self.b,
                    "details": list(self.details)}


class ShadowAuditor:
    """Sampled exact-replay check for routed/indexed answers.

    Two comparison modes, matching the serving contract being audited:

    * ``mode="bytes"`` (default) — the pruned-routing invariant: served
      dists/ids must be *byte-identical* to the exact collective replay.
      Any divergence counts.
    * ``mode="recall"`` — the ``search="approx"`` contract: the bucket
      index is allowed to drop true neighbors, but measured recall@l
      (per real row: the fraction of the exact replay's finite top-l ids
      present in the served answer; rows with no finite exact ids are
      vacuously 1.0, which makes padding rows harmless) must stay at or
      above ``floor``.  A batch whose *minimum* row recall dips below
      the floor counts as a divergence; the observed minimum also feeds
      the ``audit.shadow.recall`` histogram so the snapshot reports the
      measured contract, not just pass/fail.
    * ``mode="accuracy"`` — the ensemble-prediction contract
      (``predict_mode="ensemble"``, predict/ensemble.py): the served
      label comes from per-shard local votes, so bit-identity to the
      exact vote is not promised — instead the agreement fraction over
      the batch's real rows (label equality vs the exact-fold replay;
      a batch with no real rows is vacuously 1.0) must stay at or above
      ``floor``.  Checked through :meth:`check_labels`; the observed
      fraction feeds the ``audit.shadow.agreement`` histogram.
    """

    def __init__(self, registry: MetricsRegistry, *, every: int,
                 mode: str = "bytes", floor: float = 0.95):
        if every < 1:
            raise ValueError("every must be >= 1 (use None/off upstream)")
        if mode not in ("bytes", "recall", "accuracy"):
            raise ValueError(f"mode must be 'bytes', 'recall' or "
                             f"'accuracy', got {mode!r}")
        self.every = int(every)
        self.mode = mode
        self.floor = float(floor)
        self._n = 0
        self._lock = threading.Lock()
        self._checks = registry.counter("audit.shadow.checks")
        self._divergences = registry.counter("audit.shadow.divergences")
        self._recall = (registry.histogram("audit.shadow.recall")
                        if mode == "recall" else None)
        self._agreement = (registry.histogram("audit.shadow.agreement")
                           if mode == "accuracy" else None)
        self.last_min_recall: Optional[float] = None
        self.last_agreement: Optional[float] = None
        self.details: list = []

    def due(self) -> bool:
        """Count one routed dispatch; True on every Nth (the first
        routed dispatch is audited, so short runs still audit)."""
        with self._lock:
            due = self._n % self.every == 0
            self._n += 1
            return due

    def check(self, served_dists, served_ids,
              exact_fn: Callable[[], tuple], *,
              generation: int = -1, batch_id: int = -1,
              touched: int = -1) -> bool:
        """Replay through ``exact_fn`` (the all-shards-active,
        all-candidates executable at the same generation/key) and
        compare per ``mode``; returns True when the contract holds."""
        exact_d, exact_i = exact_fn()
        detail = {}
        if self.mode == "bytes":
            ok = (served_dists.tobytes() == exact_d.tobytes()
                  and served_ids.tobytes() == exact_i.tobytes())
        else:
            min_recall = self._min_recall(served_ids, exact_i)
            self._recall.observe(min_recall)
            self.last_min_recall = min_recall
            ok = min_recall >= self.floor
            detail["min_recall"] = min_recall
        self._checks.inc()
        if not ok:
            self._divergences.inc()
            with self._lock:
                if len(self.details) >= _MAX_DETAILS:
                    self.details.pop(0)
                self.details.append({
                    "generation": int(generation),
                    "batch_id": int(batch_id),
                    "touched": int(touched), **detail})
        return ok

    def check_labels(self, served_labels, ls, exact_fn, *,
                     generation: int = -1, batch_id: int = -1,
                     touched: int = -1) -> bool:
        """``mode="accuracy"`` entry point: replay through ``exact_fn``
        (the exact-fold executable at the same generation/key, all
        shards active — returns the (B,) exact label vector) and measure
        the agreement fraction over the batch's real rows (``ls > 0``);
        returns True while it holds the floor."""
        if self.mode != "accuracy":
            raise RuntimeError(f"check_labels needs mode='accuracy', "
                               f"auditor is {self.mode!r}")
        exact = exact_fn()
        agree = total = 0
        for s, e, l in zip(served_labels.tolist(), exact.tolist(),
                           ls.tolist()):
            if l <= 0:
                continue                    # bucket padding: no answer owed
            total += 1
            agree += int(s == e)
        agreement = agree / total if total else 1.0
        self._agreement.observe(agreement)
        self.last_agreement = agreement
        self._checks.inc()
        ok = agreement >= self.floor
        if not ok:
            self._divergences.inc()
            with self._lock:
                if len(self.details) >= _MAX_DETAILS:
                    self.details.pop(0)
                self.details.append({
                    "generation": int(generation),
                    "batch_id": int(batch_id),
                    "touched": int(touched),
                    "agreement": agreement})
        return ok

    @staticmethod
    def _min_recall(served_ids, exact_ids) -> float:
        """Minimum per-row recall@l of the served answer against the
        exact replay.  Pure python over small (B, l) id buffers — this
        module stays numpy-free.  Sentinel ids (anything the exact
        replay reports that is also sentinel in the served row) are the
        INT32_MAX no-point markers both paths emit past rank l or past
        the finite point count; only the exact replay's *finite* ids
        constitute ground truth."""
        sentinel = 2**31 - 1
        worst = 1.0
        for srow, erow in zip(served_ids.tolist(), exact_ids.tolist()):
            truth = {v for v in erow if v != sentinel}
            if not truth:
                continue                    # padding / empty row: vacuous
            got = len(truth.intersection(srow))
            worst = min(worst, got / len(truth))
        return worst

    def snapshot(self) -> dict:
        with self._lock:
            snap = {"every": self.every, "mode": self.mode,
                    "checks": self._checks.snapshot(),
                    "divergences": self._divergences.snapshot(),
                    "details": list(self.details)}
            if self.mode == "recall":
                snap["floor"] = self.floor
                snap["recall"] = self._recall.snapshot()
            elif self.mode == "accuracy":
                snap["floor"] = self.floor
                snap["agreement"] = self._agreement.snapshot()
            return snap
