"""Flight-recorder observability plane: tracing + metrics + auditors.

One :class:`ObsPlane` per server bundles the pieces the serving stack
threads through itself:

* ``plane.tracer`` — a :class:`~repro.obs.trace.Tracer` ring-buffer
  span recorder, or the shared :data:`~repro.obs.trace.NULL_TRACER`
  when tracing is off (no lock, no allocation — the obs=off arm of the
  overhead guard).
* ``plane.metrics`` — a private :class:`~repro.obs.metrics.
  MetricsRegistry` so two servers in one process never mix tallies.
  (Module-level producers with no server handle — the kernels
  dispatcher — use :func:`~repro.obs.metrics.default_registry`
  instead; ``KnnServer.obs_snapshot()`` surfaces both.)

The auditors (`obs/audit.py`) are constructed by the server itself
because they need serving-side facts (k, the audit knob) — the plane
just carries the registry they count into.

``from_config`` maps the ``obs_trace`` / ``obs_trace_capacity`` knobs
of ``KnnServiceConfig``; the metrics registry is always live (counters
are cheap and every consumer of ``snapshot()`` expects them).
"""

from __future__ import annotations

from repro.obs.audit import ContractAuditor, ShadowAuditor
from repro.obs.explain import BatchCapture, ExplainRecord
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               Window, default_registry)
from repro.obs.slo import SloEngine, SloObjective
from repro.obs.trace import (NULL_TRACER, NullTracer, Span, Tracer,
                             build_trees)

__all__ = [
    "ObsPlane", "Tracer", "NullTracer", "NULL_TRACER", "Span",
    "build_trees", "Counter", "Gauge", "Histogram", "Window",
    "MetricsRegistry", "default_registry", "ContractAuditor",
    "ShadowAuditor", "BatchCapture", "ExplainRecord", "SloEngine",
    "SloObjective",
]


class ObsPlane:
    """Tracer + metrics registry for one serving stack."""

    def __init__(self, *, trace: bool = False, trace_capacity: int = 8192,
                 registry: MetricsRegistry | None = None):
        self.tracer = Tracer(trace_capacity) if trace else NULL_TRACER
        self.metrics = registry if registry is not None else MetricsRegistry()

    @classmethod
    def from_config(cls, cfg) -> "ObsPlane":
        return cls(trace=getattr(cfg, "obs_trace", False),
                   trace_capacity=getattr(cfg, "obs_trace_capacity", 8192))

    def snapshot(self) -> dict:
        return {"trace": self.tracer.stats(),
                "metrics": self.metrics.snapshot()}

    def export_trace_jsonl(self, path_or_file) -> int:
        return self.tracer.export_jsonl(path_or_file)
