"""Unified metrics registry: counters, gauges, streaming-quantile
histograms.

Zero-dependency (stdlib only): imported by the kernels dispatcher and
the serving path, so it must not pull jax/numpy anywhere.  One registry
is the single surface the scattered ad-hoc tallies (`ServerStats`,
`IngestStats`, `maintenance_stats()`, bench-script dicts) funnel into;
``snapshot()`` returns one consistent dict and ``export_jsonl()`` dumps
it one-metric-per-line for offline diffing.

Histogram design — **no per-observe sort**.  Observations land in
geometric buckets ``index = floor(log(v) / log(GROWTH))`` kept in a
dict, so ``observe`` is O(1) (one ``math.log``, one dict add) and memory
is O(distinct buckets), never O(observations).  Quantiles are computed
*at read time* by walking the sorted bucket keys (O(B log B) for B
occupied buckets — B is tens, reads are rare) and returning the
geometric midpoint of the bucket holding the target rank, clamped to
the observed [min, max].  With ``GROWTH = 2**(1/16)`` a bucket spans
~4.4%, so any quantile is within ~2.2% relative error of the exact
order statistic (tests/test_obs.py checks against a sorted oracle).
This is what fixes `StepWatchdog.observe`'s old O(n log n)-per-step
full re-sort (`runtime/metrics.py`) without changing its semantics.

Non-positive observations (all repo metrics are durations, counts, or
sizes, so these are exceptional) share one underflow bucket whose
representative value is the observed minimum.
"""

from __future__ import annotations

import json
import math
import threading
import time
from collections import deque
from typing import Optional

GROWTH = 2.0 ** (1.0 / 16.0)
_LOG_G = math.log(GROWTH)
_SQRT_G = GROWTH ** 0.5


class Counter:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming-quantile histogram; see module docstring."""

    __slots__ = ("_lock", "count", "total", "min", "max", "_buckets",
                 "_underflow")

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._buckets: dict = {}
        self._underflow = 0       # observations <= 0

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if v > 0.0:
                idx = math.floor(math.log(v) / _LOG_G)
                self._buckets[idx] = self._buckets.get(idx, 0) + 1
            else:
                self._underflow += 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (0 <= q <= 1) of everything observed,
        within one bucket width (~2.2% relative) of the exact order
        statistic.  An empty histogram returns NaN *explicitly* — not
        the ``min``/``max`` seeds (+inf/-inf), which must never leak to
        a reader (tests/test_obs.py pins this and the q=0.0/q=1.0
        nearest-rank edges against a sorted oracle)."""
        with self._lock:
            return self._quantile_locked(q)

    def _quantile_locked(self, q: float) -> float:
        if self.count == 0:
            return math.nan
        # rank of the order statistic we report (1-based, ceil like the
        # "nearest-rank" definition; q=0 -> min, q=1 -> max)
        rank = min(max(int(math.ceil(q * self.count)), 1), self.count)
        if rank <= self._underflow:
            return self.min
        rank -= self._underflow
        for idx in sorted(self._buckets):
            rank -= self._buckets[idx]
            if rank <= 0:
                mid = math.exp(idx * _LOG_G) * _SQRT_G
                return min(max(mid, self.min), self.max)
        return self.max

    def bucket_counts(self) -> tuple:
        """``([(upper_edge, count), ...] ascending, underflow)`` — the raw
        geometric bucket layout for the exposition exporters
        (obs/export.py turns these into cumulative ``le`` buckets).
        ``upper_edge`` is the bucket's exclusive-ish upper boundary
        ``GROWTH**(idx+1)``; the underflow count holds observations
        <= 0, which sort below every positive edge."""
        with self._lock:
            edges = [(math.exp((idx + 1) * _LOG_G), c)
                     for idx, c in sorted(self._buckets.items())]
            return edges, self._underflow

    def snapshot(self) -> dict:
        with self._lock:
            if self.count == 0:
                # Full-key payload even when empty: readers (bench
                # reports, check_obs gates) index ["p99"]/["mean"]
                # unconditionally, and the internal min/max seeds
                # (+inf/-inf) must not escape as observed values.
                return {"count": 0, "sum": 0.0, "mean": 0.0,
                        "min": 0.0, "max": 0.0,
                        "p50": math.nan, "p90": math.nan,
                        "p99": math.nan}
            return {
                "count": self.count,
                "sum": self.total,
                "mean": self.total / self.count,
                "min": self.min,
                "max": self.max,
                "p50": self._quantile_locked(0.50),
                "p90": self._quantile_locked(0.90),
                "p99": self._quantile_locked(0.99),
            }


class Window:
    """Sliding *time*-window series — the registry's fourth metric type,
    added for the SLO engine (obs/slo.py).

    A histogram aggregates forever; an SLO burn rate is a statement about
    the last N seconds.  A Window keeps raw ``(t, v)`` observations in a
    bounded deque (age- and length-trimmed on every write, so memory is
    O(max_len) regardless of traffic) and answers *windowed* reads:
    count/sum/min/max/quantile over exactly the observations younger than
    ``window_s``.  Reads sort the windowed slice at call time — windows
    are bounded and reads happen once per SLO evaluation, not per
    request, so O(w log w) at read beats any per-observe bookkeeping.

    Timestamps are ``time.monotonic()`` floats; pass ``t=``/``now=``
    explicitly to replay a synthetic stream in tests (the SLO burn-rate
    units drive a fake clock through here).
    """

    __slots__ = ("_lock", "_events", "max_age_s", "max_len", "count",
                 "total")

    def __init__(self, max_age_s: float = 900.0, max_len: int = 32768):
        self._lock = threading.Lock()
        self._events: deque = deque()       # (t, v), ascending t
        self.max_age_s = float(max_age_s)
        self.max_len = int(max_len)
        self.count = 0                      # lifetime observations
        self.total = 0.0

    def observe(self, v: float, t: Optional[float] = None) -> None:
        t = time.monotonic() if t is None else float(t)
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self._events.append((t, v))
            self._trim_locked(t)

    def _trim_locked(self, now: float) -> None:
        horizon = now - self.max_age_s
        ev = self._events
        while ev and (ev[0][0] < horizon or len(ev) > self.max_len):
            ev.popleft()

    def _window_values(self, window_s: float, now: Optional[float]):
        now = time.monotonic() if now is None else float(now)
        horizon = now - float(window_s)
        with self._lock:
            return [v for (t, v) in self._events if t >= horizon]

    def window(self, window_s: float, now: Optional[float] = None) -> dict:
        """Aggregates over observations younger than ``window_s``; the
        empty window returns count 0 and NaN extremes (never ±inf)."""
        vals = self._window_values(window_s, now)
        if not vals:
            return {"count": 0, "sum": 0.0, "mean": math.nan,
                    "min": math.nan, "max": math.nan}
        return {"count": len(vals), "sum": float(sum(vals)),
                "mean": float(sum(vals)) / len(vals),
                "min": min(vals), "max": max(vals)}

    def quantile(self, q: float, window_s: float,
                 now: Optional[float] = None) -> float:
        """Exact nearest-rank q-quantile of the windowed observations
        (sorted at read time; NaN when the window is empty)."""
        vals = sorted(self._window_values(window_s, now))
        if not vals:
            return math.nan
        rank = min(max(int(math.ceil(q * len(vals))), 1), len(vals))
        return vals[rank - 1]

    def snapshot(self) -> dict:
        with self._lock:
            return {"count": self.count, "sum": self.total,
                    "retained": len(self._events),
                    "max_age_s": self.max_age_s}


class MetricsRegistry:
    """Create-or-get registry of named metrics.

    Names are dotted paths (``serve.latency_s``, ``maint.commit_s``,
    ``kernel.fallback.vmem``); the registry is flat — grouping is a
    reader-side convention.  Asking for an existing name with a
    different type raises (one name, one meaning).  ``snapshot()`` is
    one lock pass over the name table plus per-metric atomic snapshots,
    so the returned dict never tears against concurrent writers.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, asked for {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def window(self, name: str) -> Window:
        return self._get(name, Window)

    def items(self) -> list:
        """Sorted ``(name, metric object)`` pairs — the exporter surface
        (obs/export.py needs the live objects for histogram bucket
        layout, not just ``snapshot()``'s quantile digest)."""
        with self._lock:
            return sorted(self._metrics.items())

    def get(self, name: str):
        """The metric object, or None (read-only peek; no create)."""
        with self._lock:
            return self._metrics.get(name)

    def value(self, name: str, default=0):
        """Counter/gauge value by name (default when absent)."""
        m = self.get(name)
        return default if m is None else m.snapshot()

    def snapshot(self, prefix: str = "") -> dict:
        with self._lock:
            items = [(n, m) for n, m in self._metrics.items()
                     if n.startswith(prefix)]
        return {n: m.snapshot() for n, m in sorted(items)}

    def export_jsonl(self, path_or_file, prefix: str = "") -> int:
        """One ``{"metric": name, ...payload}`` object per line."""
        snap = self.snapshot(prefix)
        lines = []
        for name, payload in snap.items():
            rec = {"metric": name}
            if isinstance(payload, dict):
                rec.update(payload)
            else:
                rec["value"] = payload
            lines.append(json.dumps(rec) + "\n")
        if hasattr(path_or_file, "write"):
            path_or_file.writelines(lines)
        else:
            with open(path_or_file, "w") as f:
                f.writelines(lines)
        return len(lines)


# Process-wide default registry: the home of metrics produced by code
# with no handle to a server's private plane (the kernels dispatcher's
# fallback counters).  Servers get their own registry by default so two
# servers' serving metrics never mix; both surfaces appear in
# KnnServer.obs_snapshot().
_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MetricsRegistry()
        return _DEFAULT
