"""Exact-mode prediction fold — runs inside the fused shard_map body.

Takes the :class:`repro.core.knn.KnnResult` of Algorithm 2 (whose winner
``mask`` marks exactly the l global nearest neighbors, per-shard) plus
the top-l-aligned label payload and reduces it to one label +
confidence per query with a single psum — the class histogram / value
sum is the only thing that crosses the network, never the points or
labels themselves (the paper's privacy note extends to inference).

Determinism contract: classification ties break toward the *lowest*
class id (``argmax`` returns the first maximum), identically on every
backend and every shard count — two fresh servers with the same key and
generation produce identical label bytes (tests/test_predict.py).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import knn


def exact_predict(res: knn.KnnResult, l_run, *, predict: str,
                  num_classes: int, axis_name: str):
    """Fold the winner mask into a label; ``(label, confidence, detail)``.

    ``predict="vote"``: majority class over the l winners via
    :func:`repro.core.knn.knn_classify` (1 psum of (B, C) int32).
    ``label`` is the class id as f32, ``confidence`` the winning class's
    vote share, ``detail`` the replicated (B, C) histogram.

    ``predict="regress"``: mean label value over the l winners (1 psum
    of two (B,) f32 reductions).  ``label`` is the mean, ``confidence``
    the fraction of the requested l actually found (short rows — fewer
    live points than l — report < 1), ``detail`` the stacked
    (B, 2) [sum, count].

    Rows with ``l_run == 0`` (micro-batch bucket padding) have an empty
    mask: they come back label −1 / confidence 0 (vote) or 0 / 0
    (regress) and never influence live rows.
    """
    labels = res.local_labels
    l_f = jnp.maximum(jnp.asarray(l_run, jnp.float32), 1.0)
    if predict == "vote":
        cls, hist = knn.knn_classify(res.mask, labels.astype(jnp.int32),
                                     num_classes, axis_name=axis_name)
        total = jnp.sum(hist, axis=-1)
        top = jnp.max(hist, axis=-1)
        conf = top.astype(jnp.float32) / jnp.maximum(
            total.astype(jnp.float32), 1.0)
        label = jnp.where(total > 0, cls, -1).astype(jnp.float32)
        return label, conf, hist
    # regress: one psum carries both reductions (a pytree psum fuses)
    num = jnp.sum(jnp.where(res.mask, labels, 0.0), axis=-1)
    den = jnp.sum(res.mask.astype(jnp.float32), axis=-1)
    num, den = lax.psum((num, den), axis_name)
    label = num / jnp.maximum(den, 1.0)
    conf = den / l_f
    return label, conf, jnp.stack([num, den], axis=-1)
