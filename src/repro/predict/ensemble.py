"""Ensemble prediction — one message per routed shard, zero collectives.

Device side (:func:`local_vote` / :func:`local_mean`, called inside a
shard_map body with NO collective ops): each shard reduces its own
masked local top-l to a class histogram / (sum, count) pair over its
first ``kl`` finite candidates.  The per-shard outputs leave the
executable sharded (out_spec over the service axis), so in the k-machine
model each routed shard sends exactly one O(C) message — the bill the
serving layer accounts as ``messages == touched_shards`` and the bench
hard-asserts per query.

Host side (:func:`aggregate_vote` / :func:`aggregate_regress`): the
aggregation rule of Distributed NN Classification (Duan–Qiao–Cheng,
arXiv 1812.05005) — majority of the per-shard local votes for
classification, mean of the per-shard local means for regression.  A
shard with zero live candidates for a row abstains; ties break toward
the lowest label (np.argmax takes the first maximum), matching the
exact mode's tie rule so the single-shard degenerate case is
bit-identical.

The local-k rule (:func:`local_k_for`): ``kl = ceil(l / touched)`` by
default — the split of the global neighbor budget arXiv 1812.05005
analyzes (near-optimal excess risk for M = o(n^{4/(d+4)}) machines) —
or a fixed explicit ``local_k``.  Padded rows (l == 0) get kl == 0 and
vote for nothing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---- device side (inside shard_map; collective-free) ---------------------

def _keep_mask(d, kl):
    """(B, L) bool: the kl[b] *nearest finite* local candidates of row b.

    Rank-based, not position-based: local_top_l only guarantees ascending
    order through its top_k path — when the shard buffer is no wider than
    l it returns distances in slot order.  The double argsort computes
    each slot's ascending rank in place (stable, so distance ties break
    toward the lower slot — deterministic across runs), and a slot votes
    iff its rank is within kl and its distance is finite (+inf sentinels
    — tombstoned / routed-away / padded — never vote, whatever kl says).
    """
    order = jnp.argsort(d, axis=-1)
    rank = jnp.argsort(order, axis=-1).astype(jnp.int32)
    return (rank < kl[:, None]) & jnp.isfinite(d)


def local_vote(d, labels_top, kl, num_classes: int):
    """This shard's local-kNN class histogram, (B, C) int32.

    ``d``/``labels_top``: the shard's ascending local top-l distances and
    aligned labels (core.knn.local_top_l with ``extra=``); ``kl``: (B,)
    per-row local neighbor count.
    """
    keep = _keep_mask(d, kl)
    onehot = jax.nn.one_hot(labels_top.astype(jnp.int32), num_classes,
                            dtype=jnp.int32)
    return jnp.sum(jnp.where(keep[..., None], onehot, 0), axis=-2)


def local_mean(d, labels_top, kl):
    """This shard's local-kNN (sum, count) pair, (B, 2) f32 — the host
    turns it into a local mean; count 0 means abstain."""
    keep = _keep_mask(d, kl)
    s = jnp.sum(jnp.where(keep, labels_top, 0.0), axis=-1)
    c = jnp.sum(keep.astype(jnp.float32), axis=-1)
    return jnp.stack([s, c], axis=-1)


# ---- host side -----------------------------------------------------------

def local_k_for(l: np.ndarray, touched: int, local_k: int,
                l_max: int) -> np.ndarray:
    """(B,) int32 per-row local neighbor count.

    ``local_k == 0`` (auto): ``ceil(l / touched)`` — one shard means
    ``kl == l``, which makes the ensemble vote bit-identical to the
    exact vote.  Explicit ``local_k`` is used as-is.  Both are clamped
    to the buffer width ``l_max``; padded rows (l == 0) stay 0.
    """
    l = np.asarray(l, np.int64)
    t = max(int(touched), 1)
    kl = -(-l // t) if local_k == 0 else np.full_like(l, int(local_k))
    kl = np.minimum(np.maximum(kl, 1), l_max)
    return np.where(l > 0, kl, 0).astype(np.int32)


def aggregate_vote(hists: np.ndarray, active: np.ndarray):
    """Majority of per-shard local votes; ``(label, confidence, votes)``.

    ``hists``: (k, B, C) per-shard histograms off the device; ``active``:
    (k,) bool routing flags — a routed-away shard's histogram is zeroed
    (it holds only masked +inf slots anyway, but the bill argument wants
    untouched shards provably silent).  ``votes``: (B, C) count of
    shards voting each class (the explain plane's per-shard vote table
    derives from ``hists`` directly).  ``label`` is −1 with confidence 0
    when every shard abstained (padded rows, empty stores).
    """
    hists = np.asarray(hists)
    k, B, C = hists.shape
    hists = np.where(np.asarray(active, bool)[:, None, None], hists, 0)
    totals = hists.sum(axis=-1)                     # (k, B)
    voting = totals > 0                             # abstain on empty
    shard_vote = hists.argmax(axis=-1)              # (k, B) ties -> lowest
    votes = np.zeros((B, C), np.int64)
    rows = np.broadcast_to(np.arange(B)[None, :], (k, B))
    np.add.at(votes, (rows[voting], shard_vote[voting]), 1)
    label = votes.argmax(axis=-1)                   # ties -> lowest
    n_voting = voting.sum(axis=0)                   # (B,)
    conf = votes[np.arange(B), label] / np.maximum(n_voting, 1)
    label = np.where(n_voting > 0, label, -1)
    return (label.astype(np.float32), conf.astype(np.float32), votes)


def aggregate_regress(sumcnt: np.ndarray, active: np.ndarray):
    """Mean of per-shard local means; ``(value, confidence)``.

    ``sumcnt``: (k, B, 2) per-shard [sum, count]; ``confidence`` is the
    fraction of *routed* shards that had candidates to answer with.
    """
    sumcnt = np.asarray(sumcnt)
    active = np.asarray(active, bool)
    s, c = sumcnt[..., 0], sumcnt[..., 1]
    voting = (c > 0) & active[:, None]              # (k, B)
    means = np.where(voting, s / np.maximum(c, 1.0), 0.0)
    n_voting = voting.sum(axis=0)
    value = means.sum(axis=0) / np.maximum(n_voting, 1)
    conf = n_voting / max(int(active.sum()), 1)
    return value.astype(np.float32), conf.astype(np.float32)
