"""Label-prediction subsystem — the paper's endgame, served.

The source paper frames the whole distributed l-NN machinery as a means
to an end: "assign a label to p based on the labels of the K-nearest
points".  This package layers that end over the existing store / serving
/ obs planes, in two modes with two very different network bills:

* **Exact predict** (``predict="vote"|"regress"``, ``predict_mode=
  "exact"``): Algorithm 2 runs exactly as today, then the winner mask is
  folded into :func:`repro.core.knn.knn_classify` /
  :func:`repro.core.knn.knn_regress` *inside* the fused executable —
  only the (B, C) class histogram / value sum crosses the network (one
  extra psum: +1 round, +(t-1) messages on the Theorem-1 envelope), and
  the answer is bit-identical to a single-machine majority vote / mean
  over the true l nearest neighbors.  Tombstoned, routed-away, and
  non-candidate slots enter the pipeline at +inf and never reach the
  winner mask, so they never vote.

* **Ensemble** (``predict_mode="ensemble"``): each *routed* shard
  answers its own local-kNN vote and the host aggregates — majority of
  per-shard votes for classification, mean of per-shard local means for
  regression (Distributed NN Classification, Duan–Qiao–Cheng,
  arXiv 1812.05005; minimax fixed-k analysis in Ryu–Kim,
  arXiv 2202.02464).  Zero cross-shard point movement, zero collectives
  in the executable: the message bill is exactly ``touched_shards`` —
  one histogram per routed shard — and the accuracy gap vs exact is a
  *measured* contract (``accuracy_floor``; ShadowAuditor
  ``mode="accuracy"``; the bench's accuracy-vs-message-bill table).

The local-k rule (:func:`ensemble.local_k_for`) defaults to the
``ceil(l / touched_shards)`` split arXiv 1812.05005 analyzes; on a
single-shard store that degenerates to ``kl = l``, making the ensemble
vote bit-identical to the exact vote (tests/test_predict.py).
"""

from repro.predict.ensemble import (aggregate_regress, aggregate_vote,
                                    local_k_for, local_mean, local_vote)
from repro.predict.vote import exact_predict

__all__ = [
    "aggregate_regress",
    "aggregate_vote",
    "exact_predict",
    "local_k_for",
    "local_mean",
    "local_vote",
]
