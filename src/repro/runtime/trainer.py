"""Training runtime: microbatched step builder + fault-tolerant loop.

Step construction (`make_train_step`):
  * the global batch is split into `grad_accum` microbatches driven by a
    `lax.scan` — bounding the live activation (and vocab-logits) footprint
    and letting XLA's scheduler overlap microbatch i's backward with the
    i-1 gradient reduce-scatter (the compute/comm overlap lever);
  * gradients accumulate in f32; optional bf16 compression with error
    feedback (optim/compress.py) halves the DP-collective bytes;
  * everything is one jitted function of (params, opt_state, batch) so the
    dry-run can lower/compile it per (arch x shape x mesh) cell.

Loop (`train_loop`):
  * auto-restart: on a step failure the loop restores the latest
    checkpoint and replays from there (`failure.py` injects crashes in
    tests); the synthetic data pipeline is seeded by step, so replayed
    batches are bit-identical;
  * step-time watchdog flags p95 outliers (the straggler telemetry a real
    deployment wires to its eviction controller).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamW, compress as compress_mod, warmup_cosine
from repro.runtime import metrics as metrics_mod


@dataclasses.dataclass
class TrainConfig:
    grad_accum: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    compress_grads: bool = False
    remat: bool = True
    aux_weight: float = 0.01
    # bf16 gradient accumulation buffer: halves the largest transient at
    # >100B-parameter scale; per-microbatch grads are f32 before the add,
    # so the accumulation loses <1 ulp per microbatch (grad_accum <= 32).
    accum_dtype: object = jnp.float32


def make_train_step(api, tcfg: TrainConfig, optimizer: AdamW):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  opt_state = (AdamWState, residual|None, step_count)."""

    def loss(params, mb):
        l, aux = api.loss_fn(params, mb, remat=tcfg.remat)
        return l, aux

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        adam_state, residual, step = opt_state
        n = tcfg.grad_accum

        def micro(carry, mb):
            g_acc, l_acc = carry
            (l, aux), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32)
                              + b.astype(jnp.float32) / n).astype(a.dtype),
                g_acc, g)
            return (g_acc, l_acc + l / n), aux["ce"] / n

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params)
        microbatches = jax.tree.map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)
        (grads, loss_val), ce = jax.lax.scan(
            micro, (zeros, jnp.float32(0.0)), microbatches)

        if tcfg.compress_grads:
            grads, residual = compress_mod.compress(grads, residual)

        lr = warmup_cosine(step, peak_lr=tcfg.peak_lr,
                           warmup_steps=tcfg.warmup_steps,
                           total_steps=tcfg.total_steps)
        new_params, new_adam = optimizer.update(grads, adam_state, params,
                                                lr)
        m = {"loss": loss_val, "ce": jnp.sum(ce), "lr": lr}
        return new_params, (new_adam, residual, step + 1), m

    return train_step


def init_opt_state(api, tcfg: TrainConfig, optimizer: AdamW, params):
    residual = (compress_mod.init_residual(params)
                if tcfg.compress_grads else None)
    return (optimizer.init(params), residual, jnp.zeros((), jnp.int32))


def train_loop(
    *,
    api,
    tcfg: TrainConfig,
    optimizer: AdamW,
    params,
    opt_state,
    make_batch: Callable[[int], dict],
    num_steps: int,
    ckpt_manager=None,
    ckpt_every: int = 50,
    start_step: int = 0,
    fail_at: Optional[Callable[[int], None]] = None,
    max_restarts: int = 3,
    logger: Optional[metrics_mod.MetricLogger] = None,
):
    """Fault-tolerant synchronous loop.  Returns (params, opt_state, step).

    `fail_at(step)` is the failure-injection hook (raises to simulate a
    node loss); on failure we restore the latest checkpoint and continue —
    the checkpoint/restart path exercised by tests/test_fault_tolerance.py.
    """
    train_step = jax.jit(make_train_step(api, tcfg, optimizer))
    watchdog = metrics_mod.StepWatchdog()
    logger = logger or metrics_mod.MetricLogger()
    restarts = 0
    step = start_step

    while step < num_steps:
        try:
            t0 = time.perf_counter()
            if fail_at is not None:
                fail_at(step)
            batch = make_batch(step)
            params, opt_state, m = train_step(params, opt_state, batch)
            dt = time.perf_counter() - t0
            slow = watchdog.observe(dt)
            logger.log(step, loss=float(m["loss"]), lr=float(m["lr"]),
                       step_time=dt, straggler=slow)
            if ckpt_manager is not None and (step + 1) % ckpt_every == 0:
                ckpt_manager.save(step + 1,
                                  {"params": params, "opt": opt_state})
            step += 1
        except _RESTARTABLE as e:
            restarts += 1
            if restarts > max_restarts or ckpt_manager is None:
                raise
            logger.log(step, event=f"restart after {type(e).__name__}: {e}")
            ckpt_manager.wait()
            latest = ckpt_manager.latest_step()
            if latest is None:
                step = start_step
                continue
            state = ckpt_manager.restore(
                latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            step = latest
    if ckpt_manager is not None:
        ckpt_manager.wait()
    return params, opt_state, step


class SimulatedNodeFailure(RuntimeError):
    pass


_RESTARTABLE = (SimulatedNodeFailure,)
