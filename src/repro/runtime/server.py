"""Serving engine: batched prefill + decode with the distributed sampler.

The decode loop calls `ModelApi.serve_step`, i.e. every generated token
goes through the paper's distributed top-k over the model-sharded vocab
(or the gather baseline, selectable per request batch for A/B benching).
Host<->device traffic is one int32 token per sequence per step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 2048
    top_k: int = 50
    temperature: float = 0.8
    sampler: str = "selection"     # "selection" (paper) | "gather" (baseline)
    num_pivots: int = 1


class Server:
    def __init__(self, api, params, scfg: ServeConfig, *, mesh=None,
                 cache_dtype=jnp.float32):
        self.api = api
        self.params = params
        self.scfg = scfg
        self.mesh = mesh
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c))
        self._step = jax.jit(
            lambda p, t, c, k: api.serve_step(
                p, t, c, k, mesh=mesh, top_k=scfg.top_k,
                temperature=scfg.temperature, sampler=scfg.sampler,
                num_pivots=scfg.num_pivots))

    def generate(self, batch: dict, max_new_tokens: int,
                 key: Optional[jax.Array] = None):
        """batch: model inputs (tokens + modality stubs).  Returns
        (generated (B, max_new_tokens) int32, stats dict)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        B = batch["tokens"].shape[0]
        cache = self.api.init_cache(
            jax.random.PRNGKey(1), B, self.scfg.max_seq,
            dtype=self.cache_dtype)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache)
        # first sampled token comes from the prefill logits through the
        # same sampler path: feed as a 1-token "decode" of the argmax? No —
        # sample from prefill logits directly on host (B, V) replicated.
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        prefill_s = time.perf_counter() - t0

        out = [np.asarray(tok)]
        t1 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            tok, cache = self._step(self.params, tok, cache,
                                    jax.random.fold_in(key, i))
            out.append(np.asarray(tok))
        decode_s = time.perf_counter() - t1
        gen = np.stack(out, axis=1)
        return gen, {"prefill_s": prefill_s, "decode_s": decode_s,
                     "tok_per_s": B * max(max_new_tokens - 1, 1)
                     / max(decode_s, 1e-9)}
