"""Step telemetry: metric logging + straggler watchdog.

Both are backed by the unified obs plane (src/repro/obs/metrics.py):
``MetricLogger`` can mirror every numeric it logs into a
``MetricsRegistry`` so training/bench telemetry shows up in the same
``snapshot()`` as the serving metrics, and ``StepWatchdog`` keeps its
running p50 in a streaming-quantile histogram — O(1) per observation
instead of the old full re-sort (O(n log n) per step, O(n) memory
traffic) that made a long-running watchdog quadratic overall.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Optional, TextIO

from repro.obs.metrics import Histogram, MetricsRegistry


class MetricLogger:
    def __init__(self, stream: Optional[TextIO] = None, quiet: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 prefix: str = "train."):
        self.stream = stream or sys.stderr
        self.quiet = quiet
        self.history: list[dict] = []
        self.registry = registry
        self.prefix = prefix

    def log(self, step: int, **kwargs):
        rec = {"step": step, "t": time.time(), **kwargs}
        self.history.append(rec)
        if self.registry is not None:
            for k, v in kwargs.items():
                if isinstance(v, bool):
                    continue
                if isinstance(v, (int, float)):
                    self.registry.histogram(self.prefix + k).observe(v)
        if not self.quiet:
            self.stream.write(json.dumps(rec) + "\n")


class StepWatchdog:
    """Flags steps slower than `factor` x the running p50 once warmed up.

    At fleet scale this signal feeds the slow-host eviction controller; in
    this repo it is logged and asserted on by the straggler test.

    The running p50 comes from a streaming-quantile histogram, so each
    ``observe`` is O(1); the flagging semantics are unchanged — a step is
    compared against the median of all *prior* steps, and flagging only
    starts once ``warmup`` prior steps exist.  (Quantile reads clamp to
    the observed [min, max], so a warmup of identical durations yields
    the exact median — no approximation slack on the degenerate case the
    straggler test exercises.)
    """

    def __init__(self, factor: float = 3.0, warmup: int = 5,
                 registry: Optional[MetricsRegistry] = None,
                 name: str = "watchdog.step_s"):
        self.factor = factor
        self.warmup = warmup
        self.times: list[float] = []        # kept for inspection/back-compat
        self.flagged: list[int] = []
        self._hist = (registry.histogram(name) if registry is not None
                      else Histogram())
        if registry is not None:
            self._flagged_ctr = registry.counter(name + ".flagged")
        else:
            self._flagged_ctr = None

    def observe(self, dt: float) -> bool:
        prior = self._hist.count
        p50 = self._hist.quantile(0.5) if prior >= self.warmup else None
        self.times.append(dt)
        self._hist.observe(dt)
        if p50 is None:
            return False
        slow = dt > self.factor * p50
        if slow:
            self.flagged.append(len(self.times) - 1)
            if self._flagged_ctr is not None:
                self._flagged_ctr.inc()
        return slow
