"""Step telemetry: metric logging + straggler watchdog."""

from __future__ import annotations

import json
import sys
import time
from typing import Optional, TextIO


class MetricLogger:
    def __init__(self, stream: Optional[TextIO] = None, quiet: bool = False):
        self.stream = stream or sys.stderr
        self.quiet = quiet
        self.history: list[dict] = []

    def log(self, step: int, **kwargs):
        rec = {"step": step, "t": time.time(), **kwargs}
        self.history.append(rec)
        if not self.quiet:
            self.stream.write(json.dumps(rec) + "\n")


class StepWatchdog:
    """Flags steps slower than `factor` x the running p50 once warmed up.

    At fleet scale this signal feeds the slow-host eviction controller; in
    this repo it is logged and asserted on by the straggler test.
    """

    def __init__(self, factor: float = 3.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.times: list[float] = []
        self.flagged: list[int] = []

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        hist = sorted(self.times[:-1])
        p50 = hist[len(hist) // 2]
        slow = dt > self.factor * p50
        if slow:
            self.flagged.append(len(self.times) - 1)
        return slow
