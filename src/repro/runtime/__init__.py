from repro.runtime.trainer import (TrainConfig, make_train_step,
                                   init_opt_state, train_loop,
                                   SimulatedNodeFailure)
from repro.runtime.server import Server, ServeConfig
from repro.runtime.knn_server import KnnServer, QueryResult, ServerStats
from repro.runtime.metrics import MetricLogger, StepWatchdog

__all__ = ["TrainConfig", "make_train_step", "init_opt_state", "train_loop",
           "SimulatedNodeFailure", "Server", "ServeConfig", "KnnServer",
           "QueryResult", "ServerStats", "MetricLogger", "StepWatchdog"]
