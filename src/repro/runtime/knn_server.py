"""Micro-batched distributed kNN query service — Algorithm 2 as a server.

The paper answers one replicated query batch per call; serving "heavy
traffic from millions of users" (ROADMAP) means coalescing many independent
requests — each with its own neighbor count l — into full device batches
against the sharded point set, the way PANDA-style distributed kNN systems
amortize every datastore pass over a query block.  Pipeline:

  submit(q, l) -> [request queue] -> micro-batcher (linger max_wait_ms,
      pad-to-bucket) -> persistent shard_map executable for that bucket
      (B, l_max) shape -> per-request QueryResult (dists / ids / values
      + round/message accounting from SelectionResult)

Static shapes for jit: requests are padded to the smallest configured
bucket size (padding rows carry l=0, which Algorithm 2 resolves to "select
nothing" without touching real rows), and every per-request l shares the
static buffer bound l_max with per-row masking inside
``core.knn.knn_query_batched``.  Each bucket shape therefore compiles
exactly once (``warmup()`` pre-pays all of them) and every subsequent
flush is a cached-executable call.

All tuning — bucket shapes, l_max, linger, sampling, num_pivots, and the
selection-vs-gather A/B — comes from ``configs.knn_service.KnnServiceConfig``;
the server adds no knobs of its own.  benchmarks/bench_serve.py measures
sustained queries/sec and p50/p99 latency for both sampler settings.

The server can also be backed by a mutable store (``store=`` — a
``repro.store.MutableStore``): each dispatch captures the store's current
immutable snapshot, so in-flight micro-batches finish against the
generation they started with while later submissions see the newly
swapped epoch (DESIGN.md Section 7).  ``QueryResult.generation`` reports
which epoch answered.  benchmarks/bench_ingest.py measures ingest
throughput and query latency under concurrent ingest.

With ``cfg.route="pruned"`` each dispatch first consults per-shard pivot
summaries (store/summaries.py; captured in the same lock acquisition as
the snapshot, so routing metadata always matches the answering epoch) and
computes the micro-batch's touched-shard set; shards the lower-bound test
rules out are masked wholesale inside the executable and drop out of the
k-machine message bill (``QueryResult.shards_touched``).  Answers are
bit-identical to ``route="exact"`` — the property harness
tests/test_routing.py enforces this, DESIGN.md Section 8 explains why.
benchmarks/bench_serve.py runs the exact-vs-pruned A/B.

How much a store-backed server can actually prune is the store's
placement policy's doing (store/placement.py, DESIGN.md Section 9):
``placement="affinity"`` + ``redeal="proximity"`` keep clusters
shard-coherent so routing skips shards; ``placement_stats()`` surfaces
the per-shard live histogram and the realized prune rate.
benchmarks/bench_serve.py runs the placement A/B on a clustered
streaming-ingest workload.

How long that pruning *stays* effective under churn is the adaptive
maintenance subsystem's doing (store/adaptive.py, DESIGN.md Section 10):
multi-pivot summaries (``summary_pivots``), scheduled per-shard exact
re-tightening (``retighten_every``), and radius-triggered shard
splitting (``split_radius_factor``) keep the covering bounds tight
mid-stream; ``placement_stats()`` reports the per-shard
``summary_slack`` decay probe and the maintenance counters.
benchmarks/bench_serve.py runs the drifting-cluster adaptive A/B.

With ``cfg.route_compute="device"`` the routing decision itself moves
off the host: the summary operands are packed once per frozen summaries
object (kernels/routing.pack_summaries) and the lower-bound /
cumulative-live threshold test runs as a Pallas prologue inside the same
jitted program as the shard_map query — the touched-shard mask returns
with the batch instead of costing a separate O(B·k·(dim+r)) host numpy
pass per dispatch.  Answers stay bit-identical (tests/test_routing.py
proves mask parity against the host router; DESIGN.md Section 11).

With ``cfg.search="approx"`` the dispatch prologue additionally consults
the per-shard covering-ball bucket index (store/index.py, frozen
generation-coupled with the snapshot — ``serving_snapshot()`` hands out
all three from one lock acquisition): buckets whose distance lower bound
cannot beat the batch's cumulative-live threshold are dropped, and their
slots enter the fused kernel as non-candidates (core/knn.py
``point_candidates`` — masked exactly like tombstones).  This trades the
repo's bit-identical invariant for a *measured* recall contract: every
answer is tagged ``recall_mode="approx"``, the realized candidate
fraction feeds the ``serve.candidate_fraction`` histogram, and the
shadow auditor (mode="recall") replays sampled batches through the
exact collective to measure recall@l against ``cfg.recall_floor``
(DESIGN.md Section 13).  Under ``route_compute="device"`` the bucket
decision runs as the second stage of the same Pallas prologue
(kernels/routing.index_mask), so the candidate mask also rides the
batch's own launch.  benchmarks/bench_serve.py runs the exact-vs-approx
A/B and hard-asserts the recall floor at the candidate-reduction target.

With ``cfg.predict`` set (src/repro/predict/, DESIGN.md Section 15) the
server also answers the paper's endgame — a label for the query — in one
of two modes.  ``predict_mode="exact"`` folds the Algorithm 2 winner
mask into a class vote / value mean *inside* the fused executable (one
extra psum: only the histogram crosses the network; +1 round, +(t-1)
messages) and is bit-identical to a single-machine vote over the true
l nearest neighbors.  ``predict_mode="ensemble"`` skips the selection
collectives entirely: each routed shard answers its local-kNN vote in
ONE message (arXiv 1812.05005) and the host aggregates — the message
bill is exactly ``touched_shards``, and the accuracy gap vs exact is a
measured contract (``cfg.accuracy_floor``, ShadowAuditor
mode="accuracy", bench_serve's accuracy-vs-message-bill table).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.knn_service import CONFIG, KnnServiceConfig
from repro.core import knn as knn_mod
from repro.kernels import ops as kops
from repro.kernels import routing as routing_mod
from repro.obs import (BatchCapture, ContractAuditor, ExplainRecord,
                       ObsPlane, ShadowAuditor, SloEngine)
from repro.obs.export import ObsHttpServer
from repro.obs.metrics import default_registry
from repro.parallel.compat import make_mesh, shard_map
from repro import predict as predict_mod
from repro.store import index as index_mod
from repro.store import summaries as summaries_mod

_ID_SENTINEL = 2**31 - 1


class QueryResult(NamedTuple):
    """Answer for one request.

    ``dists``/``ids`` have the request's own length l, sorted ascending by
    distance (+inf / INT32_MAX sentinel slots last, when fewer than l
    finite points exist).  ``values`` maps ids through the server's
    optional value table (kNN-LM token ids), -1 where absent.
    ``generation`` is the store generation the answer was computed
    against: 0 forever for a static-points server, the epoch number of
    the :class:`~repro.store.MutableStore` snapshot captured at dispatch
    for a store-backed one.

    Round/message accounting follows the k-machine model conventions used
    throughout the repo (see selection.py): the selection path costs 2
    rounds per Algorithm 1 iteration (pivot all_gather + count psum) plus a
    constant number of pipeline rounds (sample-prune and result gather),
    with k-1 leader-tree messages of O(1) scalars per round.  The gather
    baseline is one collective round whose payload is l scalars from each
    of k-1 peers — its ``messages`` entry counts those O(1)-word units, so
    the O(k*l) vs O(k*log l) contrast is directly visible.

    ``shards_touched`` is the size of the carrying batch's touched-shard
    set: k under ``route="exact"``; under ``route="pruned"`` the union,
    over the batch's real rows, of shards the summary lower-bound test
    could not rule out (store/summaries.py).  Pruned shards hold no
    candidates, so in the k-machine model they send nothing — the
    ``messages`` bill charges ``shards_touched - 1`` peers per round
    instead of ``k - 1``.

    ``recall_mode`` tags the answer's exactness contract: ``"exact"``
    (the default) means the true top-l, bit-identical to the paper's
    collective regardless of routing; ``"approx"`` means the answer went
    through the per-shard bucket index (``cfg.search``, store/index.py)
    and carries the measured recall contract (``cfg.recall_floor``,
    shadow-audited) instead.

    ``label``/``confidence`` are the prediction plane's answer
    (``cfg.predict``; None when prediction is off): the majority class
    id (as f32; -1 when no live neighbor voted) with its vote share, or
    the regression mean with the answering fraction.  ``predict_mode``
    tags how it was computed: ``"exact"`` (bit-identical to a
    single-machine vote over the true l-NN) or ``"ensemble"``
    (one-message-per-shard local votes, host-aggregated — dists/ids are
    all-sentinel because no point ever leaves its shard).
    """

    dists: np.ndarray
    ids: np.ndarray
    values: Optional[np.ndarray]
    l: int
    iterations: int        # Algorithm 1 iterations of the carrying batch
    rounds: int            # k-machine rounds of the carrying batch
    messages: int          # O(1)-word messages of the carrying batch
    survivors: int         # Lemma 2.3 post-prune candidate count (this row)
    bucket: int            # device batch shape the request rode in
    queued_s: float        # enqueue -> dispatch
    latency_s: float       # enqueue -> result
    generation: int = 0    # store epoch the answer was computed against
    shards_touched: int = -1   # carrying batch's touched-shard count
    recall_mode: str = "exact"   # "exact" | "approx" (bucket index used)
    explain_ref: object = None   # ExplainRecord handle (obs/explain.py)
    label: Optional[float] = None       # predicted class id / mean value
    confidence: Optional[float] = None  # vote share / answering fraction
    predict_mode: str = "none"   # "none" | "exact" | "ensemble"

    def explain(self) -> Optional[dict]:
        """The per-query explain report (obs/explain.py SCHEMA):
        per-shard routing bounds and threshold, per-bucket keep
        decisions, stage timings, and any maintenance commit that raced
        the request — assembled lazily on first call from the dispatch's
        cheap capture, cached after.  None for results constructed
        without a capture (hand-built in tests)."""
        return None if self.explain_ref is None else self.explain_ref.build()


@dataclasses.dataclass
class ServerStats:
    """Serving counters, safe to update and read from any thread.

    ``observe()`` may race between the micro-batcher thread and a
    caller's ``flush()``; it takes the internal lock, and readers who
    need mutually-consistent values (e.g. ``queries`` vs
    ``bucket_counts``) take ``snapshot()`` rather than reading fields
    one by one — field reads are individually atomic in CPython but a
    multi-field read can tear across a concurrent ``observe()``.
    """

    queries: int = 0
    batches: int = 0
    padded_rows: int = 0
    bucket_counts: dict = dataclasses.field(default_factory=dict)
    # Routing effectiveness (route="pruned" dispatches only): summed
    # touched-shard counts and the batches they came from, the inputs to
    # KnnServer.placement_stats()'s prune rate.
    touched_shards: int = 0
    routed_batches: int = 0
    # Defensive tally: QueryResult.shards_touched's -1 "never routed"
    # sentinel must never be summed into the prune-rate inputs above —
    # one leaked sentinel would silently *raise* the reported prune
    # rate.  A negative ``touched`` is a caller bug; it is counted here
    # instead of poisoning the math (tests/test_knn_server.py pins
    # both routes).
    invalid_touched: int = 0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def observe(self, bucket: int, n_real: int,
                touched: Optional[int] = None):
        with self._lock:
            self.queries += n_real
            self.batches += 1
            self.padded_rows += bucket - n_real
            self.bucket_counts[bucket] = self.bucket_counts.get(bucket, 0) + 1
            if touched is not None:
                if touched < 0:
                    self.invalid_touched += 1
                else:
                    self.touched_shards += touched
                    self.routed_batches += 1

    def snapshot(self) -> dict:
        """One-lock-acquisition copy of every counter — the consistent
        view: invariants like ``batches == sum(bucket_counts.values())``
        hold inside a snapshot even while ``observe()`` races."""
        with self._lock:
            return {"queries": self.queries, "batches": self.batches,
                    "padded_rows": self.padded_rows,
                    "bucket_counts": dict(self.bucket_counts),
                    "touched_shards": self.touched_shards,
                    "routed_batches": self.routed_batches,
                    "invalid_touched": self.invalid_touched}


@dataclasses.dataclass
class _Pending:
    query: np.ndarray
    l: int
    t_enqueue: float
    future: Future
    # The request's root trace span (obs/trace.py), begun in submit() at
    # t_enqueue on the caller's thread and ended when the micro-batcher
    # resolves the future; the shared no-op span when tracing is off.
    span: object = None


class KnnServer:
    """Serve l-NN queries against a mesh-sharded point set.

    Two backing modes:

    * **Static** — ``points``: (n, dim) host array, sharded over
      ``axis_name`` at construction (n must divide the mesh axis size).
      ``values``: optional (n,) int32 per-point payload (e.g. kNN-LM
      next-token ids), looked up host-side for winners — values never
      cross the device interconnect, preserving the paper's
      only-distances-and-ids-on-the-wire property.

    * **Mutable** — ``store=``: a :class:`repro.store.MutableStore`.  The
      server captures ``store.snapshot()`` at each dispatch: in-flight
      micro-batches keep computing against the generation they captured
      while newer generations land (epoch-swapped serving — snapshots are
      immutable device arrays, so a swap can never tear or drop an
      in-flight query), and every answer reports the generation it was
      computed against.  Buffer shapes are fixed by the store's capacity,
      so mutations never trigger recompilation.

    Synchronous use: ``submit(...)`` then ``flush()`` (or ``query_batch``).
    Server use: ``with server.serving(): ...`` runs the micro-batcher
    thread, which lingers ``cfg.max_wait_ms`` after the first pending
    request to fill a bucket before dispatching.
    """

    def __init__(self, points=None, values=None, labels=None, *, store=None,
                 cfg: KnnServiceConfig = CONFIG, mesh=None,
                 axis_name: str = "knn", seed: int = 0):
        self.cfg = cfg
        if not cfg.bucket_sizes or list(cfg.bucket_sizes) != sorted(
                set(cfg.bucket_sizes)):
            raise ValueError(f"bucket_sizes must be ascending and unique, "
                             f"got {cfg.bucket_sizes}")
        if cfg.route not in ("exact", "pruned"):
            raise ValueError(f"route must be 'exact' or 'pruned', "
                             f"got {cfg.route!r}")
        if cfg.route_compute not in ("host", "device"):
            raise ValueError(f"route_compute must be 'host' or 'device', "
                             f"got {cfg.route_compute!r}")
        if cfg.search not in ("exact", "approx"):
            raise ValueError(f"search must be 'exact' or 'approx', "
                             f"got {cfg.search!r}")
        if cfg.search == "approx" and cfg.index_buckets < 1:
            raise ValueError(f"search='approx' needs index_buckets >= 1, "
                             f"got {cfg.index_buckets}")
        if cfg.predict not in ("none", "vote", "regress"):
            raise ValueError(f"predict must be 'none', 'vote' or "
                             f"'regress', got {cfg.predict!r}")
        if cfg.predict_mode not in ("exact", "ensemble"):
            raise ValueError(f"predict_mode must be 'exact' or 'ensemble', "
                             f"got {cfg.predict_mode!r}")
        self._indexed = cfg.search == "approx"
        self._predict = cfg.predict != "none"
        self._ensemble = self._predict and cfg.predict_mode == "ensemble"
        if self._predict and cfg.sampler != "selection":
            raise ValueError(
                f"predict={cfg.predict!r} needs sampler='selection' "
                f"(the gather baseline has no winner mask to vote over), "
                f"got sampler={cfg.sampler!r}")
        if self._ensemble:
            # The ensemble executable is collective-free by construction
            # (the one-message-per-shard bill is the whole point), so the
            # per-row local-k split must be computed host-side from the
            # touched-shard count — which rules out device routing — and
            # the per-shard local top-l must be the true local top-l,
            # which rules out the approximate bucket index.
            if cfg.search != "exact":
                raise ValueError(
                    "predict_mode='ensemble' requires search='exact' "
                    "(per-shard local votes need the true local top-l)")
            if cfg.route == "pruned" and cfg.route_compute == "device":
                raise ValueError(
                    "predict_mode='ensemble' requires route_compute="
                    "'host': the local-k split needs the touched-shard "
                    "count before the launch")
            if cfg.obs_audit_every > 0 and cfg.predict != "vote":
                raise ValueError(
                    "the accuracy shadow audit (obs_audit_every > 0 with "
                    "predict_mode='ensemble') needs predict='vote' — "
                    "label agreement is defined on class ids")
        self._store = store
        self._labels = None          # device label operand (predict only)
        self._labels_host = None     # host mirror for labels_for (static)
        if store is not None:
            if points is not None or values is not None or labels is not None:
                raise ValueError(
                    "pass either points/values/labels or store=, not both")
            if mesh is not None and mesh != store.mesh:
                raise ValueError("store-backed server uses the store's mesh")
            if self._predict and not store.with_labels:
                raise ValueError(
                    f"predict={cfg.predict!r} needs a labeled store: "
                    f"construct it with with_labels=True "
                    f"(cfg.store_kwargs() does when predict != 'none')")
            self.axis_name = store.axis_name
            self.mesh = store.mesh
            self.k = store.k
            self.dim = store.dim
            self.m_local = store.cap
            self._points = self._ids = None
            self._values = None
        else:
            if points is None:
                raise ValueError("points or store= required")
            self.axis_name = axis_name
            self.mesh = mesh if mesh is not None else make_mesh(
                (jax.device_count(),), (axis_name,))
            # k machines = the size of the service axis only; on a
            # multi-axis mesh the other axes replicate the store and the
            # collectives.
            self.k = int(dict(self.mesh.shape)[axis_name])

            points = np.asarray(points, np.float32)
            n, dim = points.shape
            if n % self.k:
                raise ValueError(
                    f"n_points={n} must divide the mesh axis size {self.k}")
            self.dim = dim
            self.m_local = n // self.k
            sharded = NamedSharding(self.mesh, P(axis_name))
            self._points = jax.device_put(points, sharded)
            self._ids = jax.device_put(np.arange(n, dtype=np.int32), sharded)
            self._values = None if values is None else np.asarray(values,
                                                                  np.int32)
            if labels is not None:
                labels = np.asarray(labels, np.float32)
                if labels.shape != (n,):
                    raise ValueError(f"labels shape {labels.shape} != "
                                     f"({n},)")
                self._labels_host = labels
                self._labels = jax.device_put(labels, sharded)
            if self._predict and self._labels is None:
                raise ValueError(
                    f"predict={cfg.predict!r} on a static server needs "
                    f"the labels= constructor argument")

        # Static-point routing summaries, built once at generation 0
        # (store-backed servers instead capture the store's
        # generation-coupled summaries at every dispatch — the sketch
        # there is the *store's*, configured at store construction, so a
        # conflicting service config must fail loudly rather than be
        # silently ignored).
        self._summaries = None
        if cfg.route == "pruned":
            if store is None:
                self._summaries = summaries_mod.build_summaries(
                    points, self.k,
                    num_projections=cfg.route_num_projections,
                    seed=cfg.route_proj_seed,
                    num_pivots=cfg.summary_pivots)
            elif (store.summary_projections != cfg.route_num_projections
                    or store.summary_seed != cfg.route_proj_seed
                    or store.summary_pivots != cfg.summary_pivots):
                raise ValueError(
                    f"route summary sketch mismatch: store was built with "
                    f"summary_projections={store.summary_projections}"
                    f"/summary_seed={store.summary_seed}"
                    f"/summary_pivots={store.summary_pivots} but cfg asks "
                    f"for route_num_projections={cfg.route_num_projections}"
                    f"/route_proj_seed={cfg.route_proj_seed}"
                    f"/summary_pivots={cfg.summary_pivots}; "
                    f"configure the store, or match the config to it")

        # search="approx" bucket index (store/index.py, DESIGN.md §13).
        # Store-backed: the index is the *store's* — generation-coupled,
        # captured per dispatch via serving_snapshot() — so a knob
        # conflict fails loudly, like the routing sketch above.  Static:
        # built once over the construction points, generation 0 forever.
        self._index0 = None
        if self._indexed:
            if store is None:
                idx = index_mod.IndexMaintainer(
                    self.k, self.m_local, self.dim, cfg.index_buckets)
                idx.rebuild(points, np.ones(len(points), bool))
                self._index0 = idx.freeze(0)
            elif store.index_buckets != cfg.index_buckets:
                raise ValueError(
                    f"search index mismatch: store was built with "
                    f"index_buckets={store.index_buckets} (0 = no index "
                    f"maintained) but cfg asks for "
                    f"index_buckets={cfg.index_buckets}; construct the "
                    f"store from cfg.store_kwargs(), or match the config "
                    f"to it")

        # Pre-flight kernel-dispatch report, one row per bucket shape:
        # the routing (Pallas kernel / interpret / jnp oracle) of the
        # l2_distance step these executables run, plus fused
        # distance_topk eligibility for capacity planning
        # (kernels/ops.py service_envelope).
        self.envelopes = [
            kops.service_envelope(b, self.m_local, self.dim, cfg.l_max)
            for b in cfg.bucket_sizes]

        # The exact-fold executable is built even for ensemble servers:
        # it is the oracle the accuracy shadow audit replays through.
        self._fn = self._build_executable()
        self._ensemble_fn = (self._build_ensemble_executable()
                             if self._ensemble else None)
        # route_compute="device": fold the routing decision into the same
        # jitted program as the query (Pallas prologue, kernels/routing.py).
        # The packed summary operands are cached per frozen-summaries
        # object — identity, not generation, because a background
        # re-tighten re-freezes at the *same* generation with tighter
        # bounds (store/maintenance.py) and the cache must follow it.
        self._route_fn = None
        self._packed_cache = None
        self._ipacked_cache = None
        if cfg.route == "pruned" and cfg.route_compute == "device":
            self._route_fn = self._build_device_router()
        self._base_key = jax.random.PRNGKey(seed)
        self._batch_counter = 0

        self._cv = threading.Condition()
        self._pending: list[_Pending] = []
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self.stats = ServerStats()

        # ---- observability plane (src/repro/obs/, DESIGN.md §12) ----
        # Tracer per cfg.obs_trace (no-op when off); a private metrics
        # registry (always live — counters/histograms are O(1) observes);
        # the Theorem-1 contract auditor (always on — it is arithmetic on
        # numbers _dispatch computes anyway) and the sampled shadow-exact
        # auditor (cfg.obs_audit_every > 0 and a pruned route).  A
        # store-backed server attaches its plane to the store so applies
        # and maintenance cycles land in the same trace/registry as the
        # queries racing them.
        self.obs = ObsPlane.from_config(cfg)
        if store is not None:
            store.attach_obs(self.obs)
        reg = self.obs.metrics
        self._m = {
            "queued": reg.histogram("serve.queued_s"),
            "snapshot": reg.histogram("serve.snapshot_s"),
            "route": reg.histogram("serve.route_s"),
            "kernel": reg.histogram("serve.kernel_s"),
            "resolve": reg.histogram("serve.resolve_s"),
            "dispatch": reg.histogram("serve.dispatch_s"),
            "latency": reg.histogram("serve.latency_s"),
            "rounds": reg.histogram("serve.rounds"),
            "messages": reg.histogram("serve.messages"),
            "touched": reg.histogram("serve.touched_shards"),
            "cand_frac": reg.histogram("serve.candidate_fraction"),
            "errors": reg.counter("serve.dispatch_errors"),
        }
        self._contract = ContractAuditor(reg, k=self.k)
        # The shadow replay audits whichever contract this server
        # serves: byte-identity for pruned exact routing, measured
        # recall@l against the floor for the approximate index tier,
        # ensemble-vs-exact label agreement for ensemble prediction.
        if self._ensemble:
            audit_mode, audit_floor = "accuracy", cfg.accuracy_floor
        elif self._indexed:
            audit_mode, audit_floor = "recall", cfg.recall_floor
        else:
            audit_mode, audit_floor = "bytes", cfg.recall_floor
        self._shadow = (ShadowAuditor(
            reg, every=cfg.obs_audit_every,
            mode=audit_mode, floor=audit_floor)
            if cfg.obs_audit_every > 0 else None)
        self._env_by_bucket = dict(zip(cfg.bucket_sizes, self.envelopes))
        # ---- operator layer (obs/explain.py, obs/slo.py, obs/export.py,
        # DESIGN.md §14) ----
        # Explain captures are always on: per dispatch they cost one
        # small object of references to things the dispatch already
        # holds; the report itself is assembled lazily.  The ring keeps
        # the newest records for explain_last().
        self._explains: deque = deque(maxlen=256)
        # The SLO engine exists only when the config declares at least
        # one objective (slo_* knobs); it shares this server's registry
        # (event windows) and tracer (alert spans).
        self._slo = SloEngine.from_config(cfg, reg, self.obs.tracer)
        # Metrics exposition endpoint: >0 = that localhost port, -1 =
        # ephemeral (tests), 0 = off.
        self._http = None
        if cfg.obs_http_port != 0:
            self._http = ObsHttpServer(
                reg, port=max(cfg.obs_http_port, 0),
                snapshot_fn=self.obs_snapshot)

    # ---- compiled dispatch ---------------------------------------------

    def _distances_fn(self):
        if self.cfg.distance_impl == "auto":
            # masked-aware: pushes a store's valid mask down into the
            # kernels layer (core/knn._masked_distances convention)
            def fn(q, p, valid=None):
                return kops.l2_distance(q, p, valid=valid)
            fn.supports_valid = True
            return fn
        # plain jnp path: _masked_distances applies the mask when needed
        return knn_mod.squared_l2_distances

    def _build_executable(self):
        cfg = self.cfg
        axis = self.axis_name
        l_max = cfg.l_max
        distances_fn = self._distances_fn()
        # The valid-mask operand exists only for store-backed servers;
        # static servers keep the unmasked executable (no per-query
        # masking cost for a point set that can never change).
        masked = self._store is not None

        # route="pruned" adds one (k,) bool operand; in_spec P(axis) hands
        # each shard its own flag, which core/knn folds into the valid
        # mask ahead of the fused distance+top-l kernel.
        routed = cfg.route == "pruned"
        # search="approx" adds one (n,) bool per-slot candidate operand —
        # the bucket index's keep decision, folded into the same mask
        # (core/knn point_candidates); P(axis) hands each shard its own
        # slots.
        indexed = self._indexed
        # cfg.predict adds one (n,) f32 per-slot label operand carried
        # through the local top-l permutation (core/knn local_top_l
        # extra=), and two replicated outputs: the predicted label and
        # its confidence, folded from the winner mask inside the same
        # program (predict/vote.py — one extra psum).
        predicting = self._predict

        if cfg.sampler == "selection":
            def body(pts, pids, pvalid, plabels, pcand, active, q, l_arr,
                     key):
                res = knn_mod.knn_query_batched(
                    pts, pids, q, l_max, l_arr, key, axis_name=axis,
                    distances_fn=distances_fn,
                    use_sampling=cfg.use_sampling,
                    num_pivots=cfg.num_pivots,
                    point_valid=pvalid, shard_active=active,
                    point_candidates=pcand, point_labels=plabels)
                out = (res.dists, res.ids, res.selection.iterations,
                       res.prune.survivors)
                if plabels is None:
                    return out
                label, conf, _detail = predict_mod.exact_predict(
                    res, l_arr, predict=cfg.predict,
                    num_classes=cfg.num_classes, axis_name=axis)
                return out + (label, conf)
        elif cfg.sampler == "gather":
            def body(pts, pids, pvalid, plabels, pcand, active, q, l_arr,
                     key):
                sd, si = knn_mod.knn_simple(
                    pts, pids, q, l_max, axis_name=axis,
                    distances_fn=distances_fn, point_valid=pvalid,
                    shard_active=active, point_candidates=pcand)
                # per-request l: slots at rank >= l[b] are masked to the
                # sentinel (knn_simple returns ascending order).
                keep = jnp.arange(l_max)[None, :] < l_arr[:, None]
                sd = jnp.where(keep, sd, jnp.inf)
                si = jnp.where(keep, si, _ID_SENTINEL)
                zeros = jnp.zeros(q.shape[:1], jnp.int32)
                return sd, si, jnp.int32(0), zeros
        else:
            raise ValueError(f"unknown sampler {cfg.sampler!r}")

        # Operand layout composes by flag, always in this order:
        #   pts, pids, [pvalid], [plabels], [pcand], [active], q, l_arr, key
        # — every present optional operand is sharded P(axis).  The
        # dispatch/warmup/replay sites assemble operands in the same
        # order from the same flags.
        def fn(*a):
            it = iter(a)
            pts, pids = next(it), next(it)
            pvalid = next(it) if masked else None
            plabels = next(it) if predicting else None
            pcand = next(it) if indexed else None
            active = next(it) if routed else None
            q, l_arr, key = next(it), next(it), next(it)
            return body(pts, pids, pvalid, plabels, pcand, active, q,
                        l_arr, key)

        n_sharded = (2 + int(masked) + int(predicting) + int(indexed)
                     + int(routed))
        in_specs = (P(axis),) * n_sharded + (P(None), P(None), P(None))
        out_specs = (P(None), P(None), P(), P(None))
        if predicting:
            out_specs = out_specs + (P(None), P(None))

        return jax.jit(shard_map(
            fn, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False))

    def _build_ensemble_executable(self):
        """The one-message-per-shard prediction program (predict/
        ensemble.py, arXiv 1812.05005).

        Collective-free by construction: each shard computes its masked
        local top-l (tombstones, routed-away shards, and bucket padding
        enter at +inf exactly as in the exact path) and reduces its
        first ``kl`` finite candidates to a class histogram / (sum,
        count) pair.  The output leaves the program *sharded*
        (out_spec P(axis) → host (k, B, C)): in the k-machine model each
        routed shard sends exactly one O(C) message and nothing else —
        the ``messages == touched_shards`` bill ``_accounting`` charges
        and bench_serve hard-asserts.  The per-row local-k operand
        ``kl`` comes from the host (predict/ensemble.local_k_for), which
        is why ensemble mode requires host-computed routing.
        """
        cfg = self.cfg
        axis = self.axis_name
        l_max = cfg.l_max
        distances_fn = self._distances_fn()
        masked = self._store is not None
        routed = cfg.route == "pruned"
        vote = cfg.predict == "vote"
        num_classes = cfg.num_classes

        def fn(*a):
            it = iter(a)
            pts, pids = next(it), next(it)
            pvalid = next(it) if masked else None
            plabels = next(it)
            active = next(it) if routed else None
            q, kl = next(it), next(it)
            valid = knn_mod._apply_shard_routing(pvalid, active,
                                                 pts.shape[0])
            d_full = knn_mod._masked_distances(distances_fn, q, pts,
                                               valid)
            d, _gid, labels_top = knn_mod.local_top_l(
                d_full, pids, l_max, extra=plabels)
            if vote:
                out = predict_mod.local_vote(d, labels_top, kl,
                                             num_classes)
            else:
                out = predict_mod.local_mean(d, labels_top, kl)
            return out[None]          # (1, B, C) -> stacked (k, B, C)

        n_sharded = 3 + int(masked) + int(routed)
        in_specs = (P(axis),) * n_sharded + (P(None), P(None))
        return jax.jit(shard_map(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=P(axis),
            check_vma=False))

    def _build_device_router(self):
        """Outer jitted program: route prologue + the shard_map query.

        The prologue runs ``kops.route_mask`` (the Pallas routing kernel,
        kernels/routing.py) over the whole micro-batch, reduces the
        per-row keep mask to the batch's union ``active`` vector, feeds
        it to the routed executable as its (k,) shard-active operand, and
        returns ``active`` as a fifth output — the touched-shard set
        rides the launch home with the answers, replacing the host
        numpy ``summaries.route_shards`` pass per dispatch.  Nested jit
        inlines, so the whole thing is one cached executable per bucket.

        With ``search="approx"`` the prologue grows its second stage:
        the per-row shard keep feeds ``kops.index_mask`` (the bucket-
        granular threshold kernel), the batch-union bucket keep is
        decoded to the (n,) per-slot candidate operand through the
        cached ``colidx``/``has`` maps (``_index_ops_for``), and the
        bucket keep comes home as a sixth output so the dispatcher can
        report the candidate fraction from the index's own live counts
        without a device readback.
        """
        inner = self._fn
        slack = self.cfg.route_slack

        if not self._indexed:
            def routed(operands, packed, q, l_arr, key):
                rows = kops.route_mask(q, l_arr, packed, slack=slack)
                active = jnp.any(rows, axis=0)
                out = inner(*operands, active, q, l_arr, key)
                # d, i, iters, surv [, label, conf] + the touched set
                return tuple(out) + (active,)

            return jax.jit(routed)

        oversample = self.cfg.index_oversample

        def routed_indexed(operands, packed, ipacked, colidx, has,
                           q, l_arr, key):
            rows = kops.route_mask(q, l_arr, packed, slack=slack)
            active = jnp.any(rows, axis=0)
            brows = kops.index_mask(q, l_arr, rows, ipacked,
                                    oversample=oversample)
            keep_any = jnp.any(brows, axis=0)          # (k·b,)
            cand = has & keep_any[colidx]              # (n,) slot mask
            out = inner(*operands, cand, active, q, l_arr, key)
            return tuple(out) + (active, keep_any)

        return jax.jit(routed_indexed)

    def _packed_for(self, summ):
        """Kernel-layout summary operands for ``summ``, cached by object
        identity (one frozen ShardSummaries == one packed tuple; a
        benign last-writer-wins race between concurrent dispatchers just
        repacks once more)."""
        cached = self._packed_cache
        if cached is None or cached[0] is not summ:
            cached = (summ, routing_mod.pack_summaries(summ))
            self._packed_cache = cached
        return cached[1]

    def _index_ops_for(self, index):
        """Device-router operands for ``index``, cached by object
        identity like ``_packed_for``: the kernel-layout packed tuple
        (kernels/routing.pack_index) plus the flat slot decode that
        turns the kernel's (k·b,) bucket keep into the executable's
        (n,) per-slot candidate operand — ``colidx = shard·b + bucket``
        per slot, ``has = slot is assigned`` (dead/free slots are never
        candidates)."""
        cached = self._ipacked_cache
        if cached is None or cached[0] is not index:
            packed = routing_mod.pack_index(index)
            a = index.assign                        # (k·cap,) int32
            shard = np.arange(a.shape[0], dtype=np.int32) // self.m_local
            colidx = (shard * index.num_buckets
                      + np.maximum(a, 0)).astype(np.int32)
            cached = (index, packed, colidx, a >= 0)
            self._ipacked_cache = cached
        return cached[1], cached[2], cached[3]

    def _backing_arrays(self):
        """(executable operands, generation, summaries, index) for one
        dispatch.

        Store-backed servers capture the current snapshot here — the
        epoch-swap point.  The returned arrays are immutable, so a batch
        dispatched before a flush finishes cleanly against its own
        generation no matter how many swaps land meanwhile.  Snapshot,
        routing summaries, and (for ``search="approx"``) the bucket
        index come from one lock acquisition (``routing_snapshot`` /
        ``serving_snapshot``), so neither can ever describe a different
        generation than the arrays being queried; for static servers the
        construction-time summaries/index are generation 0 forever.
        """
        if self._store is not None:
            if self._indexed:
                snap, summ, idx = self._store.serving_snapshot()
            else:
                (snap, summ), idx = self._store.routing_snapshot(), None
            ops = (snap.points, snap.ids, snap.valid)
            if self._predict:
                ops = ops + (snap.labels,)
            return ops, snap.generation, summ, idx
        ops = (self._points, self._ids)
        if self._predict:
            ops = ops + (self._labels,)
        return ops, 0, self._summaries, self._index0

    def placement_stats(self) -> dict:
        """Locality and bound fidelity of the layout being served, as
        routing sees it.

        ``live_per_shard``: per-shard live histogram (the balance the
        placement guardrail and the compactor defend; uniform
        ``m_local`` for a static server).  ``prune_rate``: fraction of
        shard visits the summary lower-bound test avoided across all
        routed dispatches so far — ``1 − touched/(batches·k)``, 0.0
        until a ``route="pruned"`` batch has run.  ``summary_slack``:
        per-shard covering-radius decay (maintained radius minus exact
        live radius, summaries.summary_slack) — how much certified
        pruning power incremental maintenance has cost since the last
        exact rebuild; identically 0.0 for a static server, whose
        summaries are exact at construction forever.  ``maintenance``:
        the adaptive subsystem's knobs and counters (re-tightenings,
        splits — store/adaptive.py).  Benchmarks read this after an
        ingest phase to report per-policy prune rate and bound decay
        (DESIGN.md Sections 9 and 10).
        """
        snap = self.stats.snapshot()
        touched = snap["touched_shards"]
        routed = snap["routed_batches"]
        if self._store is not None:
            hist = [int(v) for v in self._store.live_per_shard]
            placement = self._store.placement
            redeal = self._store.redeal
            slack = [float(v) for v in self._store.summary_slack()]
            maintenance = self._store.maintenance_stats()
        else:
            hist = [self.m_local] * self.k
            placement = redeal = "static"
            slack = [0.0] * self.k
            maintenance = {"summary_pivots": self.cfg.summary_pivots,
                           "retighten_every": 0,
                           "split_radius_factor": 0.0,
                           "retightens": 0, "splits": 0}
        rate = 1.0 - touched / (routed * self.k) if routed else 0.0
        return {"placement": placement, "redeal": redeal,
                "live_per_shard": hist, "routed_batches": routed,
                "prune_rate": rate,
                "summary_slack": slack,
                "max_summary_slack": max(slack) if slack else 0.0,
                "maintenance": maintenance}

    def obs_snapshot(self) -> dict:
        """The unified observability view (DESIGN.md §12): one dict with
        the legacy serving counters, this server's metric registry
        (per-stage latency histograms, round/message/touched histograms,
        store + maintenance timings when a store is attached), the
        process-wide kernel-fallback counters (kernels/ops.py tallies
        into the default registry — no server handle down there), tracer
        ring stats, both auditors' verdicts, and ``placement_stats()``.
        Benchmarks consume this instead of private tallies
        (benchmarks/common.py ``obs_section``)."""
        shadow = (self._shadow.snapshot() if self._shadow is not None
                  else {"every": 0, "checks": 0, "divergences": 0,
                        "details": []})
        return {
            "server": self.stats.snapshot(),
            "metrics": self.obs.metrics.snapshot(),
            "kernel": default_registry().snapshot(prefix="kernel."),
            "trace": self.obs.tracer.stats(),
            "audit": {"contract": self._contract.snapshot(),
                      "shadow": shadow},
            "slo": (self._slo.snapshot() if self._slo is not None
                    else {"objectives": {}, "firing": [],
                          "alerts_fired": 0, "alerts_cleared": 0}),
            "placement": self.placement_stats(),
        }

    def explain_last(self, n: int = 1) -> list[dict]:
        """Built explain reports of the newest ``n`` resolved requests
        (oldest of the n first) — the operator's "why was that one
        slow/broad?" entry point; ``QueryResult.explain()`` answers the
        same for a result you still hold."""
        if n < 1:
            return []
        recs = list(self._explains)[-n:]
        return [r.build() for r in recs]

    def export_trace_jsonl(self, path_or_file) -> int:
        """Dump the tracer ring as JSONL (0 spans when tracing is off)."""
        return self.obs.tracer.export_jsonl(path_or_file)

    def close(self) -> None:
        """Quiesce the micro-batcher and release the exposition endpoint
        (idempotent; servers without an endpoint just stop())."""
        self.stop()
        if self._http is not None:
            self._http.close()

    def warmup(self):
        """Compile every bucket shape up front (one trace per bucket)."""
        operands, _, summ, idx = self._backing_arrays()
        if self._route_fn is not None:
            packed = self._packed_for(summ)
            iops = self._index_ops_for(idx) if self._indexed else ()
            for b in self.cfg.bucket_sizes:
                q = np.zeros((b, self.dim), np.float32)
                l_arr = np.zeros(b, np.int32)
                out = self._route_fn(operands, packed, *iops, q, l_arr,
                                     self._base_key)
                jax.block_until_ready(out)
            return
        if self._ensemble_fn is not None:
            eops = operands
            if self.cfg.route == "pruned":
                eops = eops + (np.ones(self.k, bool),)
            for b in self.cfg.bucket_sizes:
                q = np.zeros((b, self.dim), np.float32)
                kl = np.zeros(b, np.int32)
                jax.block_until_ready(self._ensemble_fn(*eops, q, kl))
        if self._indexed:
            operands = operands + (np.ones(self.k * self.m_local, bool),)
        if self.cfg.route == "pruned":
            operands = operands + (np.ones(self.k, bool),)
        for b in self.cfg.bucket_sizes:
            q = np.zeros((b, self.dim), np.float32)
            l_arr = np.zeros(b, np.int32)
            out = self._fn(*operands, q, l_arr, self._base_key)
            jax.block_until_ready(out)

    # ---- store passthrough ----------------------------------------------
    # The server is most callers' only handle on the serving stack, so
    # the store's mutation and payload APIs are exposed here 1:1 (same
    # signatures, same atomic-batch semantics).  Static servers raise:
    # their point set is immutable by construction.

    def _require_store(self, op: str):
        if self._store is None:
            raise ValueError(f"{op}() needs a store-backed server "
                             f"(construct with store=)")
        return self._store

    def insert(self, points, ids=None, values=None, labels=None):
        """Stage point insertions on the backing store; returns the
        assigned global ids (see MutableStore.insert — ``values`` needs
        with_values, ``labels`` needs with_labels)."""
        return self._require_store("insert").insert(
            points, ids=ids, values=values, labels=labels)

    def update(self, ids, points, labels=None):
        """Stage in-place point overwrites; omitted ``labels`` keep the
        current label payload (MutableStore.update)."""
        return self._require_store("update").update(ids, points,
                                                    labels=labels)

    def delete(self, ids):
        """Stage deletions by global id (MutableStore.delete)."""
        return self._require_store("delete").delete(ids)

    def flush_store(self) -> int:
        """Apply staged mutations as one epoch swap; returns the new
        generation (MutableStore.flush)."""
        return self._require_store("flush_store").flush()

    @property
    def with_values(self) -> bool:
        """Whether answers carry the int payload table (store
        with_values, or the static ``values=`` argument)."""
        return (self._store.with_values if self._store is not None
                else self._values is not None)

    @property
    def with_labels(self) -> bool:
        """Whether a label payload is attached (store with_labels, or
        the static ``labels=`` argument)."""
        return (self._store.with_labels if self._store is not None
                else self._labels is not None)

    def values_for(self, ids):
        """Map global ids to int payload values, -1 where absent."""
        if self._store is not None:
            return self._store.values_for(ids)
        if self._values is None:
            raise RuntimeError("server has no value payload")
        ids = np.asarray(ids)
        safe = np.clip(ids, 0, len(self._values) - 1)
        return np.where(ids == _ID_SENTINEL, -1, self._values[safe])

    def labels_for(self, ids):
        """Map global ids to label payloads, NaN where absent."""
        if self._store is not None:
            return self._store.labels_for(ids)
        if self._labels_host is None:
            raise RuntimeError("server has no label payload")
        ids = np.asarray(ids)
        safe = np.clip(ids, 0, len(self._labels_host) - 1)
        return np.where(ids == _ID_SENTINEL, np.nan,
                        self._labels_host[safe]).astype(np.float32)

    # ---- request path ---------------------------------------------------

    def submit(self, query, l: Optional[int] = None) -> Future:
        """Enqueue one query; the Future resolves to a QueryResult."""
        l = self.cfg.l if l is None else int(l)
        if not 1 <= l <= self.cfg.l_max:
            raise ValueError(f"l={l} outside [1, l_max={self.cfg.l_max}]")
        query = np.asarray(query, np.float32)
        if query.shape != (self.dim,):
            raise ValueError(f"query shape {query.shape} != ({self.dim},)")
        t_enq = time.perf_counter()
        # Root span of this request's trace, opened at the enqueue
        # timestamp so the retroactive "queued" child always nests.
        span = self.obs.tracer.begin("request", t0=t_enq, l=l)
        rec = _Pending(query, l, t_enq, Future(), span)
        with self._cv:
            self._pending.append(rec)
            self._cv.notify()
        return rec.future

    def query_batch(self, queries, ls=None) -> list[QueryResult]:
        """Synchronous convenience: submit all, flush, collect."""
        queries = np.asarray(queries, np.float32)
        if ls is None:
            ls = [None] * len(queries)
        futs = [self.submit(q, l) for q, l in zip(queries, ls)]
        self.flush()
        return [f.result() for f in futs]

    def flush(self):
        """Drain the queue now, bucket by bucket (synchronous path)."""
        while True:
            with self._cv:
                if not self._pending:
                    return
                chunk = self._take_chunk_locked()
            self._dispatch(chunk)

    def _take_chunk_locked(self) -> list[_Pending]:
        n = min(len(self._pending), self.cfg.bucket_sizes[-1])
        chunk, self._pending = self._pending[:n], self._pending[n:]
        return chunk

    def _bucket_for(self, n: int) -> int:
        for b in self.cfg.bucket_sizes:
            if b >= n:
                return b
        return self.cfg.bucket_sizes[-1]

    def _accounting(self, iterations: int,
                    touched: int) -> tuple[int, int]:
        """k-machine (rounds, messages) for one dispatched batch.

        ``touched`` is the batch's touched-shard count (k when
        route="exact"): a pruned shard holds no candidates, so it never
        sends — the leader tree carries ``touched - 1`` peers' payloads
        per round instead of ``k - 1``.

        Ensemble prediction replaces the whole selection pipeline: one
        local pass, one O(C) answer per routed shard, zero collectives —
        1 round, exactly ``touched`` messages (the contract bench_serve
        hard-asserts per query).  Exact prediction adds the class
        histogram / value-sum psum on top of selection: +1 round,
        +(touched − 1) messages.
        """
        t = max(int(touched), 1)
        if self._ensemble:
            return 1, t
        if self.cfg.sampler == "gather":
            # one all-gather whose per-peer payload is l_max scalars
            return 1, (t - 1) * self.cfg.l_max
        rounds = 2 * iterations            # pivot all_gather + count psum
        rounds += 2 if self.cfg.use_sampling else 0   # sample + verify
        rounds += 2                        # result gather: count + pack
        messages = (t - 1) * rounds
        if self._predict:
            rounds += 1                    # the exact-predict psum
            messages += t - 1
        return rounds, messages

    def _unpack_outputs(self, out):
        """Host-side view of one executable's outputs: ``(d, i, iters,
        surv, pred)`` where ``pred`` is the ``(label, confidence)`` pair
        when the config predicts and ``()`` otherwise (the executable's
        output arity follows the same flag)."""
        d, i, iters, surv = out[:4]
        d, i = np.asarray(d), np.asarray(i)
        surv, iters = np.asarray(surv), int(iters)
        pred = tuple(np.asarray(x) for x in out[4:])
        return d, i, iters, surv, pred

    def _ensemble_call(self, operands, active, q, l_arr, touched):
        """Serve one micro-batch in ensemble mode: local-k split on the
        host, one collective-free launch, host aggregation.

        Returns ``(d, i, iters, surv, pred, payload, votes, kl)`` shaped
        like the exact path's outputs so the dispatch tail is shared:
        ``d``/``i`` are all-sentinel (no point identity ever leaves its
        shard — that is the mode's bill), ``payload`` the (k, B, C)
        per-shard answers for the explain vote table, ``votes`` the
        (B, C) shard-vote tally (classification only), ``kl`` the per-row
        local-k actually used.
        """
        cfg = self.cfg
        kl = predict_mod.local_k_for(l_arr, touched, cfg.local_k,
                                     cfg.l_max)
        ops = operands if active is None else operands + (active,)
        payload = np.asarray(self._ensemble_fn(*ops, q, kl))
        act = (np.ones(self.k, bool) if active is None
               else np.asarray(active, bool))
        if cfg.predict == "vote":
            label, conf, votes = predict_mod.aggregate_vote(payload, act)
        else:
            label, conf = predict_mod.aggregate_regress(payload, act)
            votes = None
        b = q.shape[0]
        d = np.full((b, cfg.l_max), np.inf, np.float32)
        i = np.full((b, cfg.l_max), _ID_SENTINEL, np.int32)
        surv = np.zeros(b, np.int32)
        return d, i, 0, surv, (label, conf), payload, votes, kl

    def _dispatch(self, chunk: list[_Pending]):
        n = len(chunk)
        bucket = self._bucket_for(n)
        q = np.zeros((bucket, self.dim), np.float32)
        l_arr = np.zeros(bucket, np.int32)      # padding rows keep l=0
        for row, rec in enumerate(chunk):
            q[row] = rec.query
            l_arr[row] = rec.l

        # _dispatch may run concurrently from the micro-batcher thread and
        # a caller's flush(); counter and stats updates go under the lock.
        with self._cv:
            batch_id = self._batch_counter
            self._batch_counter += 1
        key = jax.random.fold_in(self._base_key, batch_id)
        tracer = self.obs.tracer
        t_dispatch = time.perf_counter()
        # Per-batch trace root; request trees point at it through their
        # "serve" child's batch attribute (cross-tree reference by
        # attribute, never by parent link — trees stay single-rooted).
        dspan = tracer.begin("dispatch", t0=t_dispatch, batch=batch_id,
                             bucket=bucket, n_real=n)
        env = self._env_by_bucket[bucket]
        batch_spans = [dspan]        # every begun span, ended on error too
        # Stage boundaries are stamped explicitly (not read back off the
        # spans) so the per-stage histograms stay populated with tracing
        # off — the no-op span carries no clock.
        try:
            t_snap0 = time.perf_counter()
            sspan = tracer.begin("snapshot", parent=dspan, t0=t_snap0)
            batch_spans.append(sspan)
            operands, generation, summ, idx = self._backing_arrays()
            if self._store is not None:
                n_live = int(self._store.live_per_shard.sum())
                maint0 = self._store.maint_commit_clock()
            else:
                n_live = self.m_local * self.k
                maint0 = (0, None)
            sspan.end(generation=generation, n_live=n_live)
            t_snap1 = time.perf_counter()
            t_route0 = t_route1 = None
            cand_frac = None       # search="approx" kept-live fraction
            keep_arr = None        # (k, b) batch-union bucket keep
            active_arr = None      # (k,) batch-union shard keep
            pred = ()              # (label, conf) when predicting
            epayload = evotes = kl = None    # ensemble-mode extras
            kattrs = dict(path=env["path"], l2_path=env["l2_path"],
                          fallback=env["fallback_reason"] or "")
            if self._route_fn is not None:
                # Device routing: the Pallas prologue computes the
                # touched-shard union inside the same launch as the
                # query; ``active`` comes back with the batch — so the
                # routing decision has no separate interval and its span
                # is recorded over the fused launch.
                t_kern0 = time.perf_counter()
                kspan = tracer.begin("kernel", parent=dspan, t0=t_kern0,
                                     route_compute="device", **kattrs)
                batch_spans.append(kspan)
                packed = self._packed_for(summ)
                if self._indexed:
                    iops = self._index_ops_for(idx)
                    *out, active, keep_any = self._route_fn(
                        operands, packed, *iops, q, l_arr, key)
                    keep_arr = np.asarray(keep_any).reshape(
                        self.k, idx.num_buckets)
                    cand_frac = index_mod.candidate_fraction(
                        idx, keep_arr)
                else:
                    *out, active = self._route_fn(operands, packed, q,
                                                  l_arr, key)
                d, i, iters, surv, pred = self._unpack_outputs(out)
                active_arr = np.asarray(active)
                touched = int(active_arr.sum())
                kspan.end(touched=touched)
                t_kern1 = time.perf_counter()
                tracer.record("route", t_kern0, t_kern1, parent=dspan,
                              compute="device", fused=True,
                              touched=touched, slack=self.cfg.route_slack)
            elif self.cfg.route == "pruned":
                # Touched-shard set for this micro-batch: the union over
                # real rows of the summary lower-bound survivors (padding
                # rows carry l=0 and route nowhere).  One collective pass
                # serves the whole batch, so the device mask is the union;
                # accounting charges only the touched subset.
                t_route0 = time.perf_counter()
                rspan = tracer.begin("route", parent=dspan, t0=t_route0,
                                     compute="host",
                                     slack=self.cfg.route_slack)
                batch_spans.append(rspan)
                active_rows = summaries_mod.route_shards(
                    summ, q, l_arr, slack=self.cfg.route_slack)
                active = active_rows.any(axis=0)
                active_arr = active
                touched = int(active.sum())
                extra = ()
                if self._indexed:
                    # Second prologue stage, bucket granularity: the
                    # per-row shard keep gates which buckets can
                    # compete, the batch-union bucket keep becomes the
                    # (n,) per-slot candidate operand (store/index.py).
                    pcand, cand_frac, keep_arr = self._host_candidates(
                        idx, q, l_arr, active_rows)
                    extra = (pcand,)
                rspan.end(touched=touched)
                t_route1 = time.perf_counter()
                kspan = tracer.begin("kernel", parent=dspan, t0=t_route1,
                                     route_compute="host", **kattrs)
                batch_spans.append(kspan)
                if self._ensemble:
                    (d, i, iters, surv, pred, epayload, evotes,
                     kl) = self._ensemble_call(operands, active, q,
                                               l_arr, touched)
                else:
                    out = self._fn(*operands, *extra, active, q, l_arr,
                                   key)
                    d, i, iters, surv, pred = self._unpack_outputs(out)
                kspan.end()
                t_kern0, t_kern1 = t_route1, time.perf_counter()
            else:
                touched = self.k
                extra = ()
                if self._indexed:
                    t_route0 = time.perf_counter()
                    rspan = tracer.begin("route", parent=dspan,
                                         t0=t_route0, compute="host",
                                         indexed=True)
                    batch_spans.append(rspan)
                    pcand, cand_frac, keep_arr = self._host_candidates(
                        idx, q, l_arr, None)
                    extra = (pcand,)
                    rspan.end()
                    t_route1 = time.perf_counter()
                t_kern0 = time.perf_counter()
                kspan = tracer.begin("kernel", parent=dspan, t0=t_kern0,
                                     **kattrs)
                batch_spans.append(kspan)
                if self._ensemble:
                    (d, i, iters, surv, pred, epayload, evotes,
                     kl) = self._ensemble_call(operands, None, q, l_arr,
                                               touched)
                else:
                    out = self._fn(*operands, *extra, q, l_arr, key)
                    d, i, iters, surv, pred = self._unpack_outputs(out)
                kspan.end()
                t_kern1 = time.perf_counter()
        except Exception as exc:
            # A failed dispatch must never strand its futures (the chunk
            # already left the queue), kill the micro-batcher thread, or
            # leave torn spans behind.
            self._m["errors"].inc()
            for rec in chunk:
                _resolve(rec.future, error=exc)
                if rec.span is not None:
                    rec.span.end(error=type(exc).__name__)
            for sp in reversed(batch_spans):      # Span.end is idempotent
                sp.end(error=type(exc).__name__)
            return
        t_done = time.perf_counter()

        rounds, messages = self._accounting(iters, touched)
        self.stats.observe(
            bucket, n,
            touched=touched if self.cfg.route == "pruned" else None)
        l_real = max((rec.l for rec in chunk), default=1)
        # Theorem-1 contract: always-on envelope check.  The gather
        # sampler's bill charges the static buffer width l_max per peer,
        # so its envelope is checked against the same width.
        audit_l = (self.cfg.l_max if self.cfg.sampler == "gather"
                   else l_real)
        contract_ok = self._contract.check(
            l_max=audit_l, n_live=n_live, rounds=rounds, messages=messages,
            use_sampling=self.cfg.use_sampling, sampler=self.cfg.sampler,
            generation=generation)
        if self._store is not None:
            maint1 = self._store.maint_commit_clock()
            head_generation = self._store.generation
        else:
            maint1 = (0, None)
            head_generation = generation
        if self._slo is not None:
            self._slo.measure("contract", 0.0 if contract_ok else 1.0)
        # One capture per dispatch: references to what the dispatch
        # already holds (frozen summaries/index, its own padded query
        # block) plus the scalars above — the explain reports assemble
        # lazily from it (obs/explain.py).
        pmode = ("none" if not self._predict
                 else "ensemble" if self._ensemble else "exact")
        capture = BatchCapture(
            batch_id=batch_id, bucket=bucket, n_real=n,
            generation=generation, route=self.cfg.route,
            route_compute=("device" if self._route_fn is not None
                           else "host"),
            search=self.cfg.search, slack=self.cfg.route_slack,
            oversample=self.cfg.index_oversample,
            queries=q, ls=l_arr, summaries=summ, index=idx,
            active=active_arr, keep_any=keep_arr, touched=touched,
            candidate_fraction=cand_frac,
            predict=self.cfg.predict, predict_mode=pmode,
            labels=(pred[0] if pred else None),
            confidences=(pred[1] if pred else None),
            local_k=kl, shard_answers=epayload, votes=evotes,
            timings={
                "snapshot_s": t_snap1 - t_snap0,
                "route_s": (t_route1 - t_route0
                            if t_route0 is not None else None),
                "kernel_s": t_kern1 - t_kern0,
            },
            maint_before=maint0[0], maint_after=maint1[0],
            maint_last=maint1[1], contract_ok=contract_ok)
        # Shadow-exact audit: replay every Nth pruned/indexed batch
        # through the same executable with every shard active and every
        # slot a candidate — the exact collective at this generation
        # with this key.  For pruned exact routing the contract is
        # byte-identity (tests/test_routing.py as a production signal);
        # for search="approx" the auditor instead measures recall@l
        # against cfg.recall_floor.
        if (self._shadow is not None
                and (self.cfg.route == "pruned" or self._indexed
                     or self._ensemble)
                and self._shadow.due()):
            with tracer.span("shadow_audit", parent=dspan,
                             generation=generation) as aspan:
                all_on = (np.ones(self.k, bool)
                          if self.cfg.route == "pruned" else None)
                if self._ensemble:
                    # Accuracy mode: replay through the exact-fold
                    # executable (all shards active, same generation/key)
                    # and measure ensemble-vs-exact label agreement over
                    # the batch's real rows.
                    ok = self._shadow.check_labels(
                        pred[0], l_arr,
                        lambda: self._exact_label_replay(
                            operands, all_on, q, l_arr, key),
                        generation=generation, batch_id=batch_id,
                        touched=touched)
                    if (self._slo is not None
                            and self._shadow.last_agreement is not None):
                        self._slo.measure("label_agreement",
                                          self._shadow.last_agreement)
                else:
                    ok = self._shadow.check(
                        d, i, lambda: self._exact_replay(operands, all_on,
                                                         q, l_arr, key),
                        generation=generation, batch_id=batch_id,
                        touched=touched)
                    if (self._slo is not None
                            and self._shadow.mode == "recall"
                            and self._shadow.last_min_recall is not None):
                        self._slo.measure("recall_min",
                                          self._shadow.last_min_recall)
                aspan.annotate(diverged=not ok)

        t_res0 = time.perf_counter()
        vspan = tracer.begin("resolve", parent=dspan, t0=t_res0)
        for row, rec in enumerate(chunk):
            # ascending by distance (gather_selected packs by shard rank,
            # not by distance; l is small, so sort host-side — this also
            # keeps the selection and gather A/B paths byte-identical in
            # ordering)
            order = np.argsort(d[row, :rec.l], kind="stable")
            dists = d[row, order]
            ids = i[row, order]
            values = None
            if self._store is not None and self._store.with_values:
                # the store's id -> value map is monotone (entries outlive
                # deletion), so the lookup is valid for any generation's ids
                values = self._store.values_for(ids)
            elif self._values is not None:
                # sentinel slots (fewer than l finite points) map to -1;
                # clip both ends — np.where evaluates the lookup branch
                # for sentinel ids too.
                safe = np.clip(ids, 0, len(self._values) - 1)
                values = np.where(ids == _ID_SENTINEL, -1,
                                  self._values[safe])
            xrec = ExplainRecord(
                capture, row, l=rec.l, dists=dists, ids=ids,
                queued_s=t_dispatch - rec.t_enqueue,
                latency_s=t_done - rec.t_enqueue)
            self._explains.append(xrec)
            _resolve(rec.future, result=QueryResult(
                dists=dists, ids=ids, values=values, l=rec.l,
                iterations=iters, rounds=rounds, messages=messages,
                survivors=int(surv[row]), bucket=bucket,
                queued_s=t_dispatch - rec.t_enqueue,
                latency_s=t_done - rec.t_enqueue,
                generation=generation, shards_touched=touched,
                recall_mode="approx" if self._indexed else "exact",
                explain_ref=xrec,
                label=(float(pred[0][row]) if pred else None),
                confidence=(float(pred[1][row]) if pred else None),
                predict_mode=pmode))
            if rec.span is not None:
                tracer.record("queued", rec.t_enqueue, t_dispatch,
                              parent=rec.span)
                tracer.record("serve", t_dispatch, t_done,
                              parent=rec.span, batch=batch_id)
                rec.span.end(bucket=bucket, generation=generation,
                             route=self.cfg.route, touched=touched,
                             rounds=rounds)
            self._m["queued"].observe(t_dispatch - rec.t_enqueue)
            self._m["latency"].observe(
                time.perf_counter() - rec.t_enqueue)
            if self._slo is not None:
                self._slo.measure("latency_p99",
                                  time.perf_counter() - rec.t_enqueue)
                self._slo.measure("staleness",
                                  head_generation - generation)
        vspan.end()
        dspan.end(touched=touched, generation=generation)
        t_res1 = time.perf_counter()
        m = self._m
        m["snapshot"].observe(t_snap1 - t_snap0)
        m["kernel"].observe(t_kern1 - t_kern0)
        if t_route0 is not None:
            m["route"].observe(t_route1 - t_route0)
        m["resolve"].observe(t_res1 - t_res0)
        m["dispatch"].observe(t_res1 - t_dispatch)
        m["rounds"].observe(rounds)
        m["messages"].observe(messages)
        # Defensive (satellite of the -1 sentinel fix): a negative
        # touched count is QueryResult's "never routed" sentinel, not an
        # observation — it must never enter the serving histograms.
        if touched >= 0:
            m["touched"].observe(touched)
        if cand_frac is not None:
            m["cand_frac"].observe(cand_frac)
        # Explain reports assemble only after the dispatch completes, so
        # this late fill is always visible to them.
        capture.timings["resolve_s"] = t_res1 - t_res0
        if self._slo is not None:
            self._slo.evaluate()

    def _exact_replay(self, operands, all_on, q, l_arr, key):
        """The exact collective for one dispatched batch: the same
        executable, operands, and key, with every shard active
        (``all_on``; None when the server routes exact) and — for an
        indexed server — every slot a candidate.  Answers are host
        arrays ready for the shadow comparison."""
        ops = list(operands)
        if self._indexed:
            ops.append(np.ones(self.k * self.m_local, bool))
        if all_on is not None:
            ops.append(all_on)
        d, i, *_ = self._fn(*ops, q, l_arr, key)
        return np.asarray(d), np.asarray(i)

    def _exact_label_replay(self, operands, all_on, q, l_arr, key):
        """The exact-mode prediction for one ensemble batch: the
        exact-fold executable at the same generation and key with every
        shard active — the oracle the accuracy shadow audit compares the
        one-message-per-shard answer against."""
        ops = list(operands)
        if all_on is not None:
            ops.append(all_on)
        out = self._fn(*ops, q, l_arr, key)
        return np.asarray(out[4])

    def _host_candidates(self, idx, q, l_arr, shard_keep):
        """Host-path bucket prologue for one micro-batch: the (n,)
        per-slot candidate operand, the kept-live fraction, and the
        (k, b) batch-union bucket keep itself (the explain capture
        reports it and cross-checks it against the recomputed rule) —
        store/index.py ``bucket_keep`` -> union across rows ->
        ``candidate_mask``; ``shard_keep`` is the per-row routing
        decision, None = all shards compete."""
        keep = index_mod.bucket_keep(
            idx, q, l_arr, shard_keep=shard_keep,
            oversample=self.cfg.index_oversample)
        keep_any = keep.any(axis=0)
        pcand = index_mod.candidate_mask(idx, keep_any, self.m_local)
        return (pcand, index_mod.candidate_fraction(idx, keep_any),
                keep_any)

    # ---- background micro-batcher ---------------------------------------

    def start(self):
        """Run the micro-batcher thread (linger-then-dispatch loop)."""
        if self._running:
            return
        self._running = True
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="knn-microbatcher",
                                        daemon=True)
        self._thread.start()

    def stop(self):
        """Quiesce the micro-batcher and drain the queue.

        Contract (tests/test_knn_server.py::test_server_stop_drains):

        * every request pending at stop() entry has its Future resolved
          by the time stop() returns — none stranded;
        * each request is dispatched exactly once (the batcher takes a
          chunk under the lock before dispatching, so the final
          ``flush()`` can never re-dispatch a request the exiting
          batcher already took);
        * FIFO order is preserved through the drain;
        * stop() is idempotent and safe to race with itself — the
          thread handle is captured-and-cleared under the lock, so
          exactly one caller joins it and a second concurrent stop()
          just flushes.
        """
        with self._cv:
            self._running = False
            self._cv.notify_all()
            t, self._thread = self._thread, None
        if t is not None:
            t.join()
        self.flush()          # leave no request stranded

    def serving(self):
        return _Serving(self)

    def _serve_loop(self):
        linger = self.cfg.max_wait_ms / 1e3
        full = self.cfg.bucket_sizes[-1]
        while True:
            with self._cv:
                while self._running and not self._pending:
                    self._cv.wait(timeout=0.1)
                if not self._running:
                    return
                # Linger: give the batch a chance to fill before paying a
                # datastore pass for a mostly-padded bucket.
                deadline = self._pending[0].t_enqueue + linger
                while (self._running and len(self._pending) < full
                       and time.perf_counter() < deadline):
                    self._cv.wait(timeout=max(
                        deadline - time.perf_counter(), 1e-4))
                chunk = self._take_chunk_locked()
            if chunk:
                self._dispatch(chunk)


def _resolve(future: Future, result=None, error=None):
    """Resolve a future, tolerating client-side cancellation."""
    try:
        if error is not None:
            future.set_exception(error)
        else:
            future.set_result(result)
    except Exception:
        pass      # already cancelled/resolved by the client — nothing owed


class _Serving:
    def __init__(self, server: KnnServer):
        self._server = server

    def __enter__(self):
        self._server.start()
        return self._server

    def __exit__(self, *exc):
        self._server.stop()
        return False
