from repro.optim.adamw import AdamW, AdamWState, global_norm
from repro.optim.schedule import warmup_cosine
from repro.optim import compress

__all__ = ["AdamW", "AdamWState", "global_norm", "warmup_cosine", "compress"]
