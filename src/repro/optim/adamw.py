"""AdamW from scratch (optax is not available in this container).

Functional API: `init(params) -> state`, `update(grads, state, params, lr)
-> (params, state)`.  Moments are fp32 regardless of parameter dtype (bf16
training keeps master-quality statistics); global-norm clipping included.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: object       # pytree like params, f32
    v: object       # pytree like params, f32


class AdamW(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # bf16 moments halve optimizer residency — the difference between
    # jamba-398B fitting a 256-chip pod or not (EXPERIMENTS.md Section
    # Perf, jamba iteration 4).  Moment *arithmetic* stays f32.
    moment_dtype: object = jnp.float32

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, self.moment_dtype), params)
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params, lr):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm > 0:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        count = state.count + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        dt = self.moment_dtype
        new_m = jax.tree.map(
            lambda m, g: (self.b1 * m.astype(jnp.float32)
                          + (1 - self.b1) * g).astype(dt), state.m, grads)
        new_v = jax.tree.map(
            lambda v, g: (self.b2 * v.astype(jnp.float32)
                          + (1 - self.b2) * g * g).astype(dt),
            state.v, grads)

        def upd(p, m, v):
            m = m.astype(jnp.float32)
            v = v.astype(jnp.float32)
            step = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, new_m, new_v)
        return new_params, AdamWState(count=count, m=new_m, v=new_v)

    def state_specs(self, param_specs):
        """PartitionSpecs for the optimizer state mirroring the params
        (ZeRO: moments shard exactly like their parameters)."""
        from jax.sharding import PartitionSpec as P
        return AdamWState(count=P(), m=param_specs,
                          v=jax.tree.map(lambda s: s, param_specs))

    def state_shapes(self, param_shapes, mesh=None):
        """ShapeDtypeStruct state (dry-run: no allocation)."""
        def mom(p):
            sh = getattr(p, "sharding", None)
            if sh is not None:
                return jax.ShapeDtypeStruct(p.shape, self.moment_dtype,
                                            sharding=sh)
            return jax.ShapeDtypeStruct(p.shape, self.moment_dtype)
        zeros = jax.tree.map(mom, param_shapes)
        return AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32),
                          m=zeros, v=jax.tree.map(lambda x: x, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))
