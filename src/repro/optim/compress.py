"""Gradient compression with error feedback.

Distributed-optimization trick for the DP/FSDP gradient reduction at scale:
cast gradients to bf16 before the cross-replica all-reduce (halving the
dominant collective's bytes) while accumulating the quantization error in a
persistent residual that is re-injected next step — the classic
error-feedback construction that keeps convergence unbiased to first order.

Exposed as a pure transform the trainer folds around the optimizer:
    grads_c, new_residual = compress(grads, residual)
Residuals are stored bf16 (the error of an error is noise).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress(grads, residual):
    """Returns (bf16 gradients to feed the optimizer, updated residual)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r.astype(jnp.float32)
        q = corrected.astype(jnp.bfloat16)
        new_r = (corrected - q.astype(jnp.float32)).astype(jnp.bfloat16)
        return q, new_r
    flat = jax.tree.map(one, grads, residual,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    qs = jax.tree.map(lambda t: t[0], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
    rs = jax.tree.map(lambda t: t[1], flat,
                      is_leaf=lambda x: isinstance(x, tuple))
    return qs, rs
