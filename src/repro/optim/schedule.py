"""Learning-rate schedules (warmup + cosine decay)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    # (step + 1): step 0 must already train (a zero first-step lr freezes
    # smoke tests and wastes the first global batch at scale)
    warm = peak_lr * (step + 1) / jnp.maximum(warmup_steps, 1)
    prog = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)
