"""Pallas TPU kernels for the l-NN compute hot spots.

Each kernel ships three artifacts (per the repo contract):
  <name>.py -- pl.pallas_call + BlockSpec VMEM tiling (TPU target,
               validated in interpret mode on CPU);
  ops.py    -- jitted shape-general wrapper with padding + fallback routing;
  ref.py    -- the pure-jnp oracle every kernel must match.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
