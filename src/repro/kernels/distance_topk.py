"""Fused distance + running local top-l — Pallas TPU kernel.

This is the per-machine half of Algorithm 2 (Steps 2 + 8): compute the
distance of every local point to the query batch AND keep only the l
smallest, *without materializing the (B, m) distance matrix in HBM*.  For a
datastore shard of m points the unfused pipeline writes and re-reads
4*B*m bytes; the fused kernel's HBM traffic is just the operands —
arithmetic intensity rises from ~d/3 to ~d, which at d >= 512 moves the op
from memory-bound to MXU-bound on v5e (819 GB/s vs 197 TFLOP/s crossover at
intensity ~240).

Mechanics per (i, j) grid step (j = point-tile index, iterated sequentially
as the minor grid dim — TPU guarantees order, so VMEM scratch carries state
across j):

  1. distance tile (bb, bm) via MXU, identical math to `l2_distance.py`
     (d is consumed whole per tile: d*(bb+bm)*4B of VMEM — the envelope
     check lives in ops.py);
  2. guarded merge: if the tile's minimum beats the running l-th best
     (a scalar compare), run l extraction steps merging the tile into the
     running (bb, l) top buffer; otherwise skip the merge entirely.  On
     random data almost every tile after the first few is skipped, so the
     steady-state cost is the matmul alone — the selection flavor of the
     paper's own "discard most of the data cheaply" insight, applied inside
     the chip's memory hierarchy.

The merge is an l-step vectorized min-extraction (argmin + one-hot mask per
step) — O(l*(l+bm)) VPU ops, negligible against the bb*bm*d MXU MACs for
l << d.  ops.py enforces the specialization envelope (l <= 256) and falls
back to l2_distance + lax.top_k beyond it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_M = 256
MAX_L = 256

_INT_MAX = 2**31 - 1  # python int: jnp constants would be captured as consts


def _merge_tile(vals, ids, top_v, top_i, l: int):
    """Merge a (bb, w) candidate tile into the running (bb, l) top buffer.

    Returns the new (top_v, top_i), ascending by construction.  Pure jnp on
    values held in registers/VMEM; l sequential extraction steps.
    """
    buf_v = jnp.concatenate([top_v, vals], axis=1)          # (bb, l + w)
    buf_i = jnp.concatenate([top_i, ids], axis=1)
    w = buf_v.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, buf_v.shape, 1)

    def step(t, carry):
        bv, bi, ov, oi = carry
        # Lexicographic (value, id) argmin per row, id-stable like lax.top_k.
        mv = jnp.min(bv, axis=1, keepdims=True)
        tie = bv == mv
        mi = jnp.min(jnp.where(tie, bi, _INT_MAX), axis=1, keepdims=True)
        hit = tie & (bi == mi)
        # exactly one hit per row; extract and retire it
        ov = jnp.where(col[:, :ov.shape[1]] == t, mv, ov)
        oi = jnp.where(col[:, :oi.shape[1]] == t, mi, oi)
        bv = jnp.where(hit, jnp.inf, bv)
        bi = jnp.where(hit, _INT_MAX, bi)
        return bv, bi, ov, oi

    init = (buf_v, buf_i,
            jnp.full((buf_v.shape[0], l), jnp.inf, buf_v.dtype),
            jnp.full((buf_v.shape[0], l), _INT_MAX, jnp.int32))
    _, _, out_v, out_i = jax.lax.fori_loop(0, l, step, init)
    del w
    return out_v, out_i


def _kernel(q_ref, p_ref, *refs, nj: int, nk: int, l: int,
            block_m: int, m_real: int, has_valid: bool):
    # Operand order follows in_specs: an optional (1, block_m) validity tile
    # (the mutable store's live-slot mask) rides between the inputs and the
    # outputs when present.
    if has_valid:
        (valid_ref, out_v_ref, out_i_ref, acc_ref, q2_ref, p2_ref,
         top_v_ref, top_i_ref) = refs
    else:
        valid_ref = None
        (out_v_ref, out_i_ref, acc_ref, q2_ref, p2_ref,
         top_v_ref, top_i_ref) = refs
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init_top():
        top_v_ref[...] = jnp.full_like(top_v_ref, jnp.inf)
        top_i_ref[...] = jnp.full_like(top_i_ref, _INT_MAX)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        q2_ref[...] = jnp.zeros_like(q2_ref)
        p2_ref[...] = jnp.zeros_like(p2_ref)

    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    q2_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)
    p2_ref[...] += jnp.sum(p * p, axis=1)[None, :]

    @pl.when(k == nk - 1)
    def _fold():
        dist = jnp.maximum(
            q2_ref[...] - 2.0 * acc_ref[...] + p2_ref[...], 0.0)
        ids = (j * block_m
               + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1))
        # Rows beyond the caller's true point count are layout padding: they
        # must never win a top-l slot (their zero-filled coordinates land at
        # distance ||q||^2, which CAN be competitive).
        keep = ids < m_real
        if valid_ref is not None:
            # Masked-distance path: tombstoned store slots go to +inf (and
            # the sentinel id) *before* the running top-l merge, so a
            # deleted point can neither win a slot nor leak its id through
            # an inf-valued one.
            keep = keep & (valid_ref[...] > 0.0)
        dist = jnp.where(keep, dist, jnp.inf)
        ids = jnp.where(keep, ids, _INT_MAX)

        # Guarded merge: the running l-th best (max of an ascending buffer
        # is its last column) vs the tile's best candidate.
        kth = top_v_ref[:, l - 1]
        tile_min = jnp.min(dist, axis=1)
        worth = jnp.any(tile_min < kth)

        @pl.when(worth)
        def _do_merge():
            nv, ni = _merge_tile(dist, ids, top_v_ref[...], top_i_ref[...], l)
            top_v_ref[...] = nv
            top_i_ref[...] = ni

        @pl.when(j == nj - 1)
        def _write_out():
            out_v_ref[...] = top_v_ref[...]
            out_i_ref[...] = top_i_ref[...]


def distance_topk(
    queries: jax.Array,
    points: jax.Array,
    l: int,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = 512,
    m_real: int | None = None,
    valid: jax.Array | None = None,
    interpret: bool = False,
):
    """(B, d) x (m, d) -> ((B, l) ascending sq-distances, (B, l) point ids).

    Shapes must divide blocks and l <= MAX_L; `ops.distance_topk` is the
    padded general entry point with the oracle fallback.  ``m_real`` marks
    how many leading point rows are genuine (padding rows are excluded from
    the top-l inside the kernel).  ``valid`` (optional, shape (1, m)
    float32, 1.0 = live) is the mutable store's slot mask: zero entries are
    forced to +inf / sentinel id before the running top-l merge.
    """
    B, d = queries.shape
    m, d2 = points.shape
    assert d == d2
    assert l <= MAX_L, l
    assert B % block_b == 0 and m % block_m == 0 and d % block_k == 0
    nb, nj, nk = B // block_b, m // block_m, d // block_k
    if m_real is None:
        m_real = m
    has_valid = valid is not None
    if has_valid:
        assert valid.shape == (1, m), valid.shape

    kern = functools.partial(_kernel, nj=nj, nk=nk, l=l, block_m=block_m,
                             m_real=m_real, has_valid=has_valid)
    in_specs = [
        pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (j, k)),
    ]
    operands = [queries, points]
    if has_valid:
        in_specs.append(pl.BlockSpec((1, block_m), lambda i, j, k: (0, j)))
        operands.append(valid)
    return pl.pallas_call(
        kern,
        grid=(nb, nj, nk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_b, l), lambda i, j, k: (i, 0)),
            pl.BlockSpec((block_b, l), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, l), jnp.float32),
            jax.ShapeDtypeStruct((B, l), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, block_m), jnp.float32),
            pltpu.VMEM((block_b, 1), jnp.float32),
            pltpu.VMEM((1, block_m), jnp.float32),
            pltpu.VMEM((block_b, l), jnp.float32),
            pltpu.VMEM((block_b, l), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
