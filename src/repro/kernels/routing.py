"""Device-side shard routing — the ``route_shards`` decision as a Pallas
kernel riding the service launch.

Host routing (repro.store.summaries.route_shards) costs an
O(B·k·(m·dim + r)) numpy pass per dispatch *before* the persistent
executable can launch — a serial host bottleneck the paper's O(log K)
serving bound never charged for.  This module folds the identical
decision into the executable's prologue: the per-shard summary operands
(centroid/radius ball, pivot-ball union, projection sketch, live counts)
ship as small replicated arrays, and the kernel emits the (B, k) active
mask that gates the fused distance+top-l collective — the touched-shard
set returns *with* the batch instead of being computed on host ahead of
it.

**Parity contract** (tests/test_routing.py): the kernel's mask is
bit-identical to the host numpy ``route_shards`` on every tested
instance.  The host computes bounds in f64; the kernel computes the same
*structure* in f32 — same direct-difference distances, same
max-of-lower-bounds / min-of-upper-bounds, same slack-and-error-margin
keep rule — so the two can only disagree when a bound lands within f32
rounding (~1e-7 relative) of the decision boundary, while the margin
itself is ``T·slack + err`` with slack 1e-4 and a magnitude-absolute err
term.  Two structural rules keep that argument honest:

* distances are accumulated coordinate-by-coordinate as ``Σ (q_d−c_d)²``
  — NOT the ``|q|² − 2q·c + |c|²`` expansion, whose catastrophic
  cancellation at q ≈ c carries absolute error ~sqrt(eps)·|q| and would
  break parity for clusters far from the origin;
* the cumulative-live threshold is computed sort-free:
  ``T = min{ ub_s : Σ_j live_j · [ub_j <= ub_s] >= l }`` over the k
  candidate uppers, which equals the host's stable-argsort prefix
  formulation *including ties* (every shard with ub <= ub_s is counted
  regardless of tie order, so the count at each candidate threshold is
  order-independent).  O(k²) vectorized compares — no sorting network in
  the kernel.

The math core (:func:`_route_rows`) is plain traced jnp shared verbatim
by the Pallas kernel body and the jnp oracle (:func:`route_mask_ref`),
so interpret mode, compiled mode, and the oracle fallback execute the
same float ops in the same order.  Shape alignment (block padding,
lane-dim padding for the Mosaic path) lives in ops.py like the other
kernels'.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8

_F32_EPS = float(np.finfo(np.float32).eps)       # 2^-23


def pack_summaries(s) -> tuple[np.ndarray, ...]:
    """Flatten a :class:`~repro.store.ShardSummaries` into the kernel's
    f32 operand tuple (host numpy; upload/caching is the caller's —
    the server re-packs once per generation, not per dispatch).

    Layouts put k on the lane (last) dim throughout so every per-shard
    op in the kernel is a clean 2D broadcast: ``centsT`` (dim, k),
    ``radii``/``live`` (1, k), ``loT``/``hiT`` (r, k), ``pivT``
    (m·dim, k) slot-major (slot p owns rows [p·dim, (p+1)·dim)),
    ``pivrT``/``occT``/``pliveT`` (m, k), ``rmax`` (1, 1), ``dirsT``
    (dim, r).  Single-pivot summaries (``pivots is None``) pack one
    all-unoccupied dummy slot — the occupancy mask zeroes its
    contribution exactly the way the host skips the pivot pass, and the
    operand signature stays fixed across generations.  ``pliveT`` holds
    the per-ball live credits (zeros when the summaries carry none),
    feeding the ball-granular threshold stage.  ``rmax`` is the
    generation's ``max live (|centroid| + radius)`` feeding the pipeline
    error bound.
    """
    k, dim = s.centroids.shape
    centsT = np.ascontiguousarray(s.centroids.T, np.float32)
    radii = s.radii[None].astype(np.float32)
    live = s.live[None].astype(np.float32)
    if s.directions.shape[0]:
        loT = np.ascontiguousarray(s.proj_lo.T, np.float32)
        hiT = np.ascontiguousarray(s.proj_hi.T, np.float32)
        dirsT = np.ascontiguousarray(s.directions.T, np.float32)
    else:
        # no sketch: one neutral interval (gap identically 0)
        loT = np.full((1, k), -np.inf, np.float32)
        hiT = np.full((1, k), np.inf, np.float32)
        dirsT = np.zeros((dim, 1), np.float32)
    if s.pivots is None:
        pivT = np.zeros((dim, k), np.float32)
        pivrT = np.zeros((1, k), np.float32)
        occT = np.zeros((1, k), np.float32)
        pliveT = np.zeros((1, k), np.float32)
    else:
        m = s.pivots.shape[1]
        pivT = np.ascontiguousarray(
            np.transpose(s.pivots, (1, 2, 0)).reshape(m * dim, k),
            np.float32)
        pivrT = np.ascontiguousarray(s.pivot_radii.T, np.float32)
        occT = (np.arange(m)[:, None]
                < s.pivot_count[None, :]).astype(np.float32)
        pliveT = (np.ascontiguousarray(s.pivot_live.T, np.float32)
                  if s.pivot_live is not None
                  else np.zeros((m, k), np.float32))
    alive = s.live > 0
    R = (float((np.linalg.norm(s.centroids[alive], axis=1)
                + s.radii[alive]).max()) if alive.any() else 0.0)
    rmax = np.full((1, 1), R, np.float32)
    return (centsT, radii, live, loT, hiT, pivT, pivrT, occT, pliveT,
            rmax, dirsT)


def _sq_dists(q, matT, dim: int, row0: int):
    """(bb, k) f32 squared direct-difference distances from each query
    row to the k columns of ``matT`` rows [row0, row0+dim) — accumulated
    coordinate-by-coordinate (see module docstring on cancellation)."""
    acc = jnp.zeros((q.shape[0], matT.shape[1]), jnp.float32)
    for d in range(dim):
        diff = q[:, d:d + 1] - matT[row0 + d:row0 + d + 1, :]
        acc = acc + diff * diff
    return acc


def _route_rows(q, l_arr, centsT, radii, live, loT, hiT, pivT, pivrT,
                occT, pliveT, rmax, dirsT, *, dim_real: int, slack: float):
    """The routing decision on one query block — f32 mirror of the host
    route_shards, op for op.  ``q`` (bb, dim), ``l_arr`` (bb, 1) int32;
    returns (bb, k) int32 (1 = shard active).  ``dim_real`` is the
    caller's true dim (the error-bound constant — zero-padded trailing
    coordinates cancel in every distance but must not inflate it)."""
    bb, dim = q.shape
    k = centsT.shape[1]
    m = occT.shape[0]
    r = loT.shape[0]
    inf = jnp.float32(jnp.inf)

    # aggregate-ball bracket (distance units)
    dc = jnp.sqrt(_sq_dists(q, centsT, dim, 0))
    lbd = jnp.maximum(dc - radii, 0.0)
    ubd = dc + radii

    # pivot-ball union bracket; unoccupied slots are neutral.  Per-slot
    # distances are kept for the ball-granular threshold stage below.
    plb = jnp.full((bb, k), inf, jnp.float32)
    pub = jnp.full((bb, k), -inf, jnp.float32)
    dp_slots = []
    for p in range(m):
        dp = jnp.sqrt(_sq_dists(q, pivT, dim, p * dim))
        dp_slots.append(dp)
        occ = occT[p:p + 1, :] > 0.0
        plb = jnp.minimum(plb, jnp.where(
            occ, jnp.maximum(dp - pivrT[p:p + 1, :], 0.0), inf))
        pub = jnp.maximum(pub, jnp.where(
            occ, dp + pivrT[p:p + 1, :], -inf))
    has = jnp.max(occT, axis=0, keepdims=True) > 0.0
    lbd = jnp.maximum(lbd, jnp.where(has, plb, 0.0))
    ubd = jnp.minimum(ubd, jnp.where(has, pub, inf))

    # projection-sketch lower bound (1-Lipschitz interval gaps)
    qp = jax.lax.dot_general(q, dirsT, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    for rr in range(r):
        gap = jnp.maximum(jnp.maximum(
            loT[rr:rr + 1, :] - qp[:, rr:rr + 1],
            qp[:, rr:rr + 1] - hiT[rr:rr + 1, :]), 0.0)
        lbd = jnp.maximum(lbd, gap)

    alive = live > 0.0                                   # (1, k)
    lb = jnp.where(alive, lbd * lbd, inf)
    ub = jnp.where(alive, ubd * ubd, inf)

    # sort-free cumulative-live threshold (module docstring)
    lf = l_arr.astype(jnp.float32)                       # (bb, 1)
    T = jnp.full((bb, 1), inf, jnp.float32)
    for s_ in range(k):
        ub_s = ub[:, s_:s_ + 1]
        cnt = jnp.sum(jnp.where(ub <= ub_s, live, 0.0), axis=1,
                      keepdims=True)
        T = jnp.minimum(T, jnp.where(cnt >= lf, ub_s, inf))

    # ball-granular threshold from per-pivot live credits — the host
    # _pivot_threshold mirrored: candidates are (slot, shard) ball upper
    # bounds, counted against the credits of every ball at or below
    # them; min() with the shard-level T can only tighten (credits are
    # safe undercounts).  Slots with zero credit are non-candidates
    # (tub = inf), exactly like the host's occ & live > 0 gate.
    tubs = []
    for p in range(m):
        credit = (occT[p:p + 1, :] > 0.0) & (pliveT[p:p + 1, :] > 0.0)
        bub = dp_slots[p] + pivrT[p:p + 1, :]
        tubs.append(jnp.where(credit, bub * bub, inf))
    for p_c in range(m):
        for s_ in range(k):
            ub_c = tubs[p_c][:, s_:s_ + 1]
            cnt = jnp.zeros((bb, 1), jnp.float32)
            for p in range(m):
                cnt = cnt + jnp.sum(
                    jnp.where(tubs[p] <= ub_c, pliveT[p:p + 1, :], 0.0),
                    axis=1, keepdims=True)
            T = jnp.minimum(T, jnp.where(cnt >= lf, ub_c, inf))

    # f32-pipeline error margin: 16·(dim+1)·eps·(|q| + R)^2
    q2 = jnp.zeros((bb, 1), jnp.float32)
    for d in range(dim_real):
        q2 = q2 + q[:, d:d + 1] * q[:, d:d + 1]
    err = (jnp.float32(16.0 * (dim_real + 1) * _F32_EPS)
           * (jnp.sqrt(q2) + rmax) ** 2)
    t_eff = T * jnp.float32(1.0 + slack) + err           # (bb, 1)

    keep = alive & (lb <= t_eff) & (l_arr > 0)
    return keep.astype(jnp.int32)


def _kernel(q_ref, l_ref, cents_ref, rad_ref, live_ref, lo_ref, hi_ref,
            piv_ref, pivr_ref, occ_ref, plive_ref, rmax_ref, dirs_ref,
            out_ref, *, dim_real: int, slack: float):
    out_ref[...] = _route_rows(
        q_ref[...], l_ref[...], cents_ref[...], rad_ref[...],
        live_ref[...], lo_ref[...], hi_ref[...], piv_ref[...],
        pivr_ref[...], occ_ref[...], plive_ref[...], rmax_ref[...],
        dirs_ref[...], dim_real=dim_real, slack=slack)


def route_mask(queries, ls, centsT, radii, live, loT, hiT, pivT, pivrT,
               occT, pliveT, rmax, dirsT, *, dim_real: int,
               slack: float = 1e-4,
               block_b: int = DEFAULT_BLOCK_B, interpret: bool = False):
    """(B, dim) queries + per-row ls (B, 1) int32 -> (B, k) int32 active
    mask, as a Pallas call gridded over B blocks (summary operands are
    whole-array blocks replicated to every grid step — they are O(k·dim)
    small).  B must divide ``block_b``; ops.route_mask is the padded
    general entry point with the oracle fallback.
    """
    B, dim = queries.shape
    k = centsT.shape[1]
    assert B % block_b == 0, (B, block_b)
    assert ls.shape == (B, 1), ls.shape
    summary_ops = (centsT, radii, live, loT, hiT, pivT, pivrT, occT,
                   pliveT, rmax, dirsT)
    kern = functools.partial(_kernel, dim_real=dim_real, slack=slack)
    return pl.pallas_call(
        kern,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, dim), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ] + [pl.BlockSpec(op.shape, lambda i: (0, 0))
             for op in summary_ops],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.int32),
        interpret=interpret,
    )(queries, ls, *summary_ops)


def route_mask_ref(queries, ls, centsT, radii, live, loT, hiT, pivT,
                   pivrT, occT, pliveT, rmax, dirsT, *, dim_real: int,
                   slack: float = 1e-4):
    """Pure-jnp oracle — literally the kernel's shared math core on the
    whole batch at once (same ops, same order: bit-identical to the
    interpret-mode kernel, and still a single fused device computation
    when traced into the service executable)."""
    return _route_rows(queries, ls, centsT, radii, live, loT, hiT, pivT,
                       pivrT, occT, pliveT, rmax, dirsT,
                       dim_real=dim_real, slack=slack)


# ---- in-shard bucket index mask (the store/index.py tier, device-side) ---


def pack_index(index) -> tuple[np.ndarray, ...]:
    """Flatten a :class:`~repro.store.index.ShardIndex` into the index
    kernel's f32 operand tuple: ``bcentsT`` (dim, k·b) with flat column
    ``j·b + t`` for shard j bucket t, ``bradii``/``blive`` (1, k·b).
    Unoccupied or emptied buckets carry live 0, which is the kernel's
    occupancy gate (their lb/ub are forced to inf).  Cached by the
    server per frozen index, like pack_summaries."""
    k, b, dim = index.centers.shape
    occ = ((np.arange(b)[None, :] < index.count[:, None])
           & (index.live > 0))
    bcentsT = np.ascontiguousarray(
        index.centers.reshape(k * b, dim).T, np.float32)
    bradii = np.where(occ, index.radii, 0.0).reshape(1, -1).astype(
        np.float32)
    blive = np.where(occ, index.live, 0).reshape(1, -1).astype(np.float32)
    return bcentsT, bradii, blive


def _index_rows(q, l_arr, rows, bcentsT, bradii, blive, *,
                oversample: float):
    """The bucket keep decision on one query block — the f32 mirror of
    the host ``store.index.bucket_keep`` *structure* (keep rule, gating,
    sort-free threshold).  NOT a bit-parity contract: the tier is
    approximate on either path, so each path's recall is measured
    against its own exact replay rather than against the other path.
    ``rows`` (bb, k) int32 is the routing keep mask (buckets in pruned
    shards are non-candidates); returns (bb, k·b) int32."""
    bb, dim = q.shape
    kb = bcentsT.shape[1]
    k = rows.shape[1]
    b = kb // k
    inf = jnp.float32(jnp.inf)
    d = jnp.sqrt(_sq_dists(q, bcentsT, dim, 0))          # (bb, kb)
    # shard gate expanded to bucket columns via a 0/1 matmul (no lane-dim
    # reshape/repeat — Mosaic-clean, and a single fused dot elsewhere)
    col_shard = jax.lax.broadcasted_iota(jnp.int32, (1, kb), 1) // b
    row_shard = jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0)
    expand = (col_shard == row_shard).astype(jnp.float32)      # (k, kb)
    gate = jax.lax.dot_general(
        (rows > 0).astype(jnp.float32), expand,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) > 0.0              # (bb, kb)
    g = gate & (blive > 0.0)
    lbd = jnp.maximum(d - bradii, 0.0)
    lb = jnp.where(g, lbd * lbd, inf)
    ubd = d + bradii
    ub = jnp.where(g, ubd * ubd, inf)
    # sort-free cumulative-live threshold at the oversampled target
    lf = l_arr.astype(jnp.float32)                       # (bb, 1)
    target = jnp.maximum(lf, jnp.ceil(jnp.float32(oversample) * lf))
    T = jnp.full((bb, 1), inf, jnp.float32)
    for c in range(kb):
        ub_c = ub[:, c:c + 1]
        cnt = jnp.sum(jnp.where(ub <= ub_c, blive, 0.0), axis=1,
                      keepdims=True)
        T = jnp.minimum(T, jnp.where(cnt >= target, ub_c, inf))
    keep = g & (lb <= T) & (l_arr > 0)
    return keep.astype(jnp.int32)


def _index_kernel(q_ref, l_ref, rows_ref, cents_ref, rad_ref, live_ref,
                  out_ref, *, oversample: float):
    out_ref[...] = _index_rows(
        q_ref[...], l_ref[...], rows_ref[...], cents_ref[...],
        rad_ref[...], live_ref[...], oversample=oversample)


def index_mask(queries, ls, rows, bcentsT, bradii, blive, *,
               oversample: float = 2.0, block_b: int = DEFAULT_BLOCK_B,
               interpret: bool = False):
    """(B, dim) queries + (B, 1) ls + (B, k) routing keep -> (B, k·b)
    int32 bucket keep, as a Pallas call gridded over B blocks (index
    operands replicate to every grid step — O(k·b·dim) small).
    ops.index_mask is the padded general entry point with the oracle
    fallback."""
    B, dim = queries.shape
    kb = bcentsT.shape[1]
    k = rows.shape[1]
    assert B % block_b == 0, (B, block_b)
    assert ls.shape == (B, 1), ls.shape
    kern = functools.partial(_index_kernel, oversample=oversample)
    index_ops = (bcentsT, bradii, blive)
    return pl.pallas_call(
        kern,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, dim), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ] + [pl.BlockSpec(op.shape, lambda i: (0, 0))
             for op in index_ops],
        out_specs=pl.BlockSpec((block_b, kb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, kb), jnp.int32),
        interpret=interpret,
    )(queries, ls, rows, *index_ops)


def index_mask_ref(queries, ls, rows, bcentsT, bradii, blive, *,
                   oversample: float = 2.0):
    """Pure-jnp oracle — the kernel's shared math core on the whole
    batch (same ops, same order; fuses into the service executable when
    traced)."""
    return _index_rows(queries, ls, rows, bcentsT, bradii, blive,
                       oversample=oversample)
