"""Blocked squared-L2 distance matrix — Pallas TPU kernel.

The compute hot-spot of the paper's l-NN pipeline (Algorithm 2, Step 8:
``d_ij = dis(p_ij, q)`` for every local point) is a matmul in disguise:

    ||q - p||^2 = ||q||^2 - 2 q.p + ||p||^2

so the kernel is a (B, d) x (d, m) MXU contraction with a rank-1 epilogue.
Tiling (DESIGN.md hardware-adaptation): the grid is (B/bb, m/bm, d/bk); the
f32 accumulator tile (bb, bm) lives in VMEM scratch across the k-steps, and
the squared-norm partial sums ride along in two skinny scratch columns —
norms are accumulated *inside* the same k-loop so HBM sees each operand
exactly once (arithmetic intensity = the matmul's, the epilogue is free).

Block shapes default to MXU-aligned (128 multiples); `ops.py` pads inputs to
alignment and slices the result (padding points produce garbage distances in
padded columns which the caller slices away; padded d-lanes are zero-filled
and contribute nothing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 256


def _kernel(q_ref, p_ref, out_ref, acc_ref, q2_ref, p2_ref, *, nk: int):
    """One (i, j, k) grid step.

    q_ref:  (bb, bk) query tile        p_ref: (bm, bk) point tile
    out_ref:(bb, bm) output tile       acc_ref: f32 VMEM accumulator
    q2_ref: (bb, 1) running ||q||^2    p2_ref: (1, bm) running ||p||^2
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        q2_ref[...] = jnp.zeros_like(q2_ref)
        p2_ref[...] = jnp.zeros_like(p2_ref)

    q = q_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)

    # MXU contraction: (bb, bk) x (bk, bm).
    acc_ref[...] += jax.lax.dot_general(
        q, p, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    # Norm partials on the VPU, same operands, no extra HBM traffic.
    q2_ref[...] += jnp.sum(q * q, axis=1, keepdims=True)
    p2_ref[...] += jnp.sum(p * p, axis=1)[None, :]

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = q2_ref[...] - 2.0 * acc_ref[...] + p2_ref[...]
        out_ref[...] = jnp.maximum(dist, 0.0).astype(out_ref.dtype)


def l2_distance(
    queries: jax.Array,
    points: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """(B, d) x (m, d) -> (B, m) squared distances.  Dims must divide blocks
    (use `ops.l2_distance` for the padded general-shape entry point)."""
    B, d = queries.shape
    m, d2 = points.shape
    assert d == d2, (d, d2)
    assert B % block_b == 0 and m % block_m == 0 and d % block_k == 0, (
        "unpadded shapes must divide block sizes; call ops.l2_distance")
    nb, nm, nk = B // block_b, m // block_m, d // block_k

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(nb, nm, nk),
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, m), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b, block_m), jnp.float32),
            pltpu.VMEM((block_b, 1), jnp.float32),
            pltpu.VMEM((1, block_m), jnp.float32),
        ],
        interpret=interpret,
    )(queries, points)
