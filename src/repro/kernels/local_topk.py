"""Standalone local top-l (smallest) — Pallas TPU kernel.

The per-machine reduction of Algorithm 2, Step 2, for callers that already
hold a distance/score matrix in HBM: (B, m) -> l smallest per row with
indices.  Grid is (B/bb, m/bm) with the point axis iterated sequentially;
the running (bb, l) top buffer lives in VMEM scratch and uses the same
guarded l-step extraction merge as `distance_topk.py` (see there for the
cost model — here the merge IS the kernel, so this pays off vs lax.top_k
only through the guarded skip and the single HBM read).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.distance_topk import MAX_L, _INT_MAX, _merge_tile

DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_M = 512


def _kernel(x_ref, out_v_ref, out_i_ref, top_v_ref, top_i_ref, *,
            nj: int, l: int, block_m: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        top_v_ref[...] = jnp.full_like(top_v_ref, jnp.inf)
        top_i_ref[...] = jnp.full_like(top_i_ref, _INT_MAX)

    x = x_ref[...].astype(jnp.float32)
    ids = j * block_m + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    kth = top_v_ref[:, l - 1]
    worth = jnp.any(jnp.min(x, axis=1) < kth)

    @pl.when(worth)
    def _do_merge():
        nv, ni = _merge_tile(x, ids, top_v_ref[...], top_i_ref[...], l)
        top_v_ref[...] = nv
        top_i_ref[...] = ni

    @pl.when(j == nj - 1)
    def _write():
        out_v_ref[...] = top_v_ref[...]
        out_i_ref[...] = top_i_ref[...]


def local_topk(
    values: jax.Array,
    l: int,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = False,
):
    """(B, m) -> ((B, l) ascending values, (B, l) indices), l smallest."""
    B, m = values.shape
    assert l <= MAX_L, l
    assert B % block_b == 0 and m % block_m == 0
    nb, nj = B // block_b, m // block_m

    kern = functools.partial(_kernel, nj=nj, l=l, block_m=block_m)
    return pl.pallas_call(
        kern,
        grid=(nb, nj),
        in_specs=[pl.BlockSpec((block_b, block_m), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_b, l), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, l), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, l), jnp.float32),
            jax.ShapeDtypeStruct((B, l), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, l), jnp.float32),
            pltpu.VMEM((block_b, l), jnp.int32),
        ],
        interpret=interpret,
    )(values)
