"""Jitted, shape-general entry points for the Pallas kernels.

Responsibilities:
  * pad arbitrary shapes up to block multiples (+inf-padding points so padded
    rows never win a top-l slot), slice results back;
  * route to the jnp oracle when a shape is outside a kernel's
    specialization envelope (l > MAX_L, VMEM budget exceeded) or when the
    backend has no Mosaic support (this CPU container -> interpret mode for
    tests, oracle for performance paths);
  * expose one flag (`REPRO_KERNEL_MODE`) so the whole framework can be
    flipped between kernel / oracle / interpret for A-B testing.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import l2_distance as _l2
from repro.kernels import distance_topk as _dtk
from repro.kernels import local_topk as _ltk
from repro.kernels import routing as _routing
from repro.obs import metrics as _obs_metrics

# kernel  : pl.pallas_call compiled for the backend (TPU target)
# interpret: kernel body executed in Python (CPU-correctness mode)
# oracle  : pure-jnp reference (fast on CPU, also the fallback)
_MODE = os.environ.get("REPRO_KERNEL_MODE", "auto")

# v5e VMEM is ~128 MiB/core but Mosaic's practical per-kernel budget is far
# smaller; stay well under 16 MiB of live scratch + operands.
_VMEM_BUDGET = 12 * 2**20


def _mode() -> str:
    if _MODE != "auto":
        return _MODE
    return "kernel" if jax.default_backend() == "tpu" else "oracle"


def _pad_to(x, mult, axis, value):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "block_k",
                                              "interpret"))
def _l2_padded(q, p, block_b, block_m, block_k, interpret):
    B, m = q.shape[0], p.shape[0]
    qp = _pad_to(_pad_to(q, block_b, 0, 0.0), block_k, 1, 0.0)
    pp = _pad_to(_pad_to(p, block_m, 0, 0.0), block_k, 1, 0.0)
    out = _l2.l2_distance(qp, pp, block_b=block_b, block_m=block_m,
                          block_k=block_k, interpret=interpret)
    return out[:B, :m]


def l2_distance(queries, points, *, valid=None, block_b=None, block_m=None,
                block_k=None):
    """General-shape squared-L2 distance matrix (see kernels/l2_distance.py).

    ``valid`` (optional (m,) bool — the mutable store's live-slot mask)
    forces masked columns to +inf.  The unfused kernel computes the full
    matrix and masks after (the top-l reduction happens at the caller); the
    fused :func:`distance_topk` masks *inside* its running merge.
    """
    mode = _mode()
    if mode == "oracle":
        _count_fallback("l2_distance", "mode_oracle")
        if valid is not None:
            return ref.masked_l2_distance_ref(queries, points, valid)
        return ref.l2_distance_ref(queries, points)
    bb = block_b or _l2.DEFAULT_BLOCK_B
    bm = block_m or _l2.DEFAULT_BLOCK_M
    bk = block_k or _l2.DEFAULT_BLOCK_K
    out = _l2_padded(queries, points, bb, bm, bk, mode == "interpret")
    if valid is not None:
        out = jnp.where(valid[None, :].astype(jnp.bool_), out, jnp.inf)
    return out


@functools.partial(jax.jit,
                   static_argnames=("l", "block_b", "block_m", "block_k",
                                    "interpret"))
def _dtk_padded(q, p, l, block_b, block_m, block_k, interpret):
    B, m = q.shape[0], p.shape[0]
    qp = _pad_to(_pad_to(q, block_b, 0, 0.0), block_k, 1, 0.0)
    # Padded point rows are zero-filled; the kernel itself excludes ids >= m
    # from the top-l (a zero row's distance ||q||^2 can be competitive, so
    # post-hoc masking would lose genuine winners).
    pp = _pad_to(_pad_to(p, block_m, 0, 0.0), block_k, 1, 0.0)
    v, i = _dtk.distance_topk(qp, pp, l, block_b=block_b, block_m=block_m,
                              block_k=block_k, m_real=m, interpret=interpret)
    i = jnp.where(jnp.isfinite(v), i, 2**31 - 1)
    return v[:B], i[:B]


@functools.partial(jax.jit,
                   static_argnames=("l", "block_b", "block_m", "block_k",
                                    "interpret"))
def _dtk_padded_masked(q, p, valid, l, block_b, block_m, block_k, interpret):
    B, m = q.shape[0], p.shape[0]
    qp = _pad_to(_pad_to(q, block_b, 0, 0.0), block_k, 1, 0.0)
    pp = _pad_to(_pad_to(p, block_m, 0, 0.0), block_k, 1, 0.0)
    # Layout-padding slots are masked the same way tombstones are (0.0).
    vp = _pad_to(valid.astype(jnp.float32)[None, :], block_m, 1, 0.0)
    v, i = _dtk.distance_topk(qp, pp, l, block_b=block_b, block_m=block_m,
                              block_k=block_k, m_real=m, valid=vp,
                              interpret=interpret)
    i = jnp.where(jnp.isfinite(v), i, 2**31 - 1)
    return v[:B], i[:B]


def _count_fallback(entry: str, kind: str) -> None:
    """Tally one dispatcher fallback in the process-wide metrics registry
    (src/repro/obs/metrics.py) so silent oracle/jnp reroutes surface in
    ``KnnServer.obs_snapshot()`` and the bench JSONs instead of only in a
    returned string nobody reads.  Dispatcher bodies run at trace time,
    so jitted callers tally once per compiled specialization — the count
    answers "did this deployment ever fall back, and why", not "how many
    launches"."""
    reg = _obs_metrics.default_registry()
    reg.counter(f"kernel.fallback.{entry}").inc()
    reg.counter(f"kernel.fallback.{entry}.{kind}").inc()


def _reason_kind(reason: str) -> str:
    """Stable metric-suffix classification of a _fused_gate reason."""
    if reason.startswith("l="):
        return "max_l"
    if reason.startswith("vmem"):
        return "vmem"
    return "dim"


def _fused_gate(l, dim, bb, bm, bk):
    """The distance_topk routing gate: (vmem estimate, fallback reason).

    Single source of truth shared by the dispatcher below and
    :func:`service_envelope`, so the pre-flight report cannot drift from
    the actual routing.
    """
    vmem = 4 * (bb * bk + bm * bk + bb * bm + 2 * bb * l) + 8 * bm
    if l > _dtk.MAX_L:
        return vmem, f"l={l} > MAX_L={_dtk.MAX_L}"
    if vmem > _VMEM_BUDGET:
        return vmem, f"vmem {vmem} > budget {_VMEM_BUDGET}"
    if dim < 1:
        return vmem, "dim < 1"
    return vmem, None


def distance_topk(queries, points, l, *, valid=None, block_b=None,
                  block_m=None, block_k=None):
    """General-shape fused distance+top-l (see kernels/distance_topk.py).

    ``valid`` (optional (m,) bool) excludes masked point rows from the
    top-l — inside the kernel's running merge on the fused path, via the
    masked oracle on fallbacks.  On the masked path, +inf slots always
    report the INT32_MAX sentinel id (tombstoned ids never surface).
    """
    mode = _mode()
    bb = block_b or _dtk.DEFAULT_BLOCK_B
    bm = block_m or _dtk.DEFAULT_BLOCK_M
    bk = block_k or 512
    d = queries.shape[-1]
    _, reason = _fused_gate(l, d, bb, bm, bk)
    if mode == "oracle" or reason is not None:
        _count_fallback("distance_topk",
                        "mode_oracle" if reason is None
                        else _reason_kind(reason))
        if valid is not None:
            return ref.masked_distance_topk_ref(queries, points, valid, l)
        return ref.distance_topk_ref(queries, points, l)
    bk = min(bk, _ceil_mult(d, 128))
    if valid is not None:
        return _dtk_padded_masked(queries, points, valid, l, bb, bm, bk,
                                  mode == "interpret")
    return _dtk_padded(queries, points, l, bb, bm, bk, mode == "interpret")


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def service_envelope(bucket_b: int, m_local: int, dim: int, l: int) -> dict:
    """Pre-flight dispatch check for one service bucket shape — no compile.

    The micro-batched kNN service (runtime/knn_server.py) compiles one
    executable per bucket (B, l_max) shape; this reports, per bucket and
    *before* paying a compile, which path each kernel entry point routes
    to for that shape:

    * ``l2_path`` — :func:`l2_distance`, the distance step the service's
      executables actually run today (mode flag only);
    * ``path`` — :func:`distance_topk`, the fused distance+top-l hot
      path, evaluated through the same ``_fused_gate`` the dispatcher
      uses (default blocks, ``bk=512`` pre-clamp) so capacity planning
      for a fused service deployment reads true.

    ``fallback_reason`` explains a fused-path oracle fallback (if any).
    """
    mode = _mode()
    bb = _dtk.DEFAULT_BLOCK_B
    bm = _dtk.DEFAULT_BLOCK_M
    bk = 512                       # distance_topk gates on the pre-clamp bk
    vmem, reason = _fused_gate(l, dim, bb, bm, bk)
    path = mode if reason is None else "oracle"
    _obs_metrics.default_registry().counter("kernel.envelopes").inc()
    if reason is not None:
        _count_fallback("envelope", _reason_kind(reason))
    return {
        "bucket_b": bucket_b, "m_local": m_local, "dim": dim, "l": l,
        "path": path, "l2_path": mode, "vmem_bytes": vmem,
        "fallback_reason": reason,
        # padded shape the fused kernel would actually run (grid-aligned)
        "padded_b": _ceil_mult(max(bucket_b, 1), bb),
        "padded_m": _ceil_mult(max(m_local, 1), bm),
    }


@functools.partial(jax.jit,
                   static_argnames=("l", "block_b", "block_m", "interpret"))
def _ltk_padded(x, l, block_b, block_m, interpret):
    B, m = x.shape
    xp = _pad_to(_pad_to(x, block_b, 0, jnp.inf), block_m, 1, jnp.inf)
    v, i = _ltk.local_topk(xp, l, block_b=block_b, block_m=block_m,
                           interpret=interpret)
    i = jnp.where(i < m, i, 2**31 - 1)
    return v[:B], i[:B]


def local_topk(values, l, *, block_b=None, block_m=None):
    """General-shape l-smallest per row (see kernels/local_topk.py)."""
    mode = _mode()
    if mode == "oracle" or l > _dtk.MAX_L:
        _count_fallback("local_topk",
                        "mode_oracle" if l <= _dtk.MAX_L else "max_l")
        return ref.local_topk_ref(values, l)
    bb = block_b or _ltk.DEFAULT_BLOCK_B
    bm = block_m or _ltk.DEFAULT_BLOCK_M
    return _ltk_padded(values, l, bb, bm, mode == "interpret")


@functools.partial(jax.jit, static_argnames=("dim_real", "slack"))
def _route_ref_jit(q, ls2, *packed, dim_real, slack):
    return _routing.route_mask_ref(q, ls2, *packed, dim_real=dim_real,
                                   slack=slack)


@functools.partial(jax.jit, static_argnames=("dim_real", "slack",
                                             "block_b", "interpret"))
def _route_padded(q, ls2, *packed, dim_real, slack, block_b, interpret):
    B = q.shape[0]
    # padding rows carry l=0 and route nowhere, exactly like the
    # micro-batcher's own bucket padding
    qp = _pad_to(q, block_b, 0, 0.0)
    lp = _pad_to(ls2, block_b, 0, 0)
    out = _routing.route_mask(qp, lp, *packed, dim_real=dim_real,
                              slack=slack, block_b=block_b,
                              interpret=interpret)
    return out[:B]


def route_mask(queries, ls, packed, *, slack=1e-4):
    """(B, k) bool active mask — the route_shards decision on device
    (see kernels/routing.py).

    ``packed`` is the operand tuple from ``routing.pack_summaries`` (one
    pack per store generation; the server caches it).  Traceable: the
    service executable calls this in its prologue so routing rides the
    batch's own launch.  Mode routing mirrors the other entry points —
    oracle runs the shared jnp math core directly; a Mosaic-hostile
    shape (lane dims not 128-aligned — always true at the repo's k=8)
    ALSO takes the jnp core, which still fuses into the same XLA program
    and stays device-side; only the aligned case pays a pallas_call.
    """
    mode = _mode()
    q = jnp.asarray(queries, jnp.float32)
    ls2 = jnp.asarray(ls, jnp.int32).reshape(-1, 1)
    dim_real = q.shape[1]
    k = packed[1].shape[1]
    if mode != "interpret" and (mode == "oracle"
                                or dim_real % 128 or k % 128):
        _count_fallback("route_mask",
                        "mode_oracle" if mode == "oracle" else "unaligned")
        out = _route_ref_jit(q, ls2, *packed, dim_real=dim_real,
                             slack=slack)
    else:
        out = _route_padded(q, ls2, *packed, dim_real=dim_real,
                            slack=slack, block_b=_routing.DEFAULT_BLOCK_B,
                            interpret=mode == "interpret")
    return out != 0


@functools.partial(jax.jit, static_argnames=("oversample",))
def _index_ref_jit(q, ls2, rows, *packed, oversample):
    return _routing.index_mask_ref(q, ls2, rows, *packed,
                                   oversample=oversample)


@functools.partial(jax.jit, static_argnames=("oversample", "block_b",
                                             "interpret"))
def _index_padded(q, ls2, rows, *packed, oversample, block_b, interpret):
    B = q.shape[0]
    qp = _pad_to(q, block_b, 0, 0.0)
    lp = _pad_to(ls2, block_b, 0, 0)      # padding rows keep no bucket
    rp = _pad_to(rows, block_b, 0, 0)
    out = _routing.index_mask(qp, lp, rp, *packed, oversample=oversample,
                              block_b=block_b, interpret=interpret)
    return out[:B]


def index_mask(queries, ls, rows, packed, *, oversample=2.0):
    """(B, k·b) bool bucket-keep mask — the search="approx" in-shard
    candidate decision on device (see kernels/routing.py and
    store/index.py).

    ``rows`` is the (B, k) routing keep mask (bool or int32; all-ones
    under route="exact"); ``packed`` is the tuple from
    ``routing.pack_index`` (one pack per store generation; the server
    caches it).  Traceable — the service executable calls this right
    after ``route_mask`` in its prologue.  Mode routing mirrors
    route_mask: oracle and Mosaic-hostile shapes take the shared jnp
    core (still fused device-side); only lane-aligned shapes pay a
    pallas_call.
    """
    mode = _mode()
    q = jnp.asarray(queries, jnp.float32)
    ls2 = jnp.asarray(ls, jnp.int32).reshape(-1, 1)
    rows2 = jnp.asarray(rows, jnp.int32)
    dim_real = q.shape[1]
    kb = packed[1].shape[1]
    if mode != "interpret" and (mode == "oracle"
                                or dim_real % 128 or kb % 128):
        _count_fallback("index_mask",
                        "mode_oracle" if mode == "oracle" else "unaligned")
        out = _index_ref_jit(q, ls2, rows2, *packed,
                             oversample=float(oversample))
    else:
        out = _index_padded(q, ls2, rows2, *packed,
                            oversample=float(oversample),
                            block_b=_routing.DEFAULT_BLOCK_B,
                            interpret=mode == "interpret")
    return out != 0
