"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` is the semantic ground truth: the kernels must match these
within dtype tolerance for every shape/dtype in the sweep
(`tests/test_kernels.py`).  These are also the CPU fallbacks used by
`ops.py` when a shape violates a kernel's specialization envelope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def l2_distance_ref(queries: jax.Array, points: jax.Array) -> jax.Array:
    """(B, d), (m, d) -> (B, m) squared L2 distances, f32 accumulation.

    Matches the kernel's contraction order: d = |q|^2 - 2 q.p + |p|^2,
    clamped at zero (the expansion can go epsilon-negative in finite
    precision; distances are non-negative by definition).
    """
    q = queries.astype(jnp.float32)
    p = points.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)
    p2 = jnp.sum(p * p, axis=-1)
    qp = q @ p.T
    return jnp.maximum(q2 - 2.0 * qp + p2[None, :], 0.0)


def local_topk_ref(values: jax.Array, l: int):
    """(B, m) -> ((B, l) ascending values, (B, l) indices): l smallest.

    Ties broken toward the smaller index (lax.top_k's stable order on the
    negated input).
    """
    neg_top, idx = lax.top_k(-values.astype(jnp.float32), l)
    return -neg_top, idx.astype(jnp.int32)


def distance_topk_ref(queries: jax.Array, points: jax.Array, l: int):
    """Fused oracle: l smallest squared distances + point indices."""
    d = l2_distance_ref(queries, points)
    return local_topk_ref(d, l)


def masked_l2_distance_ref(queries: jax.Array, points: jax.Array,
                           valid: jax.Array) -> jax.Array:
    """Masked distance oracle: invalid point rows come back as +inf.

    ``valid``: (m,) bool — the mutable store's live-slot mask.  Masking
    happens *before* any top-l reduction a caller runs downstream, so a
    tombstoned slot can never win a neighbor slot (it competes as +inf,
    the same sentinel the paper uses for fake padding points).
    """
    d = l2_distance_ref(queries, points)
    return jnp.where(valid[None, :].astype(jnp.bool_), d, jnp.inf)


def masked_distance_topk_ref(queries: jax.Array, points: jax.Array,
                             valid: jax.Array, l: int):
    """Masked fused oracle: top-l over live slots only.

    Slots whose distance is +inf (masked or padding) report the
    INT32_MAX sentinel id — a deleted point's id must never surface,
    even attached to an infinite distance.
    """
    d = masked_l2_distance_ref(queries, points, valid)
    v, i = local_topk_ref(d, l)
    return v, jnp.where(jnp.isfinite(v), i, jnp.int32(2**31 - 1))
