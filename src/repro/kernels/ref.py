"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` is the semantic ground truth: the kernels must match these
within dtype tolerance for every shape/dtype in the sweep
(`tests/test_kernels.py`).  These are also the CPU fallbacks used by
`ops.py` when a shape violates a kernel's specialization envelope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def l2_distance_ref(queries: jax.Array, points: jax.Array) -> jax.Array:
    """(B, d), (m, d) -> (B, m) squared L2 distances, f32 accumulation.

    Matches the kernel's contraction order: d = |q|^2 - 2 q.p + |p|^2,
    clamped at zero (the expansion can go epsilon-negative in finite
    precision; distances are non-negative by definition).
    """
    q = queries.astype(jnp.float32)
    p = points.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)
    p2 = jnp.sum(p * p, axis=-1)
    qp = q @ p.T
    return jnp.maximum(q2 - 2.0 * qp + p2[None, :], 0.0)


def local_topk_ref(values: jax.Array, l: int):
    """(B, m) -> ((B, l) ascending values, (B, l) indices): l smallest.

    Ties broken toward the smaller index (lax.top_k's stable order on the
    negated input).
    """
    neg_top, idx = lax.top_k(-values.astype(jnp.float32), l)
    return -neg_top, idx.astype(jnp.int32)


def distance_topk_ref(queries: jax.Array, points: jax.Array, l: int):
    """Fused oracle: l smallest squared distances + point indices."""
    d = l2_distance_ref(queries, points)
    return local_topk_ref(d, l)
