"""Composite-key comparisons and masked range counting.

The paper (Section 2, "implementation issue") breaks ties between points of
equal distance with random unique IDs drawn from [1, n^3].  We replace the
randomized IDs with a *deterministic* composite key ``(value, global_index)``
compared lexicographically: collision-free by construction, same effect on the
algorithm (every element has a distinct rank), and free of the 1/n failure
probability of random IDs.

All selection/counting code in :mod:`repro.core` works on these keys.  A key is
represented as a pair of arrays ``(v, i)`` with ``v`` floating (the value /
distance) and ``i`` int32 (the global element id).  ``+inf`` sentinels (the
paper's Step 2 padding in Algorithm 2) carry ``i = INT32_MAX`` so they sort
after every real element; the lower bound sentinel is ``(-inf, -1)``.
"""

from __future__ import annotations

import jax.numpy as jnp

# Sentinel ids for the exclusive interval bounds (lo, hi).
ID_LO = jnp.int32(-2_147_483_648)  # pairs with -inf
ID_HI = jnp.int32(2_147_483_647)   # pairs with +inf


def key_lt(av, ai, bv, bi):
    """Lexicographic ``(av, ai) < (bv, bi)``.

    NaN-free by contract: distances are finite or +/-inf sentinels.
    """
    return (av < bv) | ((av == bv) & (ai < bi))


def key_le(av, ai, bv, bi):
    return (av < bv) | ((av == bv) & (ai <= bi))


def key_min(av, ai, bv, bi):
    """Pointwise lexicographic minimum of two keys."""
    take_a = key_lt(av, ai, bv, bi)
    return jnp.where(take_a, av, bv), jnp.where(take_a, ai, bi)


def key_max(av, ai, bv, bi):
    take_a = key_lt(av, ai, bv, bi)
    return jnp.where(take_a, bv, av), jnp.where(take_a, bi, ai)


def in_open_interval(v, i, lo_v, lo_i, hi_v, hi_i):
    """Mask of elements with ``lo < (v, i) < hi`` (both bounds exclusive).

    This is the candidate set of the current selection iteration; keeping both
    bounds exclusive guarantees the pivot itself leaves the candidate set every
    iteration, so Algorithm 1 terminates deterministically (DESIGN.md Section 2).

    Shapes: ``v, i`` are ``(..., m)``; bounds broadcast (typically ``(..., 1)``).
    """
    above_lo = key_lt(lo_v, lo_i, v, i)
    below_hi = key_lt(v, i, hi_v, hi_i)
    return above_lo & below_hi


def count_le(v, i, bound_v, bound_i, within=None):
    """``|{x : x <= bound}|`` per row, optionally restricted to ``within`` mask.

    This is the per-machine answer to the leader's ``getSize(min, p)`` query
    (Algorithm 1, line 7): each machine reports how many of its points fall at
    or below the pivot.  The caller psums the result over the machine axis.
    """
    m = key_le(v, i, bound_v, bound_i)
    if within is not None:
        m = m & within
    return jnp.sum(m.astype(jnp.int32), axis=-1)


def masked_select_nth(mask, n):
    """Index of the ``n``-th True entry of ``mask`` (0-based) along axis -1.

    Used by the per-shard uniform pivot draw: machine i picks its ``n``-th
    in-range point where ``n ~ U[0, n_i)`` (Algorithm 1, line 5(2)).  Returns
    an arbitrary valid index when ``mask`` has fewer than ``n+1`` True entries
    (callers guard on the count).
    """
    csum = jnp.cumsum(mask.astype(jnp.int32), axis=-1)
    target = jnp.expand_dims(n + 1, -1)
    hit = (csum == target) & mask
    return jnp.argmax(hit, axis=-1)
