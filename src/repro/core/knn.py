"""Algorithm 2 — distributed l-nearest-neighbors, end to end.

Paper Section 2.2.  Pipeline per query batch (each step annotated with its
paper line and its collective cost):

  1. distance computation  d_ij = dis(p_ij, q)        local (Step 8; Pallas
     kernel `kernels.distance_topk` on the hot path, jnp fallback here)
  2. local top-l reduction, +inf sentinel padding      local (Step 2)
  3. sample-and-prune to O(l) survivors                1 all_gather + 1 psum
     (Steps 3-7, `core.sampling`)
  4. Algorithm 1 selection on survivors                O(log l) x (all_gather
     + psum) of O(B) scalars  (`core.selection`)
  5. output: per-shard mask of the l winners           local
     optional result gather into a replicated (B, l) buffer: 1 psum of O(l)

Only *distances and ids* ever cross the network (the paper's privacy note:
points themselves, which may be high-dimensional, stay put).

Also provided: the paper's experimental baseline (`knn_simple`, Section 3):
gather every machine's local top-l to one place and reduce — O(l) rounds /
O(k l) values on the wire; used by `benchmarks/bench_fig2.py` to reproduce
the paper's speedup figure, and by tests as a second oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sampling
from repro.core.selection import (SelectionResult, select_l_smallest,
                                  selected_mask)


class KnnResult(NamedTuple):
    """Distributed l-NN answer.

    ``mask``/``local_dists``/``local_ids`` are per-shard (the paper's native
    output form: "each machine outputs all the points <= max").  When
    ``gather_results=True``, ``dists``/``ids`` hold the l winners replicated
    on every shard (ascending +inf-padded slots), else they are None.
    """

    mask: jax.Array                 # (B, L) bool, per-shard winners
    local_dists: jax.Array          # (B, L) per-shard candidate distances
    local_ids: jax.Array            # (B, L) per-shard candidate global ids
    selection: SelectionResult      # replicated threshold + iteration stats
    prune: sampling.PruneResult     # Lemma 2.3 stats
    dists: jax.Array | None         # (B, l) replicated, or None
    ids: jax.Array | None           # (B, l) replicated, or None
    local_labels: jax.Array | None = None  # (B, L) labels aligned with mask


def squared_l2_distances(queries: jax.Array, points: jax.Array) -> jax.Array:
    """`(B, d) x (m, d) -> (B, m)` squared euclidean distances (jnp reference).

    The MXU-friendly expansion ||q||^2 - 2 q.p + ||p||^2: one (B, d) @ (d, m)
    matmul dominates.  The Pallas kernel `kernels.l2_distance` implements the
    same contraction with explicit VMEM tiling; `kernels/ref.py` mirrors this
    function as the oracle.
    """
    q2 = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    p2 = jnp.sum(points.astype(jnp.float32) ** 2, axis=-1)
    qp = queries.astype(jnp.float32) @ points.astype(jnp.float32).T
    return jnp.maximum(q2 - 2.0 * qp + p2[None, :], 0.0)


def local_top_l(d: jax.Array, ids: jax.Array, l: int, extra=None):
    """Per-shard top-l smallest (Algorithm 2, Step 2), +inf sentinel padded.

    ``d``: (B, m) distances, ``ids``: (B, m) or (m,) global ids.  When the
    shard holds fewer than l points the paper pads with "fake" sentinel
    points of infinite value; callers with m < l must pre-pad (XLA shapes are
    static, so the pad is part of the buffer layout, not data-dependent).

    ``extra`` ((m,) or (B, m), optional) is a per-slot payload — the
    prediction plane's label buffer — reordered by the *same* top-l
    permutation, so ``extra[b, i]`` stays the payload of the point behind
    ``d[b, i]``/``ids[b, i]``.  With ``extra`` the return is a 3-tuple
    (payload pad slots carry 0; they sit behind +inf distances, which
    every consumer masks on).
    """
    if ids.ndim == 1:
        ids = jnp.broadcast_to(ids[None], d.shape)
    if extra is not None and extra.ndim == 1:
        extra = jnp.broadcast_to(extra[None], d.shape)
    m = d.shape[-1]
    if m <= l:
        pad = l - m
        d = jnp.pad(d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=2**31 - 1)
        if extra is not None:
            return d, ids, jnp.pad(extra, ((0, 0), (0, pad)))
        return d, ids
    neg_top, top_idx = lax.top_k(-d, l)
    out_d = -neg_top
    out_ids = jnp.take_along_axis(ids, top_idx, axis=-1)
    if extra is not None:
        return out_d, out_ids, jnp.take_along_axis(extra, top_idx, axis=-1)
    return out_d, out_ids


def gather_selected(d, gid, mask, l: int, *, axis_name: str):
    """Pack the globally selected elements into replicated (B, l) buffers.

    Rank-stable pack: shard j's winners land after all winners of shards
    < j, preserving nothing about intra-order (callers sort the l-sized
    result locally if they need ascending order — l is small).  Cost: one
    all_gather of a scalar count + one psum of 2 l floats (this is the
    *output* step; the paper's Algorithm 2 leaves results distributed, so
    this is optional).
    """
    B = d.shape[0]
    my_cnt = jnp.sum(mask.astype(jnp.int32), axis=-1)            # (B,)
    all_cnt = lax.all_gather(my_cnt, axis_name)                  # (k, B)
    me = lax.axis_index(axis_name)
    offset = jnp.sum(jnp.where(
        (jnp.arange(all_cnt.shape[0]) < me)[:, None], all_cnt, 0), axis=0)

    rank = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1       # (B, L)
    col = jnp.where(mask, offset[:, None] + rank, l)             # l => dropped
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], col.shape)

    dbuf = jnp.zeros((B, l + 1), d.dtype).at[rows, col].add(
        jnp.where(mask, d, 0), mode="drop")
    ibuf = jnp.zeros((B, l + 1), jnp.int32).at[rows, col].add(
        jnp.where(mask, gid, 0), mode="drop")
    dists = lax.psum(dbuf[:, :l], axis_name)
    ids = lax.psum(ibuf[:, :l], axis_name)
    # Unfilled slots (when fewer than l finite points exist) become +inf.
    filled = jnp.arange(l)[None] < lax.psum(my_cnt, axis_name)[:, None]
    dists = jnp.where(filled, dists, jnp.inf)
    ids = jnp.where(filled, ids, 2**31 - 1)
    return dists, ids


def _masked_distances(distances_fn, queries, points, point_valid):
    """Distance matrix with tombstoned rows at +inf.

    ``distances_fn`` implementations that can push the mask down into
    their own top-l machinery (kernels/ops.py) advertise it with a
    ``supports_valid`` attribute and receive ``valid=`` directly;
    otherwise the mask is applied here, before the local top-l — either
    way an invalid point competes as the paper's +inf fake point.
    """
    if point_valid is None:
        return distances_fn(queries, points)
    if getattr(distances_fn, "supports_valid", False):
        return distances_fn(queries, points, valid=point_valid)
    d = distances_fn(queries, points)
    return jnp.where(point_valid[None, :].astype(jnp.bool_), d, jnp.inf)


def _apply_shard_routing(point_valid, shard_active, m):
    """Fold the ``route="pruned"`` whole-shard mask into the point mask.

    ``shard_active`` is this shard's routing flag (a (1,)-slice of the
    per-batch (k,) active vector, or a scalar): False means the
    summaries-layer lower-bound test (store/summaries.py route_shards)
    proved this shard cannot hold a winner, so every one of its points
    enters the pipeline at +inf — upstream of the fused distance+top-l
    kernel, through the same ``valid`` operand tombstones use.  Exactness
    is the *caller's* contract: the flag must come from a sound bound
    against the same snapshot generation being queried.
    """
    if shard_active is None:
        return point_valid
    flag = jnp.reshape(shard_active, ()).astype(jnp.bool_)
    if point_valid is None:
        return jnp.broadcast_to(flag, (m,))
    return point_valid & flag


def _fold_candidates(point_valid, point_candidates):
    """Fold the ``search="approx"`` bucket-candidate mask into the point
    mask (store/index.py): a non-candidate competes as the paper's +inf
    fake point, exactly like a tombstone or a routed-away shard.  Unlike
    those two, candidate pruning is NOT exact — the caller opts in to a
    measured recall contract (DESIGN.md §13)."""
    if point_candidates is None:
        return point_valid
    pc = point_candidates.astype(jnp.bool_)
    return pc if point_valid is None else point_valid & pc


def _knn_pipeline(
    points, point_ids, queries, l_buf, l_run, key, *,
    axis_name, distances_fn, use_sampling, num_pivots, gather_results,
    point_valid=None, shard_active=None, point_candidates=None,
    point_labels=None,
) -> KnnResult:
    """Shared Algorithm 2 body.

    ``l_buf`` is the static per-shard buffer width (the paper's "exactly l
    points per machine"); ``l_run`` is the runtime selection rank — a scalar
    (classic single-l path) or a (B,) int32 array (the service's per-request
    l, bounded by ``l_buf``).  The selection threshold is per-row, so rows
    with smaller l simply stop earlier in composite-key order; their unused
    output slots come back as +inf sentinels from ``gather_selected``.

    ``point_valid`` ((m,) bool, optional) is the mutable store's live-slot
    mask: invalid slots enter the pipeline at +inf, making them
    indistinguishable from the paper's fake sentinel points — they are
    never sampled as survivors, never selected, never gathered.
    ``shard_active`` (optional) is the pruned-routing whole-shard flag
    (:func:`_apply_shard_routing`); ``point_candidates`` ((m,) bool,
    optional) is the approximate in-shard candidate mask
    (:func:`_fold_candidates`).  ``point_labels`` ((m,) f32, optional)
    is the prediction plane's per-slot payload, carried through the
    local top-l permutation into ``KnnResult.local_labels`` so
    :func:`knn_classify`/:func:`knn_regress` can vote over exactly the
    selected winners (tombstoned / routed-away / non-candidate slots
    never reach the mask, so they never vote).
    """
    point_valid = _apply_shard_routing(point_valid, shard_active,
                                       points.shape[0])
    point_valid = _fold_candidates(point_valid, point_candidates)
    d_full = _masked_distances(distances_fn, queries, points, point_valid)
    labels_top = None
    if point_labels is not None:
        d, gid, labels_top = local_top_l(d_full, point_ids, l_buf,
                                         extra=point_labels)
    else:
        d, gid = local_top_l(d_full, point_ids, l_buf)           # (B, l_buf)

    if use_sampling:
        prune = sampling.sample_prune(d, key, l_run, axis_name=axis_name)
    else:
        finite = jnp.isfinite(d)
        cnt = lax.psum(jnp.sum(finite.astype(jnp.int32), -1), axis_name)
        prune = sampling.PruneResult(
            valid=finite, radius=jnp.full(d.shape[:1], jnp.inf),
            survivors=cnt, applied=jnp.zeros(d.shape[:1], bool))

    sel = select_l_smallest(
        d, gid, l_run, jax.random.fold_in(key, 1), axis_name=axis_name,
        valid=prune.valid, num_pivots=num_pivots)
    mask = selected_mask(d, gid, sel, valid=prune.valid)

    dists = ids = None
    if gather_results:
        dists, ids = gather_selected(d, gid, mask, l_buf, axis_name=axis_name)
    return KnnResult(mask=mask, local_dists=d, local_ids=gid, selection=sel,
                     prune=prune, dists=dists, ids=ids,
                     local_labels=labels_top)


def knn_query(
    points: jax.Array,
    point_ids: jax.Array,
    queries: jax.Array,
    l: int,
    key: jax.Array,
    *,
    axis_name: str,
    distances_fn=squared_l2_distances,
    use_sampling: bool = True,
    num_pivots: int = 1,
    gather_results: bool = True,
    point_valid: jax.Array | None = None,
    shard_active: jax.Array | None = None,
    point_candidates: jax.Array | None = None,
    point_labels: jax.Array | None = None,
) -> KnnResult:
    """Full Algorithm 2 inside a shard_map context.

    ``points``: (m, dim) this shard's points; ``point_ids``: (m,) globally
    unique int32 ids; ``queries``: (B, dim) replicated query batch.
    ``num_pivots > 1`` enables the beyond-paper multi-pivot selection.
    ``point_valid`` ((m,) bool, optional): live-slot mask for mutable
    stores — invalid slots are treated as the paper's +inf fake points.
    ``shard_active`` (optional): this shard's ``route="pruned"`` flag —
    False masks the whole shard the same way (store/summaries.py).
    ``point_labels`` ((m,) f32, optional): the prediction plane's label
    payload, returned top-l-aligned in ``KnnResult.local_labels``.
    """
    return _knn_pipeline(
        points, point_ids, queries, l, l, key, axis_name=axis_name,
        distances_fn=distances_fn, use_sampling=use_sampling,
        num_pivots=num_pivots, gather_results=gather_results,
        point_valid=point_valid, shard_active=shard_active,
        point_candidates=point_candidates, point_labels=point_labels)


def knn_query_batched(
    points: jax.Array,
    point_ids: jax.Array,
    queries: jax.Array,
    l_max: int,
    l: jax.Array,
    key: jax.Array,
    *,
    axis_name: str,
    distances_fn=squared_l2_distances,
    use_sampling: bool = True,
    num_pivots: int = 1,
    gather_results: bool = True,
    point_valid: jax.Array | None = None,
    shard_active: jax.Array | None = None,
    point_candidates: jax.Array | None = None,
    point_labels: jax.Array | None = None,
) -> KnnResult:
    """Algorithm 2 with a *per-request* neighbor count — the serving form.

    The micro-batched query service (runtime/knn_server.py) coalesces
    requests with heterogeneous l into one device batch.  XLA needs static
    shapes, so all buffers are sized by the shared upper bound ``l_max``
    while the selection rank ``l`` is data: a (B,) int32 array, one entry
    per request, ``0 <= l[b] <= l_max``.  All B selection problems run in
    lockstep through the same Algorithm 1 while-loop (per-row ``done``
    freezing — a row that found its rank-l threshold stops moving), so a
    mixed-l batch costs the rounds of its *hardest* row, not the sum.

    Rows with ``l[b] == 0`` (the micro-batcher's bucket padding) select
    nothing and return all-+inf slots; their queries never influence other
    rows (every step is row-independent except the shared iteration count).
    Gathered outputs are (B, l_max): row b's first l[b] slots hold its
    ascending-by-pack winners, the rest are +inf / INT32_MAX sentinels.
    """
    l = jnp.minimum(jnp.broadcast_to(jnp.asarray(l, jnp.int32),
                                     queries.shape[:1]), l_max)
    return _knn_pipeline(
        points, point_ids, queries, l_max, l, key, axis_name=axis_name,
        distances_fn=distances_fn, use_sampling=use_sampling,
        num_pivots=num_pivots, gather_results=gather_results,
        point_valid=point_valid, shard_active=shard_active,
        point_candidates=point_candidates, point_labels=point_labels)


def knn_simple(
    points: jax.Array,
    point_ids: jax.Array,
    queries: jax.Array,
    l: int,
    *,
    axis_name: str,
    distances_fn=squared_l2_distances,
    point_valid: jax.Array | None = None,
    shard_active: jax.Array | None = None,
    point_candidates: jax.Array | None = None,
):
    """The paper's baseline "simple method" (Section 3).

    Local top-l, then gather all k*l candidates and reduce.  O(l) rounds in
    the k-machine model (k*l values over the leader's links); one
    all_gather of l values per shard here.  Returns replicated ascending
    (dists, ids) of shape (B, l); +inf slots (fewer than l live points)
    carry the INT32_MAX sentinel id.  ``shard_active`` masks this whole
    shard when pruned routing proved it loser-only (same contract as
    :func:`knn_query`).
    """
    point_valid = _apply_shard_routing(point_valid, shard_active,
                                       points.shape[0])
    point_valid = _fold_candidates(point_valid, point_candidates)
    d_full = _masked_distances(distances_fn, queries, points, point_valid)
    d, gid = local_top_l(d_full, point_ids, l)
    gd = lax.all_gather(d, axis_name)                            # (k, B, l)
    gi = lax.all_gather(gid, axis_name)
    B = d.shape[0]
    k = gd.shape[0]
    flat_d = jnp.moveaxis(gd, 0, 1).reshape(B, k * l)
    flat_i = jnp.moveaxis(gi, 0, 1).reshape(B, k * l)
    neg_top, idx = lax.top_k(-flat_d, l)
    dists = -neg_top
    ids = jnp.take_along_axis(flat_i, idx, axis=-1)
    # +inf slots may still carry a real (masked-out or padded) point's id
    # from the local buffer; a dead point's id must never surface.
    ids = jnp.where(jnp.isfinite(dists), ids, 2**31 - 1)
    from repro.parallel.collectives import replicate
    return (replicate(dists, axis_name), replicate(ids, axis_name))


def knn_classify(mask, labels, num_classes: int, *, axis_name: str):
    """Majority vote over the selected neighbors — fully distributed.

    ``labels``: (B, L) int32 per-shard labels aligned with the knn buffers
    (gather-free: the label histogram, not the points, crosses the network —
    the paper's privacy property extends to inference).
    """
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.int32)
    hist = jnp.sum(jnp.where(mask[..., None], onehot, 0), axis=-2)
    hist = lax.psum(hist, axis_name)                             # (B, C)
    return jnp.argmax(hist, axis=-1), hist


def knn_regress(mask, values, *, axis_name: str):
    """Mean of neighbor target values — fully distributed (1 psum)."""
    num = lax.psum(jnp.sum(jnp.where(mask, values, 0.0), axis=-1), axis_name)
    den = lax.psum(jnp.sum(mask.astype(jnp.float32), axis=-1), axis_name)
    return num / jnp.maximum(den, 1.0)
