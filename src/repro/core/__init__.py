"""The paper's contribution: distributed selection and l-NN in the k-machine
model, mapped onto JAX mesh collectives (see DESIGN.md Section 2).

Public API re-exports.
"""

from repro.core.selection import (SelectionResult, select_l_smallest,
                                  selected_mask)
from repro.core.sampling import PruneResult, sample_prune
from repro.core.knn import (KnnResult, knn_query, knn_query_batched,
                            knn_simple, knn_classify, knn_regress,
                            squared_l2_distances, local_top_l,
                            gather_selected)
from repro.core.topk import (TopKResult, distributed_topk, topk_sample,
                             greedy_sample)
from repro.core import datastore

__all__ = [
    "SelectionResult", "select_l_smallest", "selected_mask",
    "PruneResult", "sample_prune",
    "KnnResult", "knn_query", "knn_query_batched", "knn_simple",
    "knn_classify", "knn_regress",
    "squared_l2_distances", "local_top_l", "gather_selected",
    "TopKResult", "distributed_topk", "topk_sample", "greedy_sample",
    "datastore",
]
