"""Sharded kNN-LM datastore — the paper's l-NN as a serving-time feature.

kNN-LM (Khandelwal et al., ICLR 2020) interpolates the LM's next-token
distribution with a nearest-neighbor distribution over a datastore of
(hidden-state key, next-token value) pairs.  The datastore is naturally
*distributed* — billions of keys sharded across the mesh — which is precisely
the paper's setting: query point (the decoder hidden state) broadcast to all
machines, answer = l nearest keys.  Retrieval runs Algorithm 2 per decode
step; only distances/ids/token-values cross the ICI, never the d_model-sized
keys (paper Section 1.3's privacy/bandwidth note, production form).

The kNN mixture is returned *sparse* — (token_id, weight) pairs for the l
winners, replicated — and scattered into the model-sharded logits locally by
`interp_logits`, so the full-vocab distribution is never materialized
unsharded.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import knn as knn_mod


class Datastore(NamedTuple):
    """Per-shard slice of the (keys, values) store.

    keys:   (m, d)  hidden-state keys (bf16 storage is fine; distances are
                     accumulated in f32 by the distance kernel)
    values: (m,)    int32 next-token ids
    ids:    (m,)    globally unique int32 point ids
    """

    keys: jax.Array
    values: jax.Array
    ids: jax.Array


def build_local(keys: jax.Array, values: jax.Array, *,
                axis_name: str) -> Datastore:
    """Wrap this shard's slice, assigning globally-unique contiguous ids."""
    m = keys.shape[0]
    start = lax.axis_index(axis_name) * m
    ids = (start + jnp.arange(m)).astype(jnp.int32)
    return Datastore(keys=keys, values=values.astype(jnp.int32), ids=ids)


class RetrievalResult(NamedTuple):
    tokens: jax.Array      # (B, l) replicated winner token values
    weights: jax.Array     # (B, l) replicated softmax(-d / T) weights
    dists: jax.Array       # (B, l) replicated distances (+inf padding)
    iterations: jax.Array  # selection iterations (round-count telemetry)


def retrieve(
    store: Datastore,
    queries: jax.Array,
    l: int,
    key: jax.Array,
    *,
    axis_name: str,
    temperature: float = 10.0,
    distances_fn=knn_mod.squared_l2_distances,
    num_pivots: int = 1,
) -> RetrievalResult:
    """Algorithm 2 retrieval + softmax weighting of the l winners."""
    res = knn_mod.knn_query(
        store.keys, store.ids, queries, l, key, axis_name=axis_name,
        distances_fn=distances_fn, num_pivots=num_pivots,
        gather_results=False)

    # Winners' token values: reuse the rank-stable pack, sending the token
    # value in place of the point id (values are what the LM needs).  The
    # local top-l buffer's global ids map back to local store rows as
    # id - shard_offset (ids were assigned contiguously in build_local).
    m = store.keys.shape[0]
    start = lax.axis_index(axis_name) * m
    local_row = jnp.clip(res.local_ids - start, 0, m - 1)
    vals = store.values[local_row]                              # (B, l)
    dists, tokens = knn_mod.gather_selected(
        res.local_dists, jnp.where(res.mask, vals, 0), res.mask, l,
        axis_name=axis_name)

    logit = jnp.where(jnp.isfinite(dists), -dists / temperature, -jnp.inf)
    weights = jax.nn.softmax(logit, axis=-1)
    return RetrievalResult(tokens=tokens, weights=weights, dists=dists,
                           iterations=res.selection.iterations)


def interp_logits(
    lm_logits: jax.Array,
    retrieval: RetrievalResult,
    lam: float,
    *,
    axis_name: str,
) -> jax.Array:
    """(1-lam) * p_LM + lam * p_kNN, computed on model-sharded logits.

    ``lm_logits``: (B, V_local), this shard's contiguous vocab chunk.  The
    sparse kNN mass is scattered only into the owning shard's chunk; the
    log-space result feeds the (also sharded) sampler directly.
    """
    B, v_local = lm_logits.shape
    start = lax.axis_index(axis_name) * v_local

    # p_LM needs a global softmax over the sharded vocab: max + sumexp psums.
    m = lax.pmax(jnp.max(lm_logits, axis=-1), axis_name)
    e = jnp.exp(lm_logits - m[:, None])
    z = lax.psum(jnp.sum(e, axis=-1), axis_name)
    p_lm = e / z[:, None]

    # Scatter this shard's share of the kNN mass.
    local_tok = retrieval.tokens - start
    in_range = (local_tok >= 0) & (local_tok < v_local)
    cols = jnp.where(in_range, local_tok, v_local)
    rows = jnp.broadcast_to(jnp.arange(B)[:, None], cols.shape)
    p_knn = jnp.zeros((B, v_local + 1), p_lm.dtype).at[rows, cols].add(
        jnp.where(in_range, retrieval.weights, 0.0), mode="drop")[:, :v_local]

    mixed = (1.0 - lam) * p_lm + lam * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-30))
