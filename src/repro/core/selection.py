"""Algorithm 1 — distributed randomized selection, SPMD over a mesh axis.

Paper: Fathi, Molla, Pandurangan, "Efficient Distributed Algorithms for the
K-Nearest Neighbors Problem" (2020), Section 2.1.

The paper's k machines map to the shards of a named mesh axis; this module is
written to run *inside* :func:`jax.shard_map` (or any context where
``jax.lax.psum(axis_name)`` is legal).  See DESIGN.md Section 2 for the full
adaptation table.  The two deliberate departures from the paper's pseudocode:

* **Leaderless SPMD.**  The paper elects a leader that owns the control state
  (min, max, remaining rank) and exchanges point-to-point messages with every
  machine each iteration.  On a TPU mesh the all-reduce tree is a hardware
  primitive, so we *replicate* the leader: after each ``psum``/``all_gather``
  every shard holds identical control state and draws identical pseudo-random
  decisions from a shared key.  Lemma 2.1's pivot-uniformity argument is
  preserved exactly — shard i proposes a uniform element of its in-range set,
  and the replicated weighted draw picks shard i with probability n_i / n.

* **Exclusive bounds.**  We maintain the candidate interval as the *open*
  interval (lo, hi) over composite (value, id) keys, so the pivot itself is
  removed from the candidate set every iteration regardless of the branch
  taken.  This turns the paper's w.h.p. termination into deterministic
  termination (at most n iterations; O(log n) w.h.p. as in Theorem 2.2), which
  a fixed-trip-count ``lax.while_loop`` needs.

Round/message accounting (used by the benchmark harness): each iteration costs
one ``all_gather`` of k (pivot-candidate, count) scalar tuples — the paper's
pivot round — plus one ``psum`` of a scalar count — the paper's getSize round.
That is 2 rounds and 2(k-1) messages per iteration, matching Theorem 2.2's
O(log n) rounds / O(k log n) messages.

The ``num_pivots > 1`` mode is a **beyond-paper optimization** (recorded in
EXPERIMENTS.md Section Perf): every shard proposes a pivot and the counts for
*all* k pivots are computed in the same two collectives, tightening the
interval by the best bracketing pair.  Iterations drop from O(log n) to
O(log n / log k) — the collective payload grows from O(1) to O(k) scalars per
shard, which is still far below a single link's per-round bandwidth B.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import counting as ck


class SelectionResult(NamedTuple):
    """Replicated result of a distributed selection.

    ``threshold_*`` is the composite key of the rank-``l`` smallest element;
    an element x is selected iff ``x <= threshold`` in composite order, so
    exactly ``l`` elements are selected globally (Definition 1.1).
    ``iterations`` is the number of while-loop iterations actually executed
    (data-dependent; exposed for the Theorem 2.2 / 2.4 round benchmarks).
    """

    threshold_v: jax.Array   # (B,) float
    threshold_i: jax.Array   # (B,) int32
    iterations: jax.Array    # ()   int32
    converged: jax.Array     # (B,) bool — False only if the cap was hit


class _LoopState(NamedTuple):
    lo_v: jax.Array
    lo_i: jax.Array
    hi_v: jax.Array
    hi_i: jax.Array
    rank: jax.Array       # remaining rank within (lo, hi), int32 (B,)
    done: jax.Array       # (B,) bool
    thr_v: jax.Array
    thr_i: jax.Array
    it: jax.Array         # () int32
    key: jax.Array        # replicated PRNG key


def _propose_local_pivot(v, i, cand_mask, key):
    """Each machine draws one uniform element of its in-range set.

    Algorithm 1, line 5(2): the selected machine picks a point uniformly at
    random among its n_i in-range points.  We have *every* shard propose (the
    replicated weighted draw then discards all but one), folding the shard id
    into the key so proposals are independent across shards.
    """
    n_i = jnp.sum(cand_mask.astype(jnp.int32), axis=-1)            # (B,)
    u = jax.random.randint(key, n_i.shape, 0, jnp.maximum(n_i, 1))
    idx = ck.masked_select_nth(cand_mask, u)                        # (B,)
    pv = jnp.take_along_axis(v, idx[..., None], axis=-1)[..., 0]
    pi = jnp.take_along_axis(i, idx[..., None], axis=-1)[..., 0]
    # Shards with no in-range points propose the +inf sentinel; their count of
    # zero gives them probability zero in the replicated weighted draw.
    empty = n_i == 0
    pv = jnp.where(empty, jnp.inf, pv)
    pi = jnp.where(empty, ck.ID_HI, pi)
    return pv, pi, n_i


def _select_body(state: _LoopState, *, v, i, valid, axis_name, num_pivots):
    key_it = jax.random.fold_in(state.key, state.it)
    # Independent per-shard stream for the local uniform draw; the *shared*
    # stream (key_it) drives every replicated decision.
    key_local = jax.random.fold_in(key_it, lax.axis_index(axis_name))

    cand = ck.in_open_interval(
        v, i,
        state.lo_v[..., None], state.lo_i[..., None],
        state.hi_v[..., None], state.hi_i[..., None],
    )
    if valid is not None:
        # Algorithm 2 pruning: excluded elements are invisible to the search —
        # never pivots, never counted (paper Step 7's "removes any point
        # larger than r and any of added fake data").
        cand = cand & valid

    pv, pi, n_i = _propose_local_pivot(v, i, cand, key_local)

    # ---- paper round 1: pivot selection -----------------------------------
    # all_gather of (candidate value, candidate id, in-range count): k scalar
    # triples per batch row on the wire.
    g_pv = lax.all_gather(pv, axis_name)          # (k, B)
    g_pi = lax.all_gather(pi, axis_name)
    g_n = lax.all_gather(n_i, axis_name)          # (k, B) int32

    if num_pivots <= 1:
        # Faithful single-pivot mode: replicated weighted machine draw
        # (probability n_i / sum n_j — Lemma 2.1), identical on all shards.
        logits = jnp.where(g_n > 0, jnp.log(g_n.astype(jnp.float32)), -jnp.inf)
        choice = jax.random.categorical(key_it, logits, axis=0)      # (B,)
        piv_v = jnp.take_along_axis(g_pv, choice[None], axis=0)[0]
        piv_i = jnp.take_along_axis(g_pi, choice[None], axis=0)[0]
        piv_v = piv_v[None]                                          # (1, B)
        piv_i = piv_i[None]
    else:
        # Beyond-paper multi-pivot mode: evaluate every shard's proposal.
        piv_v, piv_i = g_pv, g_pi                                    # (k, B)

    # ---- paper round 2: getSize(lo, p] ------------------------------------
    # Count, per shard, elements in (lo, p] for each pivot, then one psum.
    le_piv = ck.key_le(v[None], i[None], piv_v[..., None], piv_i[..., None])
    local_cnt = jnp.sum((le_piv & cand[None]).astype(jnp.int32), axis=-1)
    cnt = lax.psum(local_cnt, axis_name)                             # (P, B)

    rank = state.rank[None]                                          # (1, B)
    # Pivots outside (lo, hi) (sentinel proposals) must not bracket.
    valid = ck.in_open_interval(
        piv_v, piv_i, state.lo_v[None], state.lo_i[None],
        state.hi_v[None], state.hi_i[None])

    hit = valid & (cnt == rank)
    below = valid & (cnt < rank)       # pivot can become the new lo
    above = valid & (cnt > rank)       # pivot can become the new hi

    # Tightest bracketing: new lo = max pivot with cnt < rank (and subtract
    # its count); new hi = min pivot with cnt > rank.  With one pivot this
    # degenerates to the paper's if/else on s vs l (lines 9-13).
    NEG = (-jnp.inf, ck.ID_LO)
    POS = (jnp.inf, ck.ID_HI)

    bv = jnp.where(below, piv_v, NEG[0])
    bi = jnp.where(below, piv_i, NEG[1])
    # lexicographic argmax over pivot axis
    best_lo_v, best_lo_i, best_lo_cnt = _key_argmax(bv, bi, cnt)
    av = jnp.where(above, piv_v, POS[0])
    ai = jnp.where(above, piv_i, POS[1])
    best_hi_v, best_hi_i = _key_argmin(av, ai)

    any_hit = jnp.any(hit, axis=0)
    # threshold from the (unique, if any) hitting pivot
    hv = jnp.where(hit, piv_v, jnp.inf)
    hi_ = jnp.where(hit, piv_i, ck.ID_HI)
    hit_v, hit_i = _key_argmin(hv, hi_)

    has_lo = jnp.any(below, axis=0)
    has_hi = jnp.any(above, axis=0)

    new_lo_v = jnp.where(has_lo, best_lo_v, state.lo_v)
    new_lo_i = jnp.where(has_lo, best_lo_i, state.lo_i)
    new_rank = jnp.where(has_lo, state.rank - best_lo_cnt, state.rank)
    new_hi_v = jnp.where(has_hi, best_hi_v, state.hi_v)
    new_hi_i = jnp.where(has_hi, best_hi_i, state.hi_i)

    done_now = any_hit & ~state.done
    thr_v = jnp.where(done_now, hit_v, state.thr_v)
    thr_i = jnp.where(done_now, hit_i, state.thr_i)

    keep = state.done  # frozen rows
    return _LoopState(
        lo_v=jnp.where(keep, state.lo_v, new_lo_v),
        lo_i=jnp.where(keep, state.lo_i, new_lo_i),
        hi_v=jnp.where(keep, state.hi_v, new_hi_v),
        hi_i=jnp.where(keep, state.hi_i, new_hi_i),
        rank=jnp.where(keep, state.rank, new_rank),
        done=state.done | any_hit,
        thr_v=thr_v,
        thr_i=thr_i,
        it=state.it + 1,
        key=state.key,
    )


def _key_argmin(v, i):
    """Lexicographic min over axis 0 of a (P, B) composite-key array.

    Min value first, then min id among value ties — exactly lexicographic
    order, with no custom reduction primitive.
    """
    mv = jnp.min(v, axis=0)
    tie = v == mv[None]
    mi = jnp.min(jnp.where(tie, i, ck.ID_HI), axis=0)
    return mv, mi


def _key_argmax(v, i, payload):
    """Lexicographic max over axis 0, carrying an int payload along."""
    mv = jnp.max(v, axis=0)
    tie_v = v == mv[None]
    mi = jnp.max(jnp.where(tie_v, i, ck.ID_LO), axis=0)
    sel = tie_v & (i == mi[None])
    # (value, id) pairs are globally unique, so `sel` has exactly one hit per
    # column among real keys; max is a safe extraction.
    mp = jnp.max(jnp.where(sel, payload, jnp.int32(-2147483648)), axis=0)
    return mv, mi, mp


def select_l_smallest(
    v: jax.Array,
    i: jax.Array,
    l: jax.Array,
    key: jax.Array,
    *,
    axis_name: str,
    valid: jax.Array | None = None,
    max_iterations: int | None = None,
    num_pivots: int = 1,
) -> SelectionResult:
    """Find the composite-key threshold of the ``l`` smallest elements.

    Must be called inside a :func:`jax.shard_map` (or pmap) context where
    ``axis_name`` is bound.  ``v``/``i`` are the per-shard local elements,
    shape ``(B, m)`` (``B`` independent selection problems — e.g. a decode
    batch — solved in lockstep; collective payloads are ``O(B)`` scalars).
    ``+inf`` entries are sentinels and are never selected unless ``l`` exceeds
    the number of finite elements.

    ``l`` may be a scalar or ``(B,)`` int array (1 <= l).  The returned
    threshold satisfies ``count(x <= threshold) == min(l, n_finite + n_inf)``
    globally.

    ``max_iterations`` defaults to the Theorem 2.2 w.h.p. bound
    ``8 * ceil(log2(n_global)) + 16``; rows that somehow exceed it report
    ``converged=False`` (probability <= 1/n; callers may re-run with a fresh
    key — the result is still a valid *lower* bound threshold, never wrong,
    just possibly rank-deficient).
    """
    if v.ndim == 1:
        v = v[None]
        i = i[None]
        if valid is not None and valid.ndim == 1:
            valid = valid[None]
    B, m = v.shape
    from repro.parallel.collectives import axis_size
    k = axis_size(axis_name)
    n_global = m * k
    if max_iterations is None:
        # Theorem 2.2 w.h.p. bound with generous constant; the deterministic
        # exclusive-bound update guarantees progress, so hitting the cap has
        # probability <= 1/n (reported via `converged`).
        import math
        max_iterations = 8 * max(1, math.ceil(math.log2(max(n_global, 2)))) + 16

    l = jnp.broadcast_to(jnp.asarray(l, jnp.int32), (B,))
    if valid is None:
        local_total = jnp.full((B,), m, jnp.int32)
    else:
        local_total = jnp.sum(valid.astype(jnp.int32), axis=-1)
    total = lax.psum(local_total, axis_name)
    l = jnp.minimum(l, total)

    # l == 0 rows are done immediately with the -inf threshold.
    zero = l <= 0
    # l == total rows are done immediately with the +inf threshold (select all).
    allsel = l >= total

    state = _LoopState(
        lo_v=jnp.full((B,), -jnp.inf, v.dtype),
        lo_i=jnp.full((B,), ck.ID_LO),
        hi_v=jnp.full((B,), jnp.inf, v.dtype),
        hi_i=jnp.full((B,), ck.ID_HI),
        rank=l,
        done=zero | allsel,
        thr_v=jnp.where(allsel, jnp.inf, -jnp.inf).astype(v.dtype),
        thr_i=jnp.where(allsel, ck.ID_HI, ck.ID_LO),
        it=jnp.int32(0),
        key=key,
    )

    # The loop body mixes the (replicated) control state with per-shard data,
    # so under shard_map's varying-manual-axes checking the carry must be
    # marked as varying over the machine axis up front.
    if hasattr(lax, "pcast"):
        state = jax.tree.map(
            lambda x: lax.pcast(x, (axis_name,), to="varying"), state)

    body = partial(_select_body, v=v, i=i, valid=valid, axis_name=axis_name,
                   num_pivots=num_pivots)

    def cond(s: _LoopState):
        return (~jnp.all(s.done)) & (s.it < max_iterations)

    final = lax.while_loop(cond, body, state)

    # The control state is replicated by construction (every shard ran the
    # same decisions from the same key), but shard_map's varying-manual-axes
    # checker cannot infer that through a while_loop.  One psum of shard 0's
    # copy (O(B) scalars) makes the invariance provable, so callers can use
    # replicated out_specs with full vma checking enabled.
    from repro.parallel.collectives import replicate
    return SelectionResult(
        threshold_v=replicate(final.thr_v, axis_name),
        threshold_i=replicate(final.thr_i, axis_name),
        iterations=replicate(final.it, axis_name),
        converged=replicate(final.done, axis_name),
    )


def selected_mask(v, i, result: SelectionResult, valid=None):
    """Per-shard boolean mask of the globally selected ``l`` elements."""
    m = ck.key_le(
        v, i, result.threshold_v[..., None], result.threshold_i[..., None])
    if valid is not None:
        m = m & valid
    return m
