"""Distributed top-k over a model-sharded axis — the LM-serving face of the paper.

At decode time the vocabulary logits live sharded over the `model` mesh axis
(up to 256206 / 16 per shard for the assigned architectures).  Top-k sampling
classically all-gathers the vocab row; that is exactly the paper's "simple
method" and costs O(V) bytes per token.  This module instead runs the paper's
pipeline on negated logits:

  local lax.top_k  ->  (optional sample-prune)  ->  Algorithm 1 selection
  ->  pack the k winners with one O(k)-sized psum

so the wire cost per token is O(k + log k x B) scalars instead of O(V).
Both methods are exposed; `benchmarks/bench_topk.py` maps their crossover
(gather wins at tiny k by collective-launch latency, selection wins as k or
the candidate pool grows — the Fig. 2 story at the sampler level).

All functions run inside shard_map over ``axis_name`` and assume the local
logits block is the ``axis_index``-th contiguous chunk of the vocab.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import knn as knn_mod
from repro.core.selection import select_l_smallest, selected_mask


class TopKResult(NamedTuple):
    values: jax.Array       # (B, k) replicated top-k logits, descending
    indices: jax.Array      # (B, k) replicated global vocab ids
    iterations: jax.Array   # () selection iterations (0 for gather method)


def _global_ids(local_v: int, axis_name: str) -> jax.Array:
    start = lax.axis_index(axis_name) * local_v
    return (start + jnp.arange(local_v, dtype=jnp.int32))


def distributed_topk(
    logits: jax.Array,
    k: int,
    key: jax.Array,
    *,
    axis_name: str,
    method: str = "selection",
    num_pivots: int = 1,
) -> TopKResult:
    """Top-k largest over the sharded last axis of ``logits`` (B, V_local).

    method="selection": the paper's algorithm (negated logits are distances).
    method="gather":    the simple-method baseline (all_gather k per shard).
    Results are replicated and sorted descending by value.
    """
    B, v_local = logits.shape
    gid = jnp.broadcast_to(_global_ids(v_local, axis_name)[None], (B, v_local))
    neg = -logits.astype(jnp.float32)

    # Step-2 analogue: only the local top-k can be global winners.
    d, ids = knn_mod.local_top_l(neg, gid, k)

    if method == "gather":
        from repro.parallel.collectives import replicate
        gd = lax.all_gather(d, axis_name)                    # (kk, B, k)
        gi = lax.all_gather(ids, axis_name)
        kk = gd.shape[0]
        flat_d = jnp.moveaxis(gd, 0, 1).reshape(B, kk * k)
        flat_i = jnp.moveaxis(gi, 0, 1).reshape(B, kk * k)
        top_neg, idx = lax.top_k(-flat_d, k)
        return TopKResult(
            values=replicate(top_neg, axis_name),
            indices=replicate(jnp.take_along_axis(flat_i, idx, axis=-1),
                              axis_name),
            iterations=jnp.zeros((), jnp.int32))

    if method != "selection":
        raise ValueError(f"unknown method {method!r}")

    finite = jnp.isfinite(d)
    sel = select_l_smallest(d, ids, k, key, axis_name=axis_name,
                            valid=finite, num_pivots=num_pivots)
    mask = selected_mask(d, ids, sel, valid=finite)
    dists, out_ids = knn_mod.gather_selected(d, ids, mask, k,
                                             axis_name=axis_name)
    # Ascending negated-logit order == descending logit order after a local
    # sort of the k replicated winners (k is small; local compute is free).
    order = jnp.argsort(dists, axis=-1)
    vals = -jnp.take_along_axis(dists, order, axis=-1)
    out_ids = jnp.take_along_axis(out_ids, order, axis=-1)
    return TopKResult(values=vals, indices=out_ids,
                      iterations=sel.iterations)


def topk_sample(
    logits: jax.Array,
    k: int,
    temperature: float,
    key: jax.Array,
    *,
    axis_name: str,
    method: str = "selection",
    num_pivots: int = 1,
) -> jax.Array:
    """Top-k temperature sampling over sharded logits -> (B,) token ids.

    The categorical draw happens on the replicated k winners with a shared
    key, so every shard emits the identical token (SPMD-coherent sampling).
    """
    res = distributed_topk(logits, k, jax.random.fold_in(key, 0),
                           axis_name=axis_name, method=method,
                           num_pivots=num_pivots)
    scaled = res.values / jnp.maximum(temperature, 1e-6)
    choice = jax.random.categorical(jax.random.fold_in(key, 1), scaled,
                                    axis=-1)
    return jnp.take_along_axis(res.indices, choice[..., None], axis=-1)[..., 0]


def greedy_sample(logits: jax.Array, *, axis_name: str) -> jax.Array:
    """Argmax over the sharded vocab — one (value, id) psum-max pair.

    Used as the k=1 fast path; costs a single 2-scalar collective.
    """
    B, v_local = logits.shape
    gid = _global_ids(v_local, axis_name)
    loc_v = jnp.max(logits, axis=-1)
    loc_i = gid[jnp.argmax(logits, axis=-1)]
    all_v = lax.all_gather(loc_v, axis_name)                 # (kk, B)
    all_i = lax.all_gather(loc_i, axis_name)
    # break value ties toward the smaller global id, matching lax.top_k on
    # the gathered vector
    best_v = jnp.max(all_v, axis=0)
    tie = all_v == best_v[None]
    best_i = jnp.min(jnp.where(tie, all_i, 2**31 - 1), axis=0)
    from repro.parallel.collectives import replicate
    return replicate(best_i, axis_name)
