"""Algorithm 2, steps 3-7 — the sample-and-prune search-space reduction.

Paper Section 2.2 / Lemma 2.3: every machine samples ``12 log l`` of its local
top-l distances independently with replacement; the sorted union of the
``12 k log l`` samples is taken; the element at index ``21 log l`` becomes the
prune radius r.  With probability >= 1 - 2/l^2 the survivor set {x <= r}
contains the true l nearest neighbors and has at most ``11 l`` elements, so
the follow-up selection (Algorithm 1) runs on O(l) candidates — O(log l)
rounds independent of k (Theorem 2.4).

Hardening (DESIGN.md Section 2): the paper's prune is Monte Carlo.  We spend
one extra psum to *verify* that at least ``l`` elements survive; if not (the
<= 2/l^2 tail event), the prune is skipped via a mask select and the algorithm
degrades to the un-pruned O(log(k l)) variant — the implementation is
therefore Las Vegas: always correct, fast w.h.p.

Collective cost: one all_gather of ``ceil(12 ln l)`` scalars per shard (the
paper's sampling round, Step 4) + one scalar psum (verification).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


# Paper constants (Lemma 2.3): mu = SAMPLE_C * log(l) samples per machine;
# radius index RADIUS_C * log(l) = (1 + sqrt(0.5)) * SAMPLE_C rounded up.
SAMPLE_C = 12
RADIUS_C = 21


class PruneResult(NamedTuple):
    valid: jax.Array          # (B, l) bool — survivor mask incl. finiteness
    radius: jax.Array         # (B,)   prune radius actually applied (+inf if skipped)
    survivors: jax.Array      # (B,)   int32 global survivor count
    applied: jax.Array        # (B,)   bool — False if verification rejected r


def sample_count(l: int) -> int:
    """``ceil(12 ln l)`` — per-machine samples (Algorithm 2, Step 3)."""
    return max(1, math.ceil(SAMPLE_C * math.log(max(l, 2))))


def radius_index(l: int) -> int:
    """``ceil(21 ln l)`` — 1-based index of r in the sorted sample (Step 5)."""
    return max(1, math.ceil(RADIUS_C * math.log(max(l, 2))))


def sample_prune(
    d: jax.Array,
    key: jax.Array,
    l: jax.Array | int,
    *,
    axis_name: str,
) -> PruneResult:
    """Compute the Algorithm 2 survivor mask for per-shard distances ``d``.

    ``d`` has shape ``(B, L)`` where ``L`` is the static local buffer size
    (the paper's "exactly l points per machine after sentinel padding");
    ``+inf`` entries are the paper's fake sentinel points.  ``l`` is the
    runtime neighbor count, ``l <= L`` (typically ``l == L``).

    Must run inside a shard_map context binding ``axis_name``.
    """
    B, L = d.shape
    s = sample_count(L)
    r_idx = radius_index(L)

    # Step 3: independent uniform samples *with replacement* from the local
    # buffer (sentinels included, exactly as the paper states — the analysis
    # relies on every machine contributing the same sample count).
    shard_key = jax.random.fold_in(key, lax.axis_index(axis_name))
    idx = jax.random.randint(shard_key, (B, s), 0, L)
    local_samples = jnp.take_along_axis(d, idx, axis=-1)          # (B, s)

    # Step 4: one gather round — s scalars per shard on the wire.
    gathered = lax.all_gather(local_samples, axis_name)           # (k, B, s)
    k = gathered.shape[0]
    pool = jnp.moveaxis(gathered, 0, 1).reshape(B, k * s)

    # Step 5: replicated sort (local compute — free in the k-machine model),
    # radius = element at (1-based) index 21 log l, clamped to the pool.
    pool_sorted = jnp.sort(pool, axis=-1)
    r = pool_sorted[:, min(r_idx, k * s) - 1]                     # (B,)

    # Step 7: survivors are finite points within radius r.
    finite = jnp.isfinite(d)
    pruned = finite & (d <= r[..., None])

    # Verification psum (our Las Vegas hardening): the prune may only be
    # applied if at least l elements survive globally, otherwise the true
    # l-NN set could have been cut.
    l_arr = jnp.broadcast_to(jnp.asarray(l, jnp.int32), (B,))
    local_cnts = jnp.stack(
        [jnp.sum(pruned.astype(jnp.int32), axis=-1),
         jnp.sum(finite.astype(jnp.int32), axis=-1)], axis=-1)
    cnts = lax.psum(local_cnts, axis_name)                        # (B, 2)
    cnt, finite_cnt = cnts[..., 0], cnts[..., 1]
    ok = cnt >= l_arr

    valid = jnp.where(ok[..., None], pruned, finite)
    survivors = jnp.where(ok, cnt, finite_cnt)
    from repro.parallel.collectives import replicate
    radius = replicate(jnp.where(ok, r, jnp.inf), axis_name)
    return PruneResult(valid=valid, radius=radius, survivors=survivors,
                       applied=ok)
