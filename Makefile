# Local fallback for the CI entrypoints (.github/workflows/ci.yml).
PYTHONPATH_PREFIX := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-cov test-threads deps bench bench-serve smoke-artifacts \
	bench-smoke obs-smoke perf-history examples

# Shared smoke artifacts (one bench_serve --smoke run feeds BOTH CI
# gates below).
SMOKE_BENCH := /tmp/BENCH_serve_smoke.json
SMOKE_TRACE := /tmp/BENCH_trace_smoke.jsonl
SMOKE_PROM  := /tmp/BENCH_prom_smoke.txt

deps:
	pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHONPATH_PREFIX) python -m pytest -x -q

# coverage gate for the query-path packages (ci.yml coverage job):
# store (mutable/compaction/summaries/placement/adaptive — and, since
# ISSUE 8, the in-shard bucket index in store/index.py, exercised by
# tests/test_index.py) and core (Algorithms 1 & 2) must stay above the
# floor so the routing, placement, adaptive-maintenance, and approx-
# index paths can't silently rot untested.  The job runs the full
# suite, so the ISSUE-8 regression tests ride in it too: the
# empty-histogram snapshot oracle (tests/test_obs.py) and the
# shards_touched=-1 sentinel guards (tests/test_knn_server.py).
# repro.obs joined the gate with ISSUE 9: the operator layer (explain
# reports, the SLO burn-rate engine, the Prometheus/OTLP exporters) is
# pure-python control logic whose failure modes are exactly the kind a
# coverage floor catches.
test-cov:
	$(PYTHONPATH_PREFIX) python -m pytest -q \
		--cov=repro.store --cov=repro.core --cov=repro.obs \
		--cov-report=term-missing --cov-fail-under=85

# thread-sanity gate (ci.yml thread-sanity job): the concurrency suites
# — background-maintenance harness (including the racing search="approx"
# recall-floor race), stop()-drain contract, ServerStats hammer,
# device-routing parity — run 3x under a faulthandler timeout,
# so a rare-interleaving deadlock dumps every thread's stack instead of
# hanging CI silently.
test-threads:
	for i in 1 2 3; do \
		$(PYTHONPATH_PREFIX) python -m pytest -q \
			-o faulthandler_timeout=300 \
			tests/test_async_maintenance.py tests/test_knn_server.py \
			tests/test_routing.py || exit 1; \
	done

bench:
	$(PYTHONPATH_PREFIX):. python -m benchmarks.run

bench-serve:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py

# The single serve-smoke run both gates below validate.  bench-smoke
# and obs-smoke used to run *identical* bench_serve --smoke invocations
# back to back (~2x the CI minutes for zero extra signal); the run now
# happens once here, emitting every artifact either gate needs — the
# JSON report, the flight-recorder trace, the HTTP-fetched Prometheus
# text — and appending the run's summary row to the tracked perf
# ledger.  `make bench-smoke obs-smoke` in one invocation runs it once.
smoke-artifacts:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py --smoke \
		--out $(SMOKE_BENCH) \
		--trace-out $(SMOKE_TRACE) \
		--prom-out $(SMOKE_PROM) \
		--history BENCH_history.jsonl

# CI dry-run: tiny-size bench_serve + bench_ingest end to end, JSON to /tmp —
# proves the benchmark scripts can't silently rot (ci.yml smoke step).
# bench_serve's placement section exercises placement="affinity" +
# redeal="proximity" (store/placement.py) in smoke mode too, so the
# locality-aware write path and the Lloyd re-deal run in CI on every push;
# its adaptive section drives the drifting-cluster store with
# summary_pivots=2 and hard-asserts one forced re-tighten and one forced
# split on a tiny store (store/adaptive.py), so both maintenance
# triggers fire in CI on every push.  bench_ingest's under_ingest
# section is the quiet-vs-ingest serve-latency A/B over a
# maintenance="background" store with device-side routing — it
# hard-asserts that a background re-tighten AND split fired mid-run.
# bench_serve's index section runs the search="approx" A/B on the
# clustered and drifting workloads with the recall floor and the 3x
# candidate-reduction target hard-asserted inline (store/index.py).
# The bench-regression sentinel rides here too (ISSUE 9): check_perf
# first proves its own bounds on a synthetic ledger (--self-test, where
# an injected 2x p99 regression must FAIL), then judges the smoke run
# against the rolling baseline in the tracked BENCH_history.jsonl.
bench-smoke: smoke-artifacts
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_ingest.py --smoke \
		--out /tmp/BENCH_ingest_smoke.json
	$(PYTHONPATH_PREFIX):. python benchmarks/check_perf.py --self-test
	$(PYTHONPATH_PREFIX):. python benchmarks/check_perf.py \
		--report $(SMOKE_BENCH) --history BENCH_history.jsonl

# Observability gate (ci.yml smoke step): validate the shared smoke
# artifacts — zero Theorem-1 contract violations, zero shadow-exact
# divergences (with both auditors demonstrably active), the approx
# recall floor + 3x reduction, a well-formed span export containing a
# complete routed-query tree racing a committed maintenance cycle, and
# (ISSUE 9) the operator layer: a well-formed query-explain report
# whose kept-bucket set matches the recomputed keep rule, the
# forced-breach SLO fired AND cleared (slo.* spans in the trace), the
# Prometheus exposition parsing under the strict round-trip parser,
# and (ISSUE 10) the label-prediction contract: exact arm
# oracle-identical, ensemble arms holding the accuracy floor at
# messages == shards_touched with a clean accuracy-mode shadow audit.
obs-smoke: smoke-artifacts
	$(PYTHONPATH_PREFIX):. python benchmarks/check_obs.py \
		--bench $(SMOKE_BENCH) \
		--trace $(SMOKE_TRACE) \
		--prom $(SMOKE_PROM)

# Full-size perf row: run the real bench_serve, append its summary row
# to the tracked ledger, and judge it against the rolling full-size
# baseline.  Run before cutting a release commit; commit the ledger.
perf-history:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py \
		--out BENCH_serve.json --trace-out BENCH_trace.jsonl \
		--prom-out BENCH_prom.txt --history BENCH_history.jsonl
	$(PYTHONPATH_PREFIX):. python benchmarks/check_perf.py \
		--report BENCH_serve.json --history BENCH_history.jsonl

examples:
	$(PYTHONPATH_PREFIX) python examples/quickstart.py
	$(PYTHONPATH_PREFIX) python examples/knn_lm_serve.py
