# Local fallback for the CI entrypoints (.github/workflows/ci.yml).
PYTHONPATH_PREFIX := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test deps bench bench-serve examples

deps:
	pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHONPATH_PREFIX) python -m pytest -x -q

bench:
	$(PYTHONPATH_PREFIX):. python -m benchmarks.run

bench-serve:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py

examples:
	$(PYTHONPATH_PREFIX) python examples/quickstart.py
	$(PYTHONPATH_PREFIX) python examples/knn_lm_serve.py
