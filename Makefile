# Local fallback for the CI entrypoints (.github/workflows/ci.yml).
PYTHONPATH_PREFIX := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-cov test-threads deps bench bench-serve bench-smoke \
	obs-smoke examples

deps:
	pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHONPATH_PREFIX) python -m pytest -x -q

# coverage gate for the query-path packages (ci.yml coverage job):
# store (mutable/compaction/summaries/placement/adaptive — and, since
# ISSUE 8, the in-shard bucket index in store/index.py, exercised by
# tests/test_index.py) and core (Algorithms 1 & 2) must stay above the
# floor so the routing, placement, adaptive-maintenance, and approx-
# index paths can't silently rot untested.  The job runs the full
# suite, so the ISSUE-8 regression tests ride in it too: the
# empty-histogram snapshot oracle (tests/test_obs.py) and the
# shards_touched=-1 sentinel guards (tests/test_knn_server.py).
test-cov:
	$(PYTHONPATH_PREFIX) python -m pytest -q \
		--cov=repro.store --cov=repro.core \
		--cov-report=term-missing --cov-fail-under=85

# thread-sanity gate (ci.yml thread-sanity job): the concurrency suites
# — background-maintenance harness (including the racing search="approx"
# recall-floor race), stop()-drain contract, ServerStats hammer,
# device-routing parity — run 3x under a faulthandler timeout,
# so a rare-interleaving deadlock dumps every thread's stack instead of
# hanging CI silently.
test-threads:
	for i in 1 2 3; do \
		$(PYTHONPATH_PREFIX) python -m pytest -q \
			-o faulthandler_timeout=300 \
			tests/test_async_maintenance.py tests/test_knn_server.py \
			tests/test_routing.py || exit 1; \
	done

bench:
	$(PYTHONPATH_PREFIX):. python -m benchmarks.run

bench-serve:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py

# CI dry-run: tiny-size bench_serve + bench_ingest end to end, JSON to /tmp —
# proves the benchmark scripts can't silently rot (ci.yml bench-smoke step).
# bench_serve's placement section exercises placement="affinity" +
# redeal="proximity" (store/placement.py) in smoke mode too, so the
# locality-aware write path and the Lloyd re-deal run in CI on every push;
# its adaptive section drives the drifting-cluster store with
# summary_pivots=2 and hard-asserts one forced re-tighten and one forced
# split on a tiny store (store/adaptive.py), so both maintenance
# triggers fire in CI on every push.  bench_ingest's under_ingest
# section is the quiet-vs-ingest serve-latency A/B over a
# maintenance="background" store with device-side routing — it
# hard-asserts that a background re-tighten AND split fired mid-run.
# bench_serve's index section runs the search="approx" A/B on the
# clustered and drifting workloads with the recall floor and the 3x
# candidate-reduction target hard-asserted inline (store/index.py),
# then check_obs.py re-asserts the contract from the JSON artifact —
# a recall-floor violation fails this target on every push.
bench-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py --smoke \
		--out /tmp/BENCH_serve_smoke.json \
		--trace-out /tmp/BENCH_trace_smoke.jsonl
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_ingest.py --smoke \
		--out /tmp/BENCH_ingest_smoke.json
	$(PYTHONPATH_PREFIX):. python benchmarks/check_obs.py \
		--bench /tmp/BENCH_serve_smoke.json \
		--trace /tmp/BENCH_trace_smoke.jsonl

# Observability gate (ci.yml obs-smoke step): run the smoke bench with
# the flight recorder + both auditors on, then validate the artifacts —
# zero Theorem-1 contract violations, zero shadow-exact divergences
# (with both auditors demonstrably active), and a well-formed span
# export containing a complete routed-query tree racing a committed
# maintenance cycle (benchmarks/check_obs.py); check_obs also re-asserts
# the index section's search="approx" recall floor + 3x reduction.
obs-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PYTHONPATH_PREFIX):. python benchmarks/bench_serve.py --smoke \
		--out /tmp/BENCH_serve_smoke.json \
		--trace-out /tmp/BENCH_trace_smoke.jsonl
	$(PYTHONPATH_PREFIX):. python benchmarks/check_obs.py \
		--bench /tmp/BENCH_serve_smoke.json \
		--trace /tmp/BENCH_trace_smoke.jsonl

examples:
	$(PYTHONPATH_PREFIX) python examples/quickstart.py
	$(PYTHONPATH_PREFIX) python examples/knn_lm_serve.py
