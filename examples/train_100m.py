"""End-to-end training driver: a ~100M-parameter qwen2-family model for a
few hundred steps on synthetic data, with checkpointing and restart.

Full-size run (CPU-feasible in minutes with --steps trimmed):
  PYTHONPATH=src python examples/train_100m.py --steps 200

The model is qwen2-0.5b narrowed to ~100M params (12 layers, d=512,
vocab 32768) — family-faithful: GQA + QKV bias + tied embeddings.
"""

import argparse
import dataclasses
import tempfile

import jax
import numpy as np

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import MarkovTokens
from repro.models import build_model
from repro.optim import AdamW
from repro.runtime import (MetricLogger, TrainConfig, init_opt_state,
                           train_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config for smoke runs")
    args = ap.parse_args()

    cfg = configs.get("qwen2-0.5b")
    if args.tiny:
        cfg = cfg.reduced()
    else:
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
            head_dim=64, d_ff=2048, vocab=32768)
    api = build_model(cfg)
    n = cfg.param_count()
    print(f"{cfg.name} variant: {n/1e6:.0f}M params")

    params = api.init_params(jax.random.PRNGKey(0))
    tcfg = TrainConfig(grad_accum=2, peak_lr=1e-3,
                       warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    optimizer = AdamW()
    opt_state = init_opt_state(api, tcfg, optimizer, params)
    data = MarkovTokens(cfg.vocab, seed=0, branch=4, n_contexts=257)

    def make_batch(step):
        t, l = data.batch(step, args.batch, args.seq)
        return {"tokens": t, "labels": l}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2)
        logger = MetricLogger(quiet=True)
        params, opt_state, step = train_loop(
            api=api, tcfg=tcfg, optimizer=optimizer, params=params,
            opt_state=opt_state, make_batch=make_batch,
            num_steps=args.steps, ckpt_manager=mgr, ckpt_every=50,
            logger=logger)
        losses = [r["loss"] for r in logger.history if "loss" in r]
        print(f"steps={step} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"(entropy floor ~{data.entropy_floor:.3f})")
        print(f"checkpoints kept: {mgr.all_steps()}")
        assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
