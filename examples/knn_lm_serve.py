"""kNN-LM serving: a reduced LM decodes with Algorithm-2 retrieval mixed
into its vocab distribution — the paper's l-NN as a production serving
feature (DESIGN.md Section 3).

The datastore is sharded over the mesh's model axis; each decode step:
  1. LM decode_step produces vocab-sharded logits;
  2. the last hidden state queries the datastore via Algorithm 2
     (local top-l -> sample-prune -> distributed selection);
  3. the sparse kNN mass is scattered into the sharded logits;
  4. the token is drawn by the distributed-selection top-k sampler.

  PYTHONPATH=src python examples/knn_lm_serve.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
import repro.core as core
from repro.models import build_model
from repro.models import sharding as shd
from repro.models.layers import embed

L = 8          # neighbors per step
LAM = 0.35     # kNN interpolation weight
STEPS = 12


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = configs.get("qwen2-0.5b").reduced()
    api = build_model(cfg)
    rng = np.random.default_rng(0)

    # synthetic datastore: (hidden-state key, next-token value) pairs
    N = 2 * 4096
    ds_keys = rng.normal(size=(N, cfg.d_model)).astype(np.float32)
    ds_vals = rng.integers(0, cfg.vocab, size=(N,)).astype(np.int32)

    with jax.set_mesh(mesh):
        params = api.init_params(jax.random.PRNGKey(0))
        specs = api.param_specs()
        params = jax.tree.map(
            lambda x, s: jax.device_put(
                x, NamedSharding(mesh, shd.divisible(s, x.shape, mesh))),
            params, specs)

        B = 4
        prompt = rng.integers(0, cfg.vocab, (B, 8)).astype(np.int32)
        cache = api.init_cache(jax.random.PRNGKey(1), B, 64,
                               dtype=jnp.float32)
        logits, cache = jax.jit(
            lambda p, b, c: api.prefill(p, b, c))(
                params, {"tokens": prompt}, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

        def knn_mixed_step(params, tok, cache, dsk, dsv, key):
            lm_logits, cache = api.decode_step(params, tok, cache)
            # query = current token embedding (stand-in for the hidden
            # state; a production deployment taps the pre-unembed state)
            h = embed(params["embed"], tok[:, None])[:, 0]

            def retrieve_and_mix(lml, kk, vv, hh, key):
                store = core.datastore.build_local(kk, vv,
                                                   axis_name="model")
                ret = core.datastore.retrieve(store, hh, L, key,
                                              axis_name="model")
                mixed = core.datastore.interp_logits(lml, ret, LAM,
                                                     axis_name="model")
                nxt = core.topk_sample(mixed, 16, 0.8,
                                       jax.random.fold_in(key, 1),
                                       axis_name="model")
                return nxt, ret.iterations

            nxt, iters = jax.shard_map(
                retrieve_and_mix, mesh=mesh,
                in_specs=(P(None, "model"), P("model"), P("model"),
                          P(None), P(None)),
                out_specs=(P(None), P()), check_vma=False,
            )(lm_logits, dsk, dsv, h, key)
            return nxt.astype(jnp.int32), cache, iters

        step = jax.jit(knn_mixed_step)
        out = [np.asarray(tok)]
        for i in range(STEPS):
            tok, cache, iters = step(params, tok, cache, ds_keys, ds_vals,
                                     jax.random.PRNGKey(100 + i))
            out.append(np.asarray(tok))
        gen = np.stack(out, 1)

    print(f"kNN-LM decode with lam={LAM}, l={L} over a {N}-key sharded "
          f"datastore; last retrieval took {int(iters)} selection rounds")
    print("generated token ids:")
    print(gen)


if __name__ == "__main__":
    main()
