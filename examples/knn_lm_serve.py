"""kNN-LM serving through the micro-batched query service.

A reduced LM decodes while a KnnServer fronts the sharded (hidden-state
key, next-token value) datastore (DESIGN.md Section 3).  Each decode step:

  1. LM decode_step produces vocab-sharded logits;
  2. the per-sequence query states are *submitted* to the service, whose
     micro-batcher coalesces them into one padded device batch and runs
     Algorithm 2 (local top-l -> sample-prune -> distributed selection);
  3. winners come back as (token value, distance) per request — values are
     looked up host-side from the global ids, so only distances and ids
     ever crossed the interconnect;
  4. the sparse kNN mass is scattered into the sharded logits on device
     (interp_logits) and the token is drawn by the distributed-selection
     top-k sampler.

This is the production decomposition: the LM mesh and the datastore mesh
are independent services, coupled only by (query vector in, l winners out)
— the datastore can scale, re-shard, or A/B its sampler (see
configs/knn_service.py) without touching the LM.

  PYTHONPATH=src python examples/knn_lm_serve.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
import repro.core as core
from repro.configs.knn_service import CONFIG as KNN_CONFIG
from repro.models import build_model
from repro.models import sharding as shd
from repro.models.layers import embed
from repro.parallel.compat import make_mesh, set_mesh, shard_map
from repro.runtime import KnnServer

L = 8          # neighbors per step
LAM = 0.35     # kNN interpolation weight
TEMP = 10.0    # kNN softmax temperature
STEPS = 12
B = 4          # decode batch = requests per service flush


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = configs.get("qwen2-0.5b").reduced()
    api = build_model(cfg)
    rng = np.random.default_rng(0)

    # synthetic datastore: (hidden-state key, next-token value) pairs,
    # sharded over all 8 host devices by the service's own 1-D mesh.
    N = 2 * 4096
    ds_keys = rng.normal(size=(N, cfg.d_model)).astype(np.float32)
    ds_vals = rng.integers(0, cfg.vocab, size=(N,)).astype(np.int32)
    server = KnnServer(
        ds_keys, ds_vals,
        cfg=KNN_CONFIG.replace(dim=cfg.d_model, l=L, l_max=L,
                               bucket_sizes=(1, 2, B)),
        axis_name="store")
    server.warmup()

    with set_mesh(mesh):
        params = api.init_params(jax.random.PRNGKey(0))
        specs = api.param_specs()
        params = jax.tree.map(
            lambda x, s: jax.device_put(
                x, NamedSharding(mesh, shd.divisible(s, x.shape, mesh))),
            params, specs)

        prompt = rng.integers(0, cfg.vocab, (B, 8)).astype(np.int32)
        cache = api.init_cache(jax.random.PRNGKey(1), B, 64,
                               dtype=jnp.float32)
        logits, cache = jax.jit(
            lambda p, b, c: api.prefill(p, b, c))(
                params, {"tokens": prompt}, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

        def decode_logits(params, tok, cache):
            lm_logits, cache = api.decode_step(params, tok, cache)
            # query = current token embedding (stand-in for the hidden
            # state; a production deployment taps the pre-unembed state)
            h = embed(params["embed"], tok[:, None])[:, 0]
            return lm_logits, cache, h

        def mix_and_sample(lml, toks, wts, key):
            ret = core.datastore.RetrievalResult(
                tokens=toks, weights=wts, dists=jnp.zeros_like(wts),
                iterations=jnp.int32(0))
            mixed = core.datastore.interp_logits(lml, ret, LAM,
                                                 axis_name="model")
            return core.topk_sample(mixed, 16, 0.8, key, axis_name="model")

        step_logits = jax.jit(decode_logits)
        step_mix = jax.jit(shard_map(
            mix_and_sample, mesh=mesh,
            in_specs=(P(None, "model"), P(None), P(None), P(None)),
            out_specs=P(None), check_vma=False))

        out = [np.asarray(tok)]
        iters = 0
        with server.serving():
            for i in range(STEPS):
                lm_logits, cache, h = step_logits(params, tok, cache)
                # one service request per sequence; the micro-batcher
                # coalesces all B into one bucketed device batch
                futs = [server.submit(np.asarray(h)[b], L)
                        for b in range(B)]
                res = [f.result(timeout=60) for f in futs]
                iters = res[0].iterations
                toks = np.stack([np.where(r.values < 0, 0, r.values)
                                 for r in res]).astype(np.int32)
                logit = np.where(np.isfinite([r.dists for r in res]),
                                 -np.stack([r.dists for r in res]) / TEMP,
                                 -np.inf).astype(np.float32)
                wts = jax.nn.softmax(jnp.asarray(logit), axis=-1)
                tok = step_mix(lm_logits, jnp.asarray(toks), wts,
                               jax.random.PRNGKey(100 + i)).astype(jnp.int32)
                out.append(np.asarray(tok))
        gen = np.stack(out, 1)

    print(f"kNN-LM decode with lam={LAM}, l={L} over a {N}-key datastore "
          f"served by the micro-batched query service "
          f"({server.stats.batches} batches for "
          f"{server.stats.queries} retrievals; last retrieval took "
          f"{iters} selection rounds)")
    print("generated token ids:")
    print(gen)


if __name__ == "__main__":
    main()
