"""Demo: the paper's selection as a vocab-top-k sampler, vs the gather
baseline — the production Figure-2 comparison.

Sweeps k_sel and prints wall time + wire-byte model for both methods over
a 152k vocab sharded across 8 simulated machines.

  PYTHONPATH=src python examples/distributed_topk_demo.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.parallel.compat import make_mesh, shard_map

K = 8
V = K * 19008      # ~152k, qwen-sized
B = 16


def main():
    mesh = make_mesh((K,), ("model",))
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(B, V)).astype(np.float32)

    print(f"vocab {V} sharded over {K} machines, batch {B}")
    print(f"{'k':>6} {'method':>10} {'wall_ms':>9} {'wire_bytes':>11} "
          f"{'rounds':>7}")
    for ksel in (8, 64, 256):
        for method in ("selection", "gather"):
            def fn(lg, key):
                r = core.distributed_topk(lg, ksel, key,
                                          axis_name="model",
                                          method=method)
                return r.values, r.iterations

            f = jax.jit(shard_map(
                fn, mesh=mesh, in_specs=(P(None, "model"), P(None)),
                out_specs=(P(None), P())))
            key = jax.random.PRNGKey(0)
            f(logits, key)  # compile
            t0 = time.perf_counter()
            for _ in range(10):
                vals, iters = f(logits, key)
            jax.block_until_ready(vals)
            dt = (time.perf_counter() - t0) / 10
            wire = (K * ksel * 8 * B if method == "gather" else
                    float(iters) * K * 12 * B + 2 * ksel * 4 * B)
            print(f"{ksel:>6} {method:>10} {dt*1e3:>9.2f} {wire:>11.0f} "
                  f"{float(iters):>7.0f}")
    print("\nselection moves O(k log l) scalars/query vs gather's O(k*l);"
          "\non real ICI the byte gap is the paper's Figure-2 speedup.")


if __name__ == "__main__":
    main()
