"""Quickstart: the paper's distributed l-NN over a sharded point set.

Runs Algorithm 2 end to end on simulated k machines (host devices), checks
the answer against brute force, and prints the round/message telemetry the
paper's theorems bound.

  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as core

K = 8          # machines
N = K * 4096   # points
DIM = 32
L = 16         # neighbors


def main():
    mesh = jax.make_mesh((K,), ("machines",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    points = rng.normal(size=(N, DIM)).astype(np.float32)
    point_ids = np.arange(N, dtype=np.int32)
    queries = rng.normal(size=(4, DIM)).astype(np.float32)

    def knn(points, ids, q, key):
        res = core.knn_query(points, ids, q, L, key, axis_name="machines")
        return res.dists, res.ids, res.selection.iterations, \
            res.prune.survivors

    f = jax.jit(jax.shard_map(
        knn, mesh=mesh,
        in_specs=(P("machines"), P("machines"), P(None), P(None)),
        out_specs=(P(None), P(None), P(), P(None))))

    dists, ids, iters, survivors = f(points, point_ids, queries,
                                     jax.random.PRNGKey(0))

    print(f"{N} points on {K} machines, {L}-NN for {len(queries)} queries")
    print(f"selection iterations: {int(iters)} "
          f"(Theorem 2.4 bound ~ O(log l), l = {L})")
    print(f"post-prune candidates: {np.asarray(survivors)} "
          f"(Lemma 2.3 bound {11 * L})")

    # verify against brute force
    full = ((queries[:, None, :] - points[None]) ** 2).sum(-1)
    for b in range(len(queries)):
        want = np.sort(full[b])[:L]
        got = np.sort(np.asarray(dists)[b])
        np.testing.assert_allclose(got, want, rtol=1e-4)
    print("matches brute force on all queries — OK")


if __name__ == "__main__":
    main()
