"""Quickstart: the paper's distributed l-NN served through the query service.

Builds a KnnServer over a point set sharded across simulated k machines
(host devices), submits a handful of requests — each with its *own*
neighbor count l — lets the micro-batcher coalesce them into one padded
device batch, and checks every answer against brute force.  The printed
telemetry is the paper's theorem accounting: Algorithm 1 iterations
(Theorem 2.4: O(log l), k-independent), k-machine rounds/messages, and the
Lemma 2.3 post-prune survivor counts.

  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.configs.knn_service import CONFIG
from repro.runtime import KnnServer

K = 8          # machines
N = K * 4096   # points
DIM = 32
L_MAX = 32     # shared static bound; requests pick any l <= L_MAX


def main():
    rng = np.random.default_rng(0)
    points = rng.normal(size=(N, DIM)).astype(np.float32)

    cfg = CONFIG.replace(dim=DIM, l=16, l_max=L_MAX,
                         bucket_sizes=(1, 2, 4, 8))
    server = KnnServer(points, cfg=cfg, axis_name="machines")
    server.warmup()

    queries = rng.normal(size=(5, DIM)).astype(np.float32)
    ls = [16, 1, 32, 7, 16]            # heterogeneous per-request l
    results = server.query_batch(queries, ls)

    print(f"{N} points on {K} machines; {len(queries)} requests "
          f"micro-batched into {server.stats.batches} device batch(es) "
          f"(bucket counts {server.stats.bucket_counts}, "
          f"{server.stats.padded_rows} padded rows)")
    r0 = results[0]
    print(f"selection iterations: {r0.iterations} "
          f"(Theorem 2.4 bound ~ O(log l), l_max = {L_MAX})")
    print(f"k-machine cost of the batch: {r0.rounds} rounds, "
          f"{r0.messages} O(1)-word messages")
    print(f"post-prune candidates: {[r.survivors for r in results]} "
          f"(Lemma 2.3 bound {11 * L_MAX})")

    # verify every request against brute force
    full = ((queries[:, None, :] - points[None]) ** 2).sum(-1)
    for r, row in zip(results, full):
        want = np.sort(row)[:r.l]
        np.testing.assert_allclose(np.sort(r.dists), want, rtol=1e-4)
    print("all requests match brute force — OK")


if __name__ == "__main__":
    main()
