"""Streaming ingest: a mutable kNN store serving while it changes.

The paper's Algorithm 2 assumes a static point set; production stores
don't get that luxury.  This demo drives the mutable sharded store
(``store.MutableStore``, DESIGN.md Section 7) through its whole
lifecycle under a live server:

  1. stream inserts in staged batches (write-ahead buffer -> one device
     scatter -> epoch swap; watch the generation counter climb),
  2. query mid-stream — answers report the generation they ran against,
  3. delete points and verify tombstones never surface in answers,
  4. skew the shards until the compaction trigger fires, and watch the
     repack rebalance them without changing a single answer,
  5. run queries *concurrently* with an ingest thread: every request
     resolves (epoch swaps drop nothing), spanning many generations.

  PYTHONPATH=src python examples/streaming_ingest.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import threading

import numpy as np

from repro.configs.knn_service import CONFIG
from repro.runtime import KnnServer
from repro.store import MutableStore

K = 8            # machines (simulated as host devices)
CAP = 512        # slots per shard — fixes all compiled shapes forever
DIM = 16
L = 8


def brute_ids(store, q, l):
    ids, pts = store.live_arrays()
    if not len(ids):
        return set()
    d = ((q[None] - pts) ** 2).sum(-1)
    return set(ids[np.argsort(d, kind="stable")[:l]].tolist())


def main():
    rng = np.random.default_rng(0)
    cfg = CONFIG.replace(dim=DIM, l=L, l_max=32, bucket_sizes=(1, 2, 4, 8),
                         store_capacity_per_shard=CAP,
                         store_compact_imbalance_frac=0.25)
    store = MutableStore(DIM,
                         capacity_per_shard=cfg.store_capacity_per_shard,
                         axis_name="machines",
                         staging_size=cfg.store_staging_size,
                         compact_tombstone_frac=cfg.store_compact_tombstone_frac,
                         compact_imbalance_frac=cfg.store_compact_imbalance_frac)
    server = KnnServer(store=store, cfg=cfg)
    server.warmup()
    q = rng.normal(size=DIM).astype(np.float32)

    # -- 1. streaming inserts -------------------------------------------
    print(f"capacity {store.total} slots ({K} shards x {CAP}); "
          f"generation {store.generation}, live {store.live_count}")
    all_ids = []
    for batch in range(4):
        ids = store.insert(rng.normal(size=(300, DIM)).astype(np.float32))
        all_ids.extend(ids.tolist())
        gen = store.flush()
        print(f"  batch {batch}: +300 points -> generation {gen}, "
              f"live {store.live_count}")

    # -- 2. query mid-stream --------------------------------------------
    res = server.query_batch(q[None], [L])[0]
    assert set(res.ids.tolist()) == brute_ids(store, q, L)
    print(f"query @ generation {res.generation}: "
          f"nearest ids {sorted(res.ids.tolist())} (matches brute force)")

    # -- 3. deletes: tombstones never surface ---------------------------
    victims = set(res.ids[:3].tolist())
    store.delete(sorted(victims))
    gen = store.flush()
    res = server.query_batch(q[None], [L])[0]
    assert not (set(res.ids.tolist()) & victims)
    assert set(res.ids.tolist()) == brute_ids(store, q, L)
    print(f"deleted {sorted(victims)} -> generation {gen}; new answer "
          f"excludes them and matches brute force")

    # -- 4. skew the shards until compaction rebalances -----------------
    ids, _ = store.live_arrays()
    store.delete(ids[: len(ids) // 2])          # concentrated deletes skew
    store.flush()
    s = store.stats
    print(f"compactions so far: {s.compactions} "
          f"(last reason: {s.last_compact_reason})")
    res = server.query_batch(q[None], [L])[0]
    assert set(res.ids.tolist()) == brute_ids(store, q, L)
    print(f"post-compaction answer still matches brute force "
          f"(generation {res.generation})")

    # -- 5. queries under concurrent ingest -----------------------------
    stop = threading.Event()

    def ingest():
        # net-zero churn (delete everything inserted): two epoch swaps per
        # cycle, and the stream can never fill the store no matter how
        # long the foreground queries take
        r = np.random.default_rng(1)
        while not stop.is_set():
            ids = store.insert(r.normal(size=(64, DIM)).astype(np.float32))
            store.flush()
            store.delete(ids)
            store.flush()

    t = threading.Thread(target=ingest, daemon=True)
    gens = []
    with server.serving():
        t.start()
        futures = [server.submit(rng.normal(size=DIM).astype(np.float32), L)
                   for _ in range(32)]
        for f in futures:
            gens.append(f.result(timeout=60).generation)
        stop.set()
        t.join()
    print(f"32/32 concurrent queries resolved while ingest ran; "
          f"generations spanned {min(gens)}..{max(gens)} "
          f"(zero dropped by {max(gens) - min(gens)} epoch swaps)")
    print(f"final: generation {store.generation}, live {store.live_count}, "
          f"stats {store.stats}")


if __name__ == "__main__":
    main()
