"""Streaming ingest: a mutable kNN store serving while it changes.

The paper's Algorithm 2 assumes a static point set; production stores
don't get that luxury.  This demo drives the mutable sharded store
(``store.MutableStore``, DESIGN.md Section 7) through its whole
lifecycle under a live server — with locality-aware placement
(``placement="affinity"`` + ``redeal="proximity"``, Section 9) so
pruned routing (Section 8) pays on the mutable store too:

  1. stream a *clustered* insert mix in staged batches (write-ahead
     buffer -> one device scatter -> epoch swap; affinity placement
     routes each point to its nearest live shard centroid),
  2. query mid-stream — answers report the generation they ran against
     and how many shards routing had to touch,
  3. delete points and verify tombstones never surface in answers,
  4. force a compaction: the proximity re-deal re-tightens the shard
     summaries, and the same queries now touch *fewer* shards — the
     locality win, shown end-to-end (shards_touched before vs after),
  5. run queries *concurrently* with an ingest thread: every request
     resolves (epoch swaps drop nothing), spanning many generations,
  6. finale (the operator layer, DESIGN.md §14): the declared latency
     SLO's burn-rate snapshot and one per-query explain report — why
     the last query touched the shards it touched, straight from
     ``QueryResult.explain()``.

  PYTHONPATH=src python examples/streaming_ingest.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import threading

import numpy as np

from repro.configs.knn_service import CONFIG
from repro.data import sharded_clusters
from repro.runtime import KnnServer
from repro.store import MutableStore

K = 8            # machines (simulated as host devices)
CAP = 512        # slots per shard — fixes all compiled shapes forever
DIM = 16
L = 8


def brute_ids(store, q, l):
    ids, pts = store.live_arrays()
    if not len(ids):
        return set()
    d = ((q[None] - pts) ** 2).sum(-1)
    return set(ids[np.argsort(d, kind="stable")[:l]].tolist())


def main():
    rng = np.random.default_rng(0)
    cfg = CONFIG.replace(dim=DIM, l=L, l_max=32, bucket_sizes=(1, 2, 4, 8),
                         store_capacity_per_shard=CAP,
                         store_compact_imbalance_frac=0.25,
                         route="pruned",            # summary-pruned routing
                         placement="affinity",      # locality-aware inserts
                         redeal="proximity",        # cluster-coherent repack
                         slo_latency_p99_s=0.5)     # a declared promise
    store = MutableStore(DIM, axis_name="machines", **cfg.store_kwargs())
    server = KnnServer(store=store, cfg=cfg)
    server.warmup()
    clusters, centers = sharded_clusters(K, 150, DIM, seed=2)
    stream = clusters[rng.permutation(len(clusters))]   # interleaved arrival
    q = (centers[3] + rng.normal(size=DIM)).astype(np.float32)

    # -- 1. streaming clustered inserts, affinity-placed -----------------
    print(f"capacity {store.total} slots ({K} shards x {CAP}); "
          f"placement={store.placement} redeal={store.redeal}")
    for batch in range(4):
        store.insert(stream[batch * 300:(batch + 1) * 300])
        gen = store.flush()
        print(f"  batch {batch}: +300 points -> generation {gen}, "
              f"live {store.live_count}")

    # -- 2. query mid-stream --------------------------------------------
    res = server.query_batch(q[None], [L])[0]
    assert set(res.ids.tolist()) == brute_ids(store, q, L)
    touched_before = res.shards_touched
    print(f"query @ generation {res.generation}: "
          f"nearest ids {sorted(res.ids.tolist())} (matches brute force), "
          f"shards touched {touched_before}/{K}")

    # -- 3. deletes: tombstones never surface ---------------------------
    victims = set(res.ids[:3].tolist())
    store.delete(sorted(victims))
    gen = store.flush()
    res = server.query_batch(q[None], [L])[0]
    assert not (set(res.ids.tolist()) & victims)
    assert set(res.ids.tolist()) == brute_ids(store, q, L)
    print(f"deleted {sorted(victims)} -> generation {gen}; new answer "
          f"excludes them and matches brute force")

    # -- 4. compaction: proximity re-deal tightens the routing ----------
    store.compact()
    res = server.query_batch(q[None], [L])[0]
    assert set(res.ids.tolist()) == brute_ids(store, q, L)
    print(f"compaction (reason: {store.stats.last_compact_reason}) "
          f"re-dealt by proximity -> generation {res.generation}; "
          f"same answer, shards touched {touched_before} -> "
          f"{res.shards_touched}")
    print(f"  live histogram {server.placement_stats()['live_per_shard']}, "
          f"prune rate so far "
          f"{server.placement_stats()['prune_rate']:.2f}")

    # -- 5. queries under concurrent ingest -----------------------------
    stop = threading.Event()

    def ingest():
        # net-zero churn (delete everything inserted): two epoch swaps per
        # cycle, and the stream can never fill the store no matter how
        # long the foreground queries take
        r = np.random.default_rng(1)
        while not stop.is_set():
            ids = store.insert(r.normal(size=(64, DIM)).astype(np.float32))
            store.flush()
            store.delete(ids)
            store.flush()

    t = threading.Thread(target=ingest, daemon=True)
    gens = []
    with server.serving():
        t.start()
        futures = [server.submit(rng.normal(size=DIM).astype(np.float32), L)
                   for _ in range(32)]
        for f in futures:
            gens.append(f.result(timeout=60).generation)
        stop.set()
        t.join()
    print(f"32/32 concurrent queries resolved while ingest ran; "
          f"generations spanned {min(gens)}..{max(gens)} "
          f"(zero dropped by {max(gens) - min(gens)} epoch swaps)")
    print(f"final: generation {store.generation}, live {store.live_count}, "
          f"stats {store.stats}")

    # -- 6. the operator layer: SLO burn rate + a query-explain report --
    slo = server.obs_snapshot()["slo"]
    lat = slo["objectives"]["latency_p99"]
    print(f"slo latency_p99 <= {lat['bound']}s: "
          f"burn fast/slow {lat['burn_fast']:.2f}/{lat['burn_slow']:.2f} "
          f"over {lat['slow_events']} requests, "
          f"{slo['alerts_fired']} alerts fired "
          f"({len(slo['firing'])} firing now)")
    rep = server.explain_last(1)[0]
    kept = rep["routing"]["kept_shards"]
    print(f"explain (last query, batch {rep['batch']['id']} @ generation "
          f"{rep['batch']['generation']}):")
    print(f"  routing [{rep['routing']['mode']}/"
          f"{rep['routing']['compute']}]: kept shards {kept} of {K} "
          f"(threshold_eff {rep['routing']['threshold_eff']:.1f})")
    for s in rep["routing"]["shards"]:
        mark = "KEEP " if s["kept"] else "prune"
        print(f"    shard {s['shard']}: {mark} lower {s['lower']:.1f} "
              f"upper {s['upper']:.1f}")
    print(f"  timings: queued {rep['timings']['queued_s'] * 1e3:.2f}ms, "
          f"kernel {rep['timings']['kernel_s'] * 1e3:.2f}ms, "
          f"total {rep['timings']['latency_s'] * 1e3:.2f}ms; "
          f"maintenance raced: {rep['maintenance']['raced_commit']}")


if __name__ == "__main__":
    main()
