"""Label-prediction property harness (src/repro/predict/, DESIGN.md §15).

The contracts under test:

* **Exact predict is the single-machine oracle, bit-for-bit** — on every
  route (exact/pruned) × route_compute (host/device) × search (exact,
  and approx with an unreachably large oversample target, which keeps
  every bucket and must stay bit-identical): the served label equals a
  numpy majority vote / mean over the true l nearest neighbors, and the
  label bytes agree across all modes.
* **The 1-shard ensemble degenerates to the exact vote** — local_k_for's
  auto split gives kl = l on one shard, so the one-message answer is
  bit-identical to the exact fold.
* **Ties are deterministic** — two independently constructed servers at
  the same key and generation produce identical label bytes, and a tied
  vote breaks toward the lowest class id, in both modes.
* **Tombstoned neighbors never vote** — deleting the nearest neighbor
  flips the vote in both exact and ensemble modes (the validity mask
  reaches the label path end-to-end), and labels survive compaction and
  proximity re-deals aligned with their points.
* **Racing ingest keeps the ensemble accuracy contract** — under
  concurrent inserts the accuracy-mode shadow audit (ensemble vs the
  exact fold at the *same generation*) never dips below the floor, and
  every answer's message bill is exactly its touched-shard count.
"""

import threading

import numpy as np
import pytest

from repro.configs.knn_service import KnnServiceConfig
from repro.data import bayes_labels, labeled_mixture
from repro.parallel.compat import make_mesh
from repro.runtime import KnnServer
from repro.store import MutableStore

K = 8
DIM = 8
N = 128                   # static-server point count (divides K)
NUM_CLASSES = 4
L_MAX = 16

BASE = KnnServiceConfig(
    bucket_sizes=(4,), l_max=L_MAX, num_classes=NUM_CLASSES,
    predict="vote", max_wait_ms=0.1)


def _instance(seed=0, n=N):
    pts, labels, centers = labeled_mixture(n, DIM, NUM_CLASSES,
                                           separation=6.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    qs = (centers[rng.integers(0, NUM_CLASSES, 4)]
          + rng.normal(size=(4, DIM))).astype(np.float32)
    return pts, labels.astype(np.float32), qs


def _oracle_vote(pts, labels, q, l):
    """Single-machine majority vote over the true l-NN (f64 distances,
    ties toward the lowest class — the repo-wide tie rule)."""
    d = ((q.astype(np.float64) - pts.astype(np.float64)) ** 2).sum(-1)
    top = np.argsort(d, kind="stable")[:l]
    hist = np.bincount(labels[top].astype(int), minlength=NUM_CLASSES)
    return float(hist.argmax()), hist


# ---- exact predict: the oracle matrix ------------------------------------

MATRIX = [
    dict(route="exact", route_compute="host", search="exact"),
    dict(route="pruned", route_compute="host", search="exact"),
    dict(route="pruned", route_compute="device", search="exact"),
    # approx with an unreachable oversample target keeps every bucket:
    # answers (and therefore votes) must stay bit-identical to exact.
    dict(route="exact", route_compute="host", search="approx",
         index_buckets=4, index_oversample=1e9),
    dict(route="pruned", route_compute="host", search="approx",
         index_buckets=4, index_oversample=1e9),
    dict(route="pruned", route_compute="device", search="approx",
         index_buckets=4, index_oversample=1e9),
]


@pytest.mark.parametrize("seed", [0, 3])
def test_exact_predict_matches_oracle_on_every_mode(seed):
    pts, labels, qs = _instance(seed)
    ls = [1, 5, L_MAX, 3]
    per_mode = []
    for knobs in MATRIX:
        srv = KnnServer(pts, labels=labels, cfg=BASE.replace(**knobs))
        res = srv.query_batch(qs, ls=ls)
        got = np.array([r.label for r in res], np.float32)
        for q, l, r in zip(qs, ls, res):
            want, hist = _oracle_vote(pts, labels, q, l)
            assert r.predict_mode == "exact"
            assert r.label == want, (knobs, l)
            assert r.confidence == pytest.approx(
                hist.max() / hist.sum())
        per_mode.append(got)
        srv.close()
    for got in per_mode[1:]:
        assert got.tobytes() == per_mode[0].tobytes()


def test_exact_regress_matches_oracle():
    pts, labels, qs = _instance(7)
    srv = KnnServer(pts, labels=labels, cfg=BASE.replace(predict="regress"))
    for q, r in zip(qs, srv.query_batch(qs, ls=[5] * 4)):
        d = ((q.astype(np.float64) - pts.astype(np.float64)) ** 2).sum(-1)
        top = np.argsort(d, kind="stable")[:5]
        assert r.label == pytest.approx(
            labels[top].astype(np.float32).mean(), rel=1e-6)
        assert r.confidence == pytest.approx(1.0)
    srv.close()


# ---- ensemble: degenerate case, bill, determinism ------------------------

def test_one_shard_ensemble_is_bitwise_exact_vote():
    """kl = ceil(l / 1) = l on a single shard: the one-message local
    vote IS the global vote, so the two modes must agree to the byte."""
    pts, labels, qs = _instance(2, n=64)
    mesh = make_mesh((1,), ("knn",))
    exact = KnnServer(pts, labels=labels, mesh=mesh, cfg=BASE)
    ens = KnnServer(pts, labels=labels, mesh=mesh,
                    cfg=BASE.replace(predict_mode="ensemble"))
    ls = [1, 4, 9, L_MAX]
    le = np.array([r.label for r in exact.query_batch(qs, ls=ls)],
                  np.float32)
    lo = np.array([r.label for r in ens.query_batch(qs, ls=ls)],
                  np.float32)
    assert le.tobytes() == lo.tobytes()
    exact.close()
    ens.close()


def test_ensemble_message_bill_is_touched_shards():
    pts, labels, qs = _instance(4)
    srv = KnnServer(pts, labels=labels,
                    cfg=BASE.replace(predict_mode="ensemble"))
    for r in srv.query_batch(qs, ls=[3, 8, 1, L_MAX]):
        assert r.predict_mode == "ensemble"
        assert r.rounds == 1
        assert r.messages == r.shards_touched == K
        # no point identity ever leaves its shard
        assert (r.ids == 2**31 - 1).all()
        assert np.isinf(r.dists).all()
    srv.close()


def _tie_instance():
    """A query whose l=4 neighborhood votes 2:2 between classes 1 and 3
    (far label-0 filler beyond l keeps n divisible by the mesh)."""
    pts = np.zeros((16, DIM), np.float32)
    pts[0, 0], pts[1, 0] = 1.0, -1.0
    pts[2, 1], pts[3, 1] = 1.0, -1.0
    pts[4:] = 100.0 + np.arange(12)[:, None]
    labels = np.zeros(16, np.float32)
    labels[[0, 2]] = 3.0
    labels[[1, 3]] = 1.0
    q = np.zeros(DIM, np.float32)
    return pts, labels, q


@pytest.mark.parametrize("mode", ["exact", "ensemble"])
def test_tied_votes_are_deterministic_across_fresh_servers(mode):
    pts, labels, q = _tie_instance()
    cfg = BASE.replace(predict_mode=mode)
    got = []
    for _ in range(2):
        srv = KnnServer(pts, labels=labels, cfg=cfg, seed=0)
        r = srv.query_batch([q], ls=[4])[0]
        assert r.generation == 0
        got.append(np.float32(r.label))
        srv.close()
    assert got[0].tobytes() == got[1].tobytes()
    if mode == "exact":
        # 2:2 between classes 1 and 3 -> the tie rule: lowest class wins
        assert got[0] == 1.0
    else:
        # ensemble character, pinned: every shard votes its local kNN
        # regardless of distance (arXiv 1812.05005), so the six far
        # label-0 shards outvote the two near tied shards.
        assert got[0] == 0.0


# ---- validity mask end-to-end: tombstones, compaction, re-deals ----------

def _labeled_store(cfg, seed=0, **kw):
    return MutableStore(DIM, mesh=make_mesh((K,), ("knn",)),
                        **{**cfg.store_kwargs(), **kw})


@pytest.mark.parametrize("mode", ["exact", "ensemble"])
def test_tombstoned_nearest_neighbor_never_votes(mode):
    """The regression the label path must hold end-to-end: delete the
    query's nearest neighbor and its label must vanish from the vote in
    the very next generation — in both modes."""
    cfg = BASE.replace(predict_mode=mode,
                       store_capacity_per_shard=16, num_classes=4)
    store = _labeled_store(cfg)
    rng = np.random.default_rng(5)
    q = np.zeros(DIM, np.float32)
    far = rng.normal(size=(31, DIM)).astype(np.float32) + 20.0
    store.insert(far, labels=np.full(31, 2.0))
    nearest = store.insert(q + 0.01, labels=[3.0])   # lone class-3 voter
    store.flush()
    srv = KnnServer(store=store, cfg=cfg)

    def class3_votes(r):
        """Total class-3 mass in the vote: the exact fold's winner set,
        or (ensemble) the per-shard local histograms from the explain
        vote table — either way the lone tombstone-candidate's voice."""
        if mode == "exact":
            return int(r.label == 3.0)
        table = np.array(r.explain()["predict"]["shard_answers"])
        return int(table[:, 3].sum())

    before = srv.query_batch([q], ls=[1])[0]
    assert class3_votes(before) == 1      # the nearest neighbor votes
    srv.delete(nearest)
    srv.flush_store()
    after = srv.query_batch([q], ls=[1])[0]
    assert class3_votes(after) == 0, "tombstoned neighbor's label voted"
    assert after.label == 2.0
    srv.close()


def test_labels_survive_compaction_and_proximity_redeal():
    cfg = BASE.replace(store_capacity_per_shard=64, redeal="proximity",
                       placement="affinity")
    store = _labeled_store(cfg)
    pts, labels, _ = _instance(9, n=256)
    ids = store.insert(pts, labels=labels)
    store.flush()
    # delete every third point, force the repack, and re-check the
    # surviving id -> label map against the insert-time assignment
    store.delete(ids[::3])
    store.flush()
    store.compact()
    keep = np.ones(len(ids), bool)
    keep[::3] = False
    kept_ids = ids[keep]
    np.testing.assert_array_equal(store.labels_for(kept_ids),
                                  labels[keep])
    live_ids, live_labels = store.live_labels()
    assert set(live_ids.tolist()) == set(kept_ids.tolist())
    # and the server still votes the surviving labels, not stale slots
    srv = KnnServer(store=store, cfg=cfg)
    q = pts[kept_ids[0] == ids][0] if (kept_ids[0] == ids).any() else pts[1]
    r = srv.query_batch([q], ls=[1])[0]
    assert r.label == float(store.labels_for([r.ids[0]])[0])
    srv.close()


# ---- racing ingest: the accuracy contract under churn --------------------

def test_racing_ingest_holds_accuracy_floor():
    """Ensemble accuracy vs the exact fold at the same generation, while
    an ingest thread races the queries: the accuracy-mode shadow audit
    replays every batch and must never dip below the floor on the
    well-separated mixture (and every bill stays touched_shards)."""
    cfg = BASE.replace(predict_mode="ensemble", obs_audit_every=1,
                       accuracy_floor=0.9, store_capacity_per_shard=256)
    store = _labeled_store(cfg)
    pts, labels, centers = labeled_mixture(512, DIM, NUM_CLASSES,
                                           separation=8.0, seed=11)
    labels = labels.astype(np.float32)
    store.insert(pts[:256], labels=labels[:256])
    store.flush()
    srv = KnnServer(store=store, cfg=cfg)

    stop = threading.Event()

    def ingest():
        i = 256
        while not stop.is_set() and i < 512:
            srv.insert(pts[i:i + 8], labels=labels[i:i + 8])
            srv.flush_store()
            i += 8

    t = threading.Thread(target=ingest)
    t.start()
    try:
        rng = np.random.default_rng(12)
        qbase = bayes_labels  # noqa: F841 (oracle available for debugging)
        for _ in range(12):
            qs = (centers[rng.integers(0, NUM_CLASSES, 4)]
                  + 0.5 * rng.normal(size=(4, DIM))).astype(np.float32)
            for r in srv.query_batch(qs, ls=[5, 5, 5, 5]):
                assert r.messages == r.shards_touched
    finally:
        stop.set()
        t.join()
    shadow = srv.obs_snapshot()["audit"]["shadow"]
    assert shadow["mode"] == "accuracy"
    assert shadow["checks"] > 0
    assert shadow["divergences"] == 0, shadow["details"]
    srv.close()
