"""Physical head/expert padding invariants (EXPERIMENTS.md Section Perf).

Padding exists purely so tensor dims tile the mesh; it must be
functionally inert: dummy heads contribute nothing to the output and
receive zero gradient (so training can never 'grow into' them), and
dummy experts are never routed to.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import build_model
from repro.models.attention import make_head_mask


def test_head_mask_layout():
    cfg = configs.get("granite-moe-3b-a800m")   # 24 heads -> 32, kv 8
    m = np.asarray(make_head_mask(cfg))
    assert m.shape == (32,)
    assert m.sum() == 24
    # kv-major layout: per kv group of g_phys=4, first 3 real
    assert (m.reshape(8, 4) == np.array([1, 1, 1, 0])).all()


def test_dummy_heads_get_zero_gradient(rng):
    cfg = configs.get("qwen2-0.5b").reduced()   # head_pad_to=16, real 4
    assert cfg.n_heads_phys > cfg.n_heads
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32),
             "labels": rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32)}
    g = jax.jit(jax.grad(lambda p, b: api.loss_fn(p, b)[0]))(params, batch)

    mask = np.asarray(make_head_mask(cfg))      # (H_phys,)
    hd = cfg.head_dim
    for name in ("wq", "wo"):
        gw = np.asarray(g["blocks"]["sub0"]["attn"][name], np.float32)
        if name == "wq":                        # (L, D, H*hd)
            per_head = np.abs(gw).reshape(*gw.shape[:-1], -1, hd).sum(
                axis=(0, 1, 3))
        else:                                   # (L, H*hd, D)
            per_head = np.abs(gw).reshape(gw.shape[0], -1, hd,
                                          gw.shape[-1]).sum(axis=(0, 2, 3))
        assert (per_head[mask == 0] == 0).all(), f"dummy {name} grads leak"
        assert (per_head[mask == 1] > 0).all(), f"real {name} grads missing"


def test_dummy_experts_never_routed(rng):
    cfg = dataclasses.replace(
        configs.get("granite-moe-3b-a800m").reduced(), expert_pad_to=6)
    assert cfg.n_experts_phys == 6 and cfg.n_experts == 4
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32),
             "labels": rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32)}
    g = jax.jit(jax.grad(lambda p, b: api.loss_fn(p, b)[0]))(params, batch)
    gw = np.asarray(g["blocks"]["sub0"]["moe"]["w_gate"], np.float32)
    per_expert = np.abs(gw).sum(axis=(0, 2, 3))       # (E_phys,)
    assert (per_expert[cfg.n_experts:] == 0).all(), "dummy experts trained"
    assert (per_expert[:cfg.n_experts] > 0).all()
