"""kNN-LM datastore: retrieval + logit interpolation vs numpy oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.parallel.compat import shard_map

K = 8


def test_retrieve_and_interp(mesh8, rng):
    N, dm, V, B, l = K * 512, 16, K * 128, 3, 12
    keys = rng.normal(size=(N, dm)).astype(np.float32)
    values = rng.integers(0, V, size=(N,)).astype(np.int32)
    h = rng.normal(size=(B, dm)).astype(np.float32)
    lm_logits = rng.normal(size=(B, V)).astype(np.float32)
    lam, temp = 0.3, 10.0

    def fn(kk, vv, hh, lml, key):
        store = core.datastore.build_local(kk, vv, axis_name="x")
        ret = core.datastore.retrieve(store, hh, l, key, axis_name="x",
                                      temperature=temp)
        out = core.datastore.interp_logits(lml, ret, lam, axis_name="x")
        return ret.tokens, ret.weights, ret.dists, out

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None, "x"), P(None)),
        out_specs=(P(None), P(None), P(None), P(None, "x"))))
    toks, w, d, mixed = f(keys, values, h, lm_logits, jax.random.PRNGKey(0))

    dfull = ((h[:, None, :] - keys[None]) ** 2).sum(-1)
    for b in range(B):
        nn = np.argsort(dfull[b])[:l]
        wt = np.exp(-np.sort(dfull[b])[:l] / temp)
        wt /= wt.sum()
        pk = np.zeros(V)
        np.add.at(pk, values[nn], wt)
        pl = np.exp(lm_logits[b] - lm_logits[b].max())
        pl /= pl.sum()
        want = np.log(np.maximum((1 - lam) * pl + lam * pk, 1e-30))
        np.testing.assert_allclose(np.asarray(mixed)[b], want, rtol=1e-4,
                                   atol=1e-5)
        # weights normalized, descending with distance
        np.testing.assert_allclose(float(np.asarray(w)[b].sum()), 1.0,
                                   rtol=1e-5)


def test_retrieved_distribution_prefers_near_tokens(mesh8, rng):
    """Sanity: a query sitting on a cluster of same-token keys puts most
    kNN mass on that token."""
    N, dm, V, l = K * 256, 8, 64, 16
    keys = rng.normal(size=(N, dm)).astype(np.float32) * 5
    values = rng.integers(0, V, size=(N,)).astype(np.int32)
    # plant a tight cluster of token 7 around the query
    q = rng.normal(size=(1, dm)).astype(np.float32) * 5
    keys[:l] = q + rng.normal(size=(l, dm)).astype(np.float32) * 0.01
    values[:l] = 7

    def fn(kk, vv, hh, key):
        store = core.datastore.build_local(kk, vv, axis_name="x")
        ret = core.datastore.retrieve(store, hh, l, key, axis_name="x",
                                      temperature=1.0)
        return ret.tokens, ret.weights

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None)),
        out_specs=(P(None), P(None))))
    toks, w = f(keys, values, q, jax.random.PRNGKey(1))
    mass_on_7 = float(np.asarray(w)[0][np.asarray(toks)[0] == 7].sum())
    assert mass_on_7 > 0.95
