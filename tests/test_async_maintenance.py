"""Async serving plane — the concurrency property harness.

The contract under test (DESIGN.md Section 11): with
``maintenance="background"`` a worker thread re-tightens summaries,
splits drifting shards, and compacts tombstones *while* the micro-batcher
serves and mutator threads ingest — and none of it may show anywhere in
an answer.  Concretely:

* **Bit-identical serving.**  Every ``QueryResult`` a racing pruned
  server produces must equal, byte for byte, what a quiet single-threaded
  ``route="exact"`` server answers over the same live set — reconstructed
  by replaying ``store.history(QueryResult.generation)`` into a fresh
  store.  Whatever interleaving the scheduler picks, an answer is always
  the exact answer for the generation that served it.

* **No torn reads.**  ``routing_snapshot()`` must never return summaries
  whose generation differs from the snapshot's — a detector thread
  hammers it throughout the race (the generation-coupling invariant that
  makes pruned routing safe to consult concurrently).

* **The worker actually worked.**  The harness asserts the background
  counters moved (commits, and at least one re-tighten or split) and
  that the worker finished with zero errors — a race that silently
  parked the worker would vacuously pass the identity checks.

Thread schedules are OS-chosen and non-deterministic; every *input* is
seeded, and the assertions are interleaving-independent (they hold for
any schedule), so a failure is always a real invariant violation, never
flake-by-design.  CI runs this module 3x under a faulthandler timeout
(thread-sanity job) to shake out rarer interleavings.
"""

import threading
import time
import traceback

import numpy as np
import pytest

from repro.configs.knn_service import CONFIG
from repro.runtime import KnnServer
from repro.store import MutableStore, summary_invariants

K = 8
DIM = 8
CAP = 192
L_MAX = 16

MUT_STEPS = 12
QUERY_WAVES = 10
WAVE_SIZE = 4
ORACLE_GEN_CAP = 8       # replay at most this many generations (compile cost)


def _mk_store(mesh, **overrides):
    kw = dict(capacity_per_shard=CAP, mesh=mesh, axis_name="x",
              placement="affinity", redeal="proximity", summary_pivots=2,
              retighten_every=3, split_radius_factor=1.2,
              maintenance="background", track_history=True,
              staging_size=64)
    kw.update(overrides)
    return MutableStore(DIM, **kw)


def _centers(seed):
    return np.random.default_rng(seed).normal(scale=20.0, size=(2 * K, DIM))


def _draw(rng, centers, n, c=None):
    c = int(rng.integers(0, len(centers))) if c is None else c
    return (centers[c] + rng.normal(size=(n, DIM))).astype(np.float32)


def _mutator(store, centers, seed, errors):
    """Seeded ingest/delete/update churn, flushed in small waves so the
    background worker races real epoch swaps, not one big one."""
    rng = np.random.default_rng(seed)
    try:
        for step in range(MUT_STEPS):
            store.insert(_draw(rng, centers, 12))
            store.flush()
            live = store.live_arrays()[0]
            if len(live) > 80:
                perm = rng.permutation(live)
                store.delete(perm[:8])               # disjoint from moved
                moved = perm[8:12]
                store.update(moved, _draw(rng, centers, len(moved)))
                store.flush()
            time.sleep(0.003)
    except Exception:
        errors.append(traceback.format_exc())


def _torn_read_detector(store, stop_evt, violations):
    """Hammer routing_snapshot() for the generation-coupling invariant
    while commits land from the flush path and the worker both."""
    while not stop_evt.is_set():
        snap, summ = store.routing_snapshot()
        if summ.generation != snap.generation:
            violations.append((summ.generation, snap.generation))
        time.sleep(0)      # yield so the race stays dense, not starved


def _sampled(gens, cap):
    if len(gens) <= cap:
        return gens
    idx = np.linspace(0, len(gens) - 1, cap).round().astype(int)
    return [gens[i] for i in sorted(set(idx.tolist()))]


@pytest.mark.parametrize("route_compute", ("host", "device"))
def test_racing_answers_match_quiet_oracle(mesh8, route_compute):
    """The tentpole property: ingest/delete/update threads race the
    micro-batcher and the background maintenance worker, and every
    answer is bit-identical to a quiet-store exact oracle replayed at
    the answer's own generation — for both the host routing pass and
    the fused device-side routing prologue."""
    seed = 0 if route_compute == "host" else 1
    centers = _centers(seed)
    store = _mk_store(mesh8)
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=(1, 2, 4),
                         route="pruned", route_compute=route_compute,
                         summary_pivots=2, use_sampling=False,
                         max_wait_ms=2.0)
    srv = KnnServer(store=store, cfg=cfg)

    # seed the store so the first wave has something to answer
    rng = np.random.default_rng(10 + seed)
    store.insert(_draw(rng, centers, 40, 0))
    store.insert(_draw(rng, centers, 40, 1))
    store.flush()
    srv.warmup()

    stop_evt = threading.Event()
    torn, mut_errors = [], []
    detector = threading.Thread(
        target=_torn_read_detector, args=(store, stop_evt, torn),
        name="torn-read-detector", daemon=True)
    mutator = threading.Thread(
        target=_mutator, args=(store, centers, 100 + seed, mut_errors),
        name="mutator", daemon=True)

    qrng = np.random.default_rng(200 + seed)
    pending = []
    with srv.serving():
        detector.start()
        mutator.start()
        for _ in range(QUERY_WAVES):
            for _ in range(WAVE_SIZE):
                q = _draw(qrng, centers, 1)[0]
                l = int(qrng.integers(1, L_MAX))
                pending.append((q, l, srv.submit(q, l)))
            time.sleep(0.004)
        mutator.join()
        results = [(q, l, f.result(timeout=120)) for q, l, f in pending]
    stop_evt.set()
    detector.join()
    store.close()

    assert not mut_errors, mut_errors[0]
    assert not torn, f"torn routing_snapshot reads: {torn[:5]}"

    # the worker must have actually churned mid-run, with zero errors
    ws = store.maintenance_stats()["worker"]
    assert ws["errors"] == 0 and ws["error"] is None
    assert ws["commits"] > 0
    assert ws["retightens"] + ws["splits"] + ws["repacks"] > 0

    # replay each served generation into a fresh quiet store and demand
    # byte equality from an exact (unpruned, host-routed) server
    by_gen = {}
    for q, l, r in results:
        by_gen.setdefault(r.generation, []).append((q, l, r))
    gens = _sampled(sorted(by_gen), ORACLE_GEN_CAP)
    assert gens, "no queries resolved"
    oracle_cfg = cfg.replace(route="exact", route_compute="host",
                             summary_pivots=1)
    for g in gens:
        ids, pts_g = store.history(g)
        oracle = MutableStore(DIM, capacity_per_shard=CAP, mesh=mesh8,
                              axis_name="x")
        if len(ids):
            oracle.insert(pts_g, ids=ids)
        oracle.flush()
        osrv = KnnServer(store=oracle, cfg=oracle_cfg)
        qs = np.stack([q for q, _, _ in by_gen[g]])
        ls = [l for _, l, _ in by_gen[g]]
        for expect, (_, _, got) in zip(osrv.query_batch(qs, ls), by_gen[g]):
            assert got.dists.tobytes() == expect.dists.tobytes(), g
            assert np.array_equal(got.ids, expect.ids), g


def test_background_converges_to_inline_live_set(mesh8):
    """A background store and an inline twin fed the identical seeded op
    sequence hold the identical live set once the worker quiesces —
    repacks and splits move slots, never membership — and the
    background store's summaries still satisfy the covering
    invariants exactly."""
    centers = _centers(7)
    rng = np.random.default_rng(7)
    bg = _mk_store(mesh8)
    inline = _mk_store(mesh8, maintenance="inline")
    for step in range(10):
        batch = _draw(rng, centers, 14)
        ids_a = bg.insert(batch)
        ids_b = inline.insert(batch)
        assert np.array_equal(ids_a, ids_b)
        bg.flush()
        inline.flush()
        live = inline.live_arrays()[0]
        if len(live) > 60 and step % 2:
            victims = np.sort(live)[::5][:6]
            bg.delete(victims)
            inline.delete(victims)
            bg.flush()
            inline.flush()
    time.sleep(0.25)            # let the worker drain its queue
    bg.close()

    ids_a, pts_a = bg.live_arrays()
    ids_b, pts_b = inline.live_arrays()
    oa, ob = np.argsort(ids_a), np.argsort(ids_b)
    assert np.array_equal(ids_a[oa], ids_b[ob])
    assert pts_a[oa].tobytes() == pts_b[ob].tobytes()

    inv = summary_invariants(bg.summaries(), bg._pts, bg._valid, bg.cap)
    assert inv["live_mismatch"] == 0
    assert inv["radius_violation"] <= 1e-9
    assert inv["projection_violation"] <= 1e-9
    ws = bg.maintenance_stats()["worker"]
    assert ws["errors"] == 0
    assert ws["commits"] > 0


def test_inline_mode_has_no_worker(mesh8):
    """maintenance="inline" preserves today's behavior exactly: no worker
    thread, no worker stats, close() is a no-op, and maintenance runs
    on the flush path as before."""
    store = MutableStore(DIM, capacity_per_shard=32, mesh=mesh8,
                         axis_name="x", retighten_every=1)
    assert store.maintenance == "inline"
    assert "worker" not in store.maintenance_stats()
    before = threading.active_count()
    store.insert(np.random.default_rng(0)
                 .normal(size=(40, DIM)).astype(np.float32))
    store.flush()
    assert store.stats.retightens > 0          # inline path still maintains
    store.close()                              # no-op, must not raise
    assert threading.active_count() == before
    with pytest.raises(ValueError, match="maintenance"):
        MutableStore(DIM, capacity_per_shard=8, axis_name="x",
                     maintenance="sometimes")


def test_background_worker_stops_cleanly(mesh8):
    """close() joins the worker thread; a second close() is a no-op; the
    store keeps serving (reads and inline-free flushes) after close."""
    store = _mk_store(mesh8)
    rng = np.random.default_rng(3)
    store.insert(rng.normal(scale=10.0, size=(64, DIM)).astype(np.float32))
    store.flush()
    names = [t.name for t in threading.enumerate()]
    assert "knn-store-maintenance" in names
    store.close()
    store.close()
    names = [t.name for t in threading.enumerate()]
    assert "knn-store-maintenance" not in names
    # the store itself is still a valid (now unmaintained) store
    store.insert(rng.normal(size=(8, DIM)).astype(np.float32))
    gen = store.flush()
    snap, summ = store.routing_snapshot()
    assert summ.generation == snap.generation == gen


def _torn_serving_detector(store, stop_evt, violations):
    """serving_snapshot()'s three-way generation coupling (snapshot,
    summaries, bucket index) under the same hammering as the routing
    detector."""
    while not stop_evt.is_set():
        snap, summ, idx = store.serving_snapshot()
        if not (summ.generation == snap.generation == idx.generation):
            violations.append((snap.generation, summ.generation,
                               idx.generation))
        time.sleep(0)


def test_racing_approx_respects_recall_floor(mesh8):
    """search="approx" under the full race: mutator churn + background
    maintenance + micro-batched serving through the bucket index.  The
    tier is allowed to miss neighbors, but the *measured* contract must
    hold whatever interleaving the scheduler picks: every answer's
    recall@l against a quiet-store exact oracle replayed at the
    answer's own generation stays at/above the floor, serving_snapshot
    never tears its three-way generation coupling, and the live shadow
    recall audit agrees."""
    seed = 2
    centers = _centers(seed)
    store = _mk_store(mesh8, index_buckets=4)
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=(1, 2, 4),
                         route="pruned", summary_pivots=2,
                         search="approx", index_buckets=4,
                         recall_floor=0.95, obs_audit_every=3,
                         use_sampling=False, max_wait_ms=2.0)
    srv = KnnServer(store=store, cfg=cfg)

    rng = np.random.default_rng(10 + seed)
    store.insert(_draw(rng, centers, 40, 0))
    store.insert(_draw(rng, centers, 40, 1))
    store.flush()
    srv.warmup()

    stop_evt = threading.Event()
    torn, mut_errors = [], []
    detector = threading.Thread(
        target=_torn_serving_detector, args=(store, stop_evt, torn),
        name="torn-serving-detector", daemon=True)
    mutator = threading.Thread(
        target=_mutator, args=(store, centers, 100 + seed, mut_errors),
        name="mutator", daemon=True)

    qrng = np.random.default_rng(200 + seed)
    pending = []
    with srv.serving():
        detector.start()
        mutator.start()
        for _ in range(QUERY_WAVES):
            for _ in range(WAVE_SIZE):
                q = _draw(qrng, centers, 1)[0]
                l = int(qrng.integers(1, L_MAX))
                pending.append((q, l, srv.submit(q, l)))
            time.sleep(0.004)
        mutator.join()
        results = [(q, l, f.result(timeout=120)) for q, l, f in pending]
    stop_evt.set()
    detector.join()
    store.close()

    assert not mut_errors, mut_errors[0]
    assert not torn, f"torn serving_snapshot reads: {torn[:5]}"
    assert all(r.recall_mode == "approx" for _, _, r in results)

    ws = store.maintenance_stats()["worker"]
    assert ws["errors"] == 0
    assert ws["commits"] > 0

    # quiet-store oracle: replay each served generation, demand the
    # measured recall contract (not byte identity — this is the approx
    # tier) at the answer's own epoch
    sentinel = 2 ** 31 - 1
    by_gen = {}
    for q, l, r in results:
        by_gen.setdefault(r.generation, []).append((q, l, r))
    gens = _sampled(sorted(by_gen), ORACLE_GEN_CAP)
    assert gens, "no queries resolved"
    oracle_cfg = cfg.replace(search="exact", route="exact",
                             summary_pivots=1)
    recalls = []
    for g in gens:
        ids, pts_g = store.history(g)
        oracle = MutableStore(DIM, capacity_per_shard=CAP, mesh=mesh8,
                              axis_name="x")
        if len(ids):
            oracle.insert(pts_g, ids=ids)
        oracle.flush()
        osrv = KnnServer(store=oracle, cfg=oracle_cfg)
        qs = np.stack([q for q, _, _ in by_gen[g]])
        ls = [l for _, l, _ in by_gen[g]]
        for expect, (_, _, got) in zip(osrv.query_batch(qs, ls),
                                       by_gen[g]):
            truth = set(expect.ids[expect.ids != sentinel].tolist())
            if not truth:
                continue
            recalls.append(
                len(truth & set(got.ids.tolist())) / len(truth))
    assert recalls
    assert min(recalls) >= cfg.recall_floor, min(recalls)

    # the live shadow audit measured the same contract mid-race
    shadow = srv.obs_snapshot()["audit"]["shadow"]
    assert shadow["mode"] == "recall"
    assert shadow["checks"] >= 1
    assert shadow["divergences"] == 0
