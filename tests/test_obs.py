"""Observability plane (src/repro/obs/): recorder, registry, auditors.

What this suite pins, layer by layer:

* **Histogram quantiles** stay within one geometric bucket (~2.2%
  relative, asserted at 5%) of a sorted oracle with O(1) observes — the
  property that fixed ``StepWatchdog.observe``'s per-step re-sort.
* **Span trees are well-formed under racing** — the
  test_async_maintenance.py-style harness (mutator thread + background
  maintenance worker + micro-batcher) must quiesce with zero torn
  spans, every exported tree reassembling cleanly, complete request
  trees, and maintenance cycles interleaved in the same ring.
* **The auditors audit.**  The Theorem-1 contract envelope passes on
  real serving and trips on absurd bills; the shadow-exact auditor
  catches an injected routing corruption (a monkeypatched router that
  silently drops shards) and stays silent on a clean run.
* **The recorder is affordable**: instrumented-vs-off on the same smoke
  workload within the 10% budget (DESIGN.md §12).
"""

import io
import json
import math
import threading
import time

import numpy as np
import pytest

from repro.configs.knn_service import CONFIG
from repro.obs import ObsPlane
from repro.obs.audit import ContractAuditor, ShadowAuditor
from repro.obs.metrics import (GROWTH, Histogram, MetricsRegistry,
                               default_registry)
from repro.obs.trace import NULL_TRACER, Tracer, build_trees
from repro.runtime import KnnServer
from repro.runtime.metrics import StepWatchdog
from repro.store import MutableStore

DIM = 8
L_MAX = 16


# ---- metrics registry ----------------------------------------------------

def test_histogram_quantiles_vs_sorted_oracle(rng):
    h = Histogram()
    samples = rng.lognormal(mean=-6.0, sigma=1.5, size=20_000)
    for v in samples:
        h.observe(float(v))
    s = np.sort(samples)
    for q in (0.50, 0.90, 0.99):
        exact = float(s[min(int(math.ceil(q * len(s))) - 1, len(s) - 1)])
        approx = h.quantile(q)
        assert abs(approx - exact) / exact < 0.05, (q, approx, exact)
    snap = h.snapshot()
    assert snap["count"] == len(samples)
    assert snap["min"] == float(samples.min())
    assert snap["max"] == float(samples.max())
    assert abs(snap["mean"] - samples.mean()) / samples.mean() < 1e-9


def test_histogram_identical_values_exact_and_edge_cases():
    h = Histogram()
    assert math.isnan(h.quantile(0.5))
    for _ in range(9):
        h.observe(0.1)
    # all-identical observations: clamping to [min, max] makes every
    # quantile exact — the property StepWatchdog's flagging rests on
    assert h.quantile(0.5) == pytest.approx(0.1)
    assert h.quantile(0.99) == pytest.approx(0.1)
    h.observe(0.0)                     # underflow bucket -> reported min
    assert h.quantile(0.01) == 0.0
    # any quantile is within one bucket (~GROWTH) of the true value
    assert GROWTH < 1.05


def test_histogram_empty_explicit_and_full_key_snapshot():
    """Empty-histogram oracle: quantile is NaN at *every* q (never the
    +inf/-inf min/max seeds), and snapshot carries the full key set so
    readers indexing ["p99"]/["mean"] unconditionally never KeyError on
    a histogram that simply hasn't fired yet (e.g. serve.route_s under
    route="exact")."""
    h = Histogram()
    for q in (0.0, 0.01, 0.5, 0.99, 1.0):
        assert math.isnan(h.quantile(q)), q
    snap = h.snapshot()
    assert snap["count"] == 0
    assert set(snap) == {"count", "sum", "mean", "min", "max",
                         "p50", "p90", "p99"}
    assert snap["sum"] == 0.0 and snap["mean"] == 0.0
    assert snap["min"] == 0.0 and snap["max"] == 0.0      # seeds hidden
    assert all(math.isnan(snap[k]) for k in ("p50", "p90", "p99"))
    assert not any(math.isinf(v) for v in snap.values()
                   if isinstance(v, float))


def test_histogram_single_observation_and_extreme_q_oracle():
    """Nearest-rank edges against the sorted oracle: one observation
    answers every q with itself; q=0.0 is the min and q=1.0 the max of
    any sample."""
    h = Histogram()
    h.observe(0.25)
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == pytest.approx(0.25), q
    snap = h.snapshot()
    assert snap["count"] == 1
    assert snap["min"] == snap["max"] == 0.25
    assert snap["mean"] == pytest.approx(0.25)
    samples = [0.003, 0.5, 0.02, 0.11, 7.0]
    h2 = Histogram()
    for v in samples:
        h2.observe(v)
    # multi-sample edges: within one geometric bucket (~2.2%) of the
    # true order statistic, and never outside the observed range
    assert h2.quantile(0.0) == pytest.approx(min(samples), rel=0.05)
    assert h2.quantile(1.0) == pytest.approx(max(samples), rel=0.05)
    assert min(samples) <= h2.quantile(0.0) <= max(samples)
    assert min(samples) <= h2.quantile(1.0) <= max(samples)


def test_registry_create_or_get_and_type_collision():
    reg = MetricsRegistry()
    c = reg.counter("a.count")
    c.inc()
    assert reg.counter("a.count") is c
    assert reg.value("a.count") == 1
    assert reg.value("missing", default=7) == 7
    with pytest.raises(TypeError, match="already registered"):
        reg.histogram("a.count")
    reg.gauge("a.gauge").set(2.5)
    reg.histogram("a.hist").observe(1.0)
    snap = reg.snapshot(prefix="a.")
    assert set(snap) == {"a.count", "a.gauge", "a.hist"}
    buf = io.StringIO()
    assert reg.export_jsonl(buf) == 3
    lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
    assert {ln["metric"] for ln in lines} == set(snap)


def test_step_watchdog_streaming_semantics():
    w = StepWatchdog(factor=3.0, warmup=3)
    for _ in range(10):
        assert not w.observe(0.1)
    assert w.observe(1.0)              # 10x the p50 -> flagged
    assert w.flagged
    assert not w.observe(0.1)          # recovery is not sticky
    # registry-backed: the same flagging, counted
    reg = MetricsRegistry()
    w2 = StepWatchdog(factor=3.0, warmup=2, registry=reg)
    for _ in range(4):
        w2.observe(0.05)
    w2.observe(0.5)
    assert reg.value("watchdog.step_s.flagged") == 1
    assert reg.get("watchdog.step_s").count == 5


# ---- tracer --------------------------------------------------------------

def test_tracer_span_tree_and_retroactive_record():
    tr = Tracer(capacity=64)
    root = tr.begin("request", l=4)
    t_mid = time.perf_counter()
    with tr.span("kernel", parent=root, path="oracle"):
        time.sleep(0.001)
    tr.record("queued", root.t0, t_mid, parent=root)
    root.end(route="pruned")
    assert tr.active_count() == 0
    recs = tr.spans()
    assert [r["name"] for r in recs] == ["kernel", "queued", "request"]
    trees = build_trees(recs)
    assert len(trees) == 1
    by_name = {r["name"]: r for r in recs}
    assert by_name["kernel"]["parent"] == by_name["request"]["span"]
    assert by_name["request"]["attrs"] == {"l": 4, "route": "pruned"}
    # idempotent end: a second end must not double-record
    root.end()
    assert len(tr.spans()) == 3


def test_tracer_ring_eviction_and_export():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.begin(f"s{i}").end()
    assert len(tr.spans()) == 4
    assert tr.dropped == 6
    assert [r["name"] for r in tr.spans()] == ["s6", "s7", "s8", "s9"]
    buf = io.StringIO()
    assert tr.export_jsonl(buf) == 4
    assert tr.stats()["recorded"] == 4
    tr.clear()
    assert tr.spans() == [] and tr.dropped == 0
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_null_tracer_is_inert():
    sp = NULL_TRACER.begin("x", parent=None, l=1)
    assert sp.end() is sp and sp.span_id == 0
    with NULL_TRACER.span("y"):
        pass
    assert NULL_TRACER.spans() == []
    assert NULL_TRACER.active_count() == 0
    assert NULL_TRACER.export_jsonl(io.StringIO()) == 0
    assert NULL_TRACER.stats()["enabled"] is False


def test_build_trees_rejects_malformed_forests():
    def rec(span, parent, t0, t1, trace=1, name="s"):
        return {"trace": trace, "span": span, "parent": parent,
                "name": name, "t0": t0, "t1": t1}

    with pytest.raises(ValueError, match="unfinished"):
        build_trees([rec(1, None, 0.0, None)])
    with pytest.raises(ValueError, match="orphaned"):
        build_trees([rec(2, 99, 0.0, 1.0)])
    with pytest.raises(ValueError, match="ends before"):
        build_trees([rec(1, None, 5.0, 1.0)])
    with pytest.raises(ValueError, match="outside parent"):
        build_trees([rec(1, None, 0.0, 1.0),
                     rec(2, 1, 0.0, 2.0)])
    with pytest.raises(ValueError, match="crosses traces"):
        build_trees([rec(1, None, 0.0, 1.0),
                     rec(2, 1, 0.0, 0.5, trace=7)])
    # well-formed forest: two roots, nested children
    ok = [rec(1, None, 0.0, 1.0), rec(2, 1, 0.2, 0.8),
          rec(3, None, 0.0, 1.0, trace=3)]
    assert set(build_trees(ok)) == {1, 3}


def test_obs_plane_from_config():
    on = ObsPlane.from_config(CONFIG.replace(obs_trace=True,
                                             obs_trace_capacity=32))
    assert on.tracer.enabled and on.tracer.capacity == 32
    off = ObsPlane.from_config(CONFIG)
    assert off.tracer is NULL_TRACER
    assert off.snapshot()["trace"]["enabled"] is False


def test_compaction_evaluate_publishes_registry():
    from repro.store import compaction
    reg = MetricsRegistry()
    live = np.array([10, 10, 10, 10])
    used = np.array([20, 10, 10, 10])   # 10 dead of 50 used
    d = compaction.evaluate(live, used, 32, tombstone_frac=0.1,
                            imbalance_frac=0.5, registry=reg)
    assert d.compact and "tombstone" in d.reason
    assert reg.value("store.compact_trigger.tombstone") == 1
    assert reg.value("store.tombstone_density") == pytest.approx(0.2)
    d2 = compaction.evaluate(live, np.array([30, 10, 10, 10]), 32,
                             tombstone_frac=0.9, imbalance_frac=0.5,
                             registry=reg)
    assert not d2.compact               # gauges refresh even when quiet
    assert reg.value("store.tombstone_density") == pytest.approx(1 / 3)
    # registry-less calls stay pure (the store without an attached plane)
    assert compaction.evaluate(live, used, 32, tombstone_frac=0.1,
                               imbalance_frac=0.5).compact


# ---- contract auditor ----------------------------------------------------

def test_contract_auditor_bounds_and_verdicts():
    reg = MetricsRegistry()
    a = ContractAuditor(reg, k=8)
    # monotone in l, barely sensitive to n (the w.h.p. loglog term)
    r1 = a.rounds_bound(1, 10_000, use_sampling=True, sampler="selection")
    r128 = a.rounds_bound(128, 10_000, use_sampling=True,
                          sampler="selection")
    assert r1 < r128
    big_n = a.rounds_bound(1, 10_000_000, use_sampling=True,
                           sampler="selection")
    assert big_n - r1 < 6.0            # loglog growth, not log
    # gather is exact: 1 round, (k-1)*l_max messages
    assert a.rounds_bound(16, 10_000, use_sampling=True,
                          sampler="gather") == 1.0
    assert a.messages_bound(16, 10_000, use_sampling=True,
                            sampler="gather") == 7 * 16
    # a realistic bill passes; an absurd one (the deterministic
    # iteration cap, ~8*log2(n) rounds) is flagged
    assert a.check(l_max=8, n_live=10_000, rounds=24, messages=7 * 24,
                   use_sampling=True, sampler="selection")
    assert not a.check(l_max=8, n_live=10_000, rounds=280,
                       messages=7 * 280, use_sampling=True,
                       sampler="selection")
    snap = a.snapshot()
    assert snap["checks"] == 2 and snap["violations"] == 1
    assert snap["details"][0]["rounds"] == 280
    # Theorem 2.2 regime (no sampling): O(log n) rounds are in-envelope
    assert a.check(l_max=8, n_live=10_000, rounds=60, messages=7 * 60,
                   use_sampling=False, sampler="selection")


def test_shadow_auditor_sampling_and_divergence():
    reg = MetricsRegistry()
    s = ShadowAuditor(reg, every=3)
    assert [s.due() for _ in range(7)] == [True, False, False,
                                           True, False, False, True]
    d = np.arange(4, dtype=np.float32)
    i = np.arange(4, dtype=np.int32)
    assert s.check(d, i, lambda: (d.copy(), i.copy()))
    assert not s.check(d, i, lambda: (d + 1, i.copy()), batch_id=5)
    snap = s.snapshot()
    assert snap["checks"] == 2 and snap["divergences"] == 1
    assert snap["details"][0]["batch_id"] == 5
    with pytest.raises(ValueError):
        ShadowAuditor(reg, every=0)
    with pytest.raises(ValueError, match="mode"):
        ShadowAuditor(reg, every=1, mode="fuzzy")


def test_shadow_auditor_recall_mode():
    """mode="recall" (the search="approx" contract): per-row recall@l
    against the exact replay's finite ids, minimum over rows, floored.
    Sentinel-only rows (padding / l=0) are vacuous; the measured
    minimum lands in the snapshot's recall histogram."""
    sent = 2**31 - 1
    reg = MetricsRegistry()
    s = ShadowAuditor(reg, every=1, mode="recall", floor=0.75)
    exact_i = np.array([[1, 2, 3, 4],
                        [10, 11, sent, sent],
                        [sent, sent, sent, sent]], np.int32)
    d = np.zeros_like(exact_i, np.float32)
    # row recalls 4/4, 2/2 -> min 1.0: passes
    assert s.check(exact_i.copy(), exact_i.copy(),
                   lambda: (d, exact_i.copy()))
    # row0 drops one true id (3/4 = 0.75, at the floor): still passes
    near = exact_i.copy()
    near[0, 3] = 99
    assert s.check(d, near, lambda: (d, exact_i.copy()))
    # row1 misses both true ids -> min 0.0: flagged with the measurement
    bad = exact_i.copy()
    bad[1, :2] = [98, 99]
    assert not s.check(d, bad, lambda: (d, exact_i.copy()), batch_id=3)
    snap = s.snapshot()
    assert snap["mode"] == "recall" and snap["floor"] == 0.75
    assert snap["checks"] == 3 and snap["divergences"] == 1
    assert snap["details"][0]["min_recall"] == 0.0
    assert snap["details"][0]["batch_id"] == 3
    assert snap["recall"]["count"] == 3
    assert snap["recall"]["min"] == 0.0


# ---- serving integration -------------------------------------------------

def _clustered_server(mesh8, *, obs_trace=True, audit_every=0,
                      route_compute="host", seed=0, per_shard=24):
    from repro.data import sharded_clusters
    pts, centers = sharded_clusters(8, per_shard, DIM, seed=seed)
    cfg = CONFIG.replace(dim=DIM, l=4, l_max=L_MAX, bucket_sizes=(1, 2, 4),
                         sampler="selection", route="pruned",
                         route_compute=route_compute,
                         obs_trace=obs_trace, obs_audit_every=audit_every)
    srv = KnnServer(pts, cfg=cfg, mesh=mesh8, axis_name="x")
    srv.warmup()
    return srv, centers


def test_request_trace_complete_and_audits_clean(mesh8):
    """One traced, audited serving pass: every request tree is complete
    (queued + serve children), every dispatch tree carries the
    snapshot/route/kernel/resolve stages, both auditors ran and stayed
    clean, and the per-stage histograms populated."""
    srv, centers = _clustered_server(mesh8, audit_every=2)
    rng = np.random.default_rng(1)
    for wave in range(5):
        qs = (centers[wave % len(centers)]
              + rng.normal(size=(3, DIM))).astype(np.float32)
        srv.query_batch(qs, [1 + wave % 4] * 3)
    assert srv.obs.tracer.active_count() == 0
    recs = srv.obs.tracer.spans()
    build_trees(recs)
    kids = {}
    for r in recs:
        if r["parent"] is not None:
            kids.setdefault(r["parent"], set()).add(r["name"])
    requests = [r for r in recs if r["name"] == "request"]
    assert len(requests) == 15
    assert all(kids[r["span"]] == {"queued", "serve"} for r in requests)
    dispatches = [r for r in recs if r["name"] == "dispatch"]
    assert dispatches
    for d in dispatches:
        assert {"snapshot", "route", "kernel", "resolve"} <= kids[d["span"]]
    # the serve child names its dispatch batch (cross-tree reference by
    # attribute, never by parent link)
    batches = {d["attrs"]["batch"] for d in dispatches}
    serves = [r for r in recs if r["name"] == "serve"]
    assert all(r["attrs"]["batch"] in batches for r in serves)

    snap = srv.obs_snapshot()
    assert snap["audit"]["contract"]["checks"] == len(dispatches)
    assert snap["audit"]["contract"]["violations"] == 0
    assert snap["audit"]["shadow"]["checks"] >= 1
    assert snap["audit"]["shadow"]["divergences"] == 0
    for stage in ("serve.snapshot_s", "serve.route_s", "serve.kernel_s",
                  "serve.resolve_s", "serve.latency_s", "serve.queued_s"):
        assert snap["metrics"][stage]["count"] > 0, stage
    assert snap["metrics"]["serve.rounds"]["count"] == len(dispatches)
    # the kernels dispatcher counted its envelope builds (and any
    # fallbacks) in the process-wide registry
    assert default_registry().value("kernel.envelopes") > 0


def test_device_routed_trace_has_fused_route_span(mesh8):
    srv, centers = _clustered_server(mesh8, route_compute="device",
                                     audit_every=2, seed=3)
    qs = (centers[0] + np.random.default_rng(2)
          .normal(size=(2, DIM))).astype(np.float32)
    srv.query_batch(qs, [4, 4])
    recs = srv.obs.tracer.spans()
    build_trees(recs)
    routes = [r for r in recs if r["name"] == "route"]
    assert routes and all(r["attrs"]["fused"] for r in routes)
    kernels = [r for r in recs if r["name"] == "kernel"]
    assert all(r["attrs"]["route_compute"] == "device" for r in kernels)
    snap = srv.obs_snapshot()
    assert snap["audit"]["shadow"]["checks"] >= 1
    assert snap["audit"]["shadow"]["divergences"] == 0
    assert snap["audit"]["contract"]["violations"] == 0


def test_shadow_auditor_catches_injected_routing_corruption(mesh8):
    """Corrupt the router (drop every shard but the query's worst) and
    the sampled shadow-exact replay must flag byte divergence — the
    offline bit-identity invariant as a live tripwire."""
    from repro.store import summaries as summaries_mod
    srv, centers = _clustered_server(mesh8, audit_every=1, seed=4)
    real_route = summaries_mod.route_shards

    def corrupt_route(summ, q, l_arr, slack):
        mask = real_route(summ, q, l_arr, slack=slack)
        out = np.zeros_like(mask)
        out[:, 0] = True               # only shard 0, whatever the query
        return out

    try:
        summaries_mod.route_shards = corrupt_route
        rng = np.random.default_rng(5)
        # queries near non-shard-0 clusters: the exact answer lives on a
        # shard the corrupted router just dropped
        for c in (3, 5, 7):
            qs = (centers[c] + rng.normal(size=(2, DIM))) \
                .astype(np.float32)
            srv.query_batch(qs, [4, 4])
    finally:
        summaries_mod.route_shards = real_route
    snap = srv.obs_snapshot()
    assert snap["audit"]["shadow"]["checks"] >= 3
    assert snap["audit"]["shadow"]["divergences"] >= 1
    assert snap["audit"]["shadow"]["details"][0]["batch_id"] >= 0


def test_racing_span_forest_well_formed(mesh8):
    """The concurrency bar: a mutator thread and the background
    maintenance worker race a traced server, and the ring still holds a
    clean forest — no torn spans after quiesce, every tree
    reassembles, request trees complete, and maintenance
    plan/prepare/commit cycles interleave with query spans in the same
    export."""
    centers = np.random.default_rng(11).normal(scale=20.0, size=(16, DIM))
    cfg = CONFIG.replace(dim=DIM, l=4, l_max=L_MAX, bucket_sizes=(1, 2, 4),
                         route="pruned", summary_pivots=2,
                         use_sampling=False, max_wait_ms=2.0,
                         placement="affinity", redeal="proximity",
                         retighten_every=3, split_radius_factor=1.2,
                         maintenance="background",
                         store_capacity_per_shard=192, store_staging_size=64,
                         obs_trace=True, obs_audit_every=3)
    store = MutableStore(DIM, mesh=mesh8, axis_name="x",
                         **cfg.store_kwargs())
    srv = KnnServer(store=store, cfg=cfg)
    rng = np.random.default_rng(12)

    def draw(n, c=None):
        c = int(rng.integers(0, len(centers))) if c is None else c
        return (centers[c] + rng.normal(size=(n, DIM))).astype(np.float32)

    store.insert(draw(40, 0))
    store.insert(draw(40, 1))
    store.flush()
    srv.warmup()

    errors = []

    def mutator():
        try:
            for _ in range(10):
                store.insert(draw(12))
                store.flush()
                live = store.live_arrays()[0]
                if len(live) > 90:
                    store.delete(np.random.default_rng(1)
                                 .permutation(live)[:8])
                    store.flush()
                time.sleep(0.003)
        except Exception as exc:     # surfaced below, not swallowed
            errors.append(exc)

    t = threading.Thread(target=mutator, daemon=True)
    pending = []
    with srv.serving():
        t.start()
        for wave in range(8):
            for _ in range(3):
                pending.append(srv.submit(draw(1)[0],
                                          1 + wave % 4))
            time.sleep(0.004)
        t.join()
        for f in pending:
            f.result(timeout=120)
    store.close()
    assert not errors, errors

    assert srv.obs.tracer.active_count() == 0, "torn spans after quiesce"
    recs = srv.obs.tracer.spans()
    trees = build_trees(recs)
    names = {r["name"] for r in recs}
    assert {"request", "queued", "serve", "dispatch", "snapshot",
            "kernel", "resolve", "store.apply"} <= names
    ws = store.maintenance_stats()["worker"]
    assert ws["errors"] == 0
    assert ws["commits"] > 0
    assert {"maint.cycle", "maint.prepare", "maint.commit"} <= names
    kids = {}
    for r in recs:
        if r["parent"] is not None:
            kids.setdefault(r["parent"], set()).add(r["name"])
    requests = [r for r in recs if r["name"] == "request"]
    assert len(requests) == len(pending)
    assert all(kids[r["span"]] == {"queued", "serve"} for r in requests)
    assert len(trees) >= len(requests)
    snap = srv.obs_snapshot()
    assert snap["audit"]["contract"]["violations"] == 0
    assert snap["audit"]["shadow"]["checks"] >= 1
    assert snap["audit"]["shadow"]["divergences"] == 0


def test_instrumentation_overhead_within_budget(mesh8):
    """Tracing + contract auditing must cost <= 10% of obs-off
    throughput on the smoke workload (DESIGN.md §12 budget).  The arms
    run the identical seeded load *interleaved* (back-to-back arms
    confound the recorder's microseconds with scheduler drift), and
    min-of-7 per arm damps the remaining noise."""
    servers = {}
    for obs_trace in (False, True):
        srv, centers = _clustered_server(mesh8, obs_trace=obs_trace,
                                         seed=6)
        servers[obs_trace] = srv
    rng = np.random.default_rng(7)
    qs_waves = [(centers[w % 8] + rng.normal(size=(4, DIM)))
                .astype(np.float32) for w in range(6)]

    def one_pass(srv):
        t0 = time.perf_counter()
        for qs in qs_waves:
            srv.query_batch(qs, [4] * 4)
        return time.perf_counter() - t0

    for srv in servers.values():       # warm the whole path, both arms
        one_pass(srv)
    best = {False: math.inf, True: math.inf}
    for _ in range(7):
        for obs_trace, srv in servers.items():
            best[obs_trace] = min(best[obs_trace], one_pass(srv))
    overhead = (best[True] - best[False]) / best[False]
    assert overhead <= 0.10, f"obs overhead {overhead:.1%} > 10%"
