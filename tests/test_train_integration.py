"""Training integration: learnability, fault tolerance, stragglers,
gradient compression, data determinism."""

import time

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import MarkovTokens, Prefetcher
from repro.models import build_model
from repro.optim import AdamW
from repro.runtime import (MetricLogger, SimulatedNodeFailure, StepWatchdog,
                           TrainConfig, init_opt_state, train_loop)


def _setup(compress=False, steps=60):
    cfg = configs.get("qwen2-0.5b").reduced()
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    tcfg = TrainConfig(grad_accum=2, peak_lr=3e-3, warmup_steps=5,
                       total_steps=steps + 20, compress_grads=compress)
    opt = AdamW(weight_decay=0.01)
    opt_state = init_opt_state(api, tcfg, opt, params)
    data = MarkovTokens(cfg.vocab, seed=3, branch=2, n_contexts=13)

    def make_batch(step):
        t, l = data.batch(step, 8, 32)
        return {"tokens": t, "labels": l}

    return api, tcfg, opt, params, opt_state, make_batch


def test_loss_decreases():
    api, tcfg, opt, params, opt_state, make_batch = _setup()
    logger = MetricLogger(quiet=True)
    train_loop(api=api, tcfg=tcfg, optimizer=opt, params=params,
               opt_state=opt_state, make_batch=make_batch, num_steps=50,
               logger=logger)
    losses = [r["loss"] for r in logger.history if "loss" in r]
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_fault_injection_restart(tmp_path):
    api, tcfg, opt, params, opt_state, make_batch = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    crashed = {"n": 0}

    def fail_at(step):
        if step == 22 and crashed["n"] == 0:
            crashed["n"] += 1
            raise SimulatedNodeFailure("injected node loss")

    logger = MetricLogger(quiet=True)
    _, _, step = train_loop(
        api=api, tcfg=tcfg, optimizer=opt, params=params,
        opt_state=opt_state, make_batch=make_batch, num_steps=30,
        ckpt_manager=mgr, ckpt_every=10, fail_at=fail_at, logger=logger)
    assert step == 30
    assert crashed["n"] == 1
    assert any("event" in r for r in logger.history)  # restart logged
    # replayed steps exist: step 20..22 run twice
    steps = [r["step"] for r in logger.history if "loss" in r]
    assert steps.count(21) == 2


def test_compressed_grads_still_learn():
    api, tcfg, opt, params, opt_state, make_batch = _setup(compress=True)
    logger = MetricLogger(quiet=True)
    train_loop(api=api, tcfg=tcfg, optimizer=opt, params=params,
               opt_state=opt_state, make_batch=make_batch, num_steps=50,
               logger=logger)
    losses = [r["loss"] for r in logger.history if "loss" in r]
    assert losses[-1] < losses[0] - 1.0


def test_straggler_watchdog():
    w = StepWatchdog(factor=3.0, warmup=3)
    for _ in range(10):
        assert not w.observe(0.1)
    assert w.observe(1.0)      # 10x the median -> flagged
    assert w.flagged


def test_prefetcher_determinism_and_shutdown():
    data = MarkovTokens(97, seed=5)

    def make(step):
        t, l = data.batch(step, 2, 8)
        return {"tokens": t, "labels": l}

    pf = Prefetcher(make, prefetch=2)
    got = [next(pf) for _ in range(4)]
    pf.close()
    # determinism: regenerating the same steps gives identical batches
    for step, batch in got:
        t, l = data.batch(step, 2, 8)
        np.testing.assert_array_equal(batch["tokens"], t)
        np.testing.assert_array_equal(batch["labels"], l)
    assert [s for s, _ in got] == [0, 1, 2, 3]
