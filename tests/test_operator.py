"""Operator layer (ISSUE 9): explain reports, SLO burn rates, exporters.

What this suite pins, layer by layer:

* **Window metrics keep honest clocks** — sliding-window aggregates and
  quantiles over an explicit synthetic timebase, so the SLO engine's
  evidence can be replayed deterministically.
* **Burn-rate arithmetic has units** — with the standard 1% budget, a
  window whose bad fraction is exactly the budget burns at exactly 1.0;
  the fire/clear state machine walks a synthetic clock through breach,
  page, and recovery, emitting the slo.* spans and counters on the way.
* **The exporters round-trip** — Prometheus text exposition parses back
  under the strict parser (golden TYPE/le lines, cumulative bucket
  monotonicity, +Inf == _count), the OTLP-ish JSON keeps the
  bounds/bucketCounts shape contract, and the stdlib HTTP endpoint
  serves all three views on an ephemeral port.
* **Explain reports are deterministic** — the same query at the same
  key and generation builds a byte-identical ``deterministic_json``
  (volatile timings/maintenance/batch-id stripped), and the report's
  kept-shard / kept-bucket sets match a from-scratch recompute of the
  routing and index keep rules.
* **The server wires it together** — a forced-breach latency SLO fires
  and clears on a live server, and the config-bound HTTP endpoint
  exposes the same registry the snapshot reads.
"""

import io
import json
import time
import urllib.request

import numpy as np
import pytest

from repro.configs.knn_service import CONFIG
from repro.obs.explain import (SCHEMA as EXPLAIN_SCHEMA, deterministic_json,
                               export_jsonl)
from repro.obs.export import (ObsHttpServer, metric_name, otlp_json,
                              parse_prometheus_text, prometheus_text)
from repro.obs.metrics import MetricsRegistry, Window
from repro.obs.slo import SloEngine, SloObjective
from repro.obs.trace import NULL_TRACER, Tracer
from repro.runtime import KnnServer

DIM = 8
L_MAX = 16


# ---- sliding-window metrics ----------------------------------------------

def test_window_aggregates_on_synthetic_clock():
    w = Window()
    for t in range(10):                      # one event per second, t=0..9
        w.observe(float(t), t=float(t))
    agg = w.window(5.0, now=9.0)             # [9-5, 9] -> t in {4..9}
    assert agg["count"] == 6
    assert agg["sum"] == pytest.approx(4 + 5 + 6 + 7 + 8 + 9)
    assert agg["min"] == 4.0 and agg["max"] == 9.0
    assert agg["mean"] == pytest.approx(6.5)
    # the full horizon still holds everything
    assert w.window(100.0, now=9.0)["count"] == 10
    # an empty slice reports NaN extremes, zero count
    empty = w.window(5.0, now=100.0)
    assert empty["count"] == 0 and np.isnan(empty["min"])


def test_window_quantile_nearest_rank():
    w = Window()
    for i, v in enumerate([10.0, 20.0, 30.0, 40.0]):
        w.observe(v, t=float(i))
    assert w.quantile(0.5, 100.0, now=3.0) == 20.0
    assert w.quantile(1.0, 100.0, now=3.0) == 40.0
    assert np.isnan(w.quantile(0.5, 0.1, now=100.0))


# ---- SLO burn-rate engine ------------------------------------------------

def _engine(**kw):
    reg = MetricsRegistry()
    eng = SloEngine(
        reg, kw.pop("tracer", NULL_TRACER),
        [SloObjective("latency_p99", "upper", 0.1)],
        fast_window_s=kw.pop("fast", 10.0),
        slow_window_s=kw.pop("slow", 50.0), **kw)
    return eng, reg


def test_burn_rate_units_on_synthetic_stream():
    """With budget=0.01, bad fraction == budget burns at exactly 1.0 —
    the SRE framing: burn 1.0 spends the error budget exactly on
    schedule, and only burn > threshold pages."""
    eng, _ = _engine(budget=0.01, fast=1000.0, slow=1000.0)
    # 100 events, exactly 1 bad (0.2s > the 0.1s bound)
    for i in range(100):
        eng.measure("latency_p99", 0.2 if i == 0 else 0.01, now=float(i))
    snap = eng.snapshot(now=100.0)
    obj = snap["objectives"]["latency_p99"]
    assert obj["burn_fast"] == pytest.approx(1.0)
    assert obj["bad_fast"] == 1.0 and obj["fast_events"] == 100
    # burn == threshold does NOT fire (strict inequality)
    assert snap["alerts_fired"] == 0 and not obj["firing"]
    # double the bad fraction -> burn 2.0 -> pages
    eng.measure("latency_p99", 0.2, now=101.0)
    snap = eng.snapshot(now=101.0)
    assert snap["objectives"]["latency_p99"]["burn_fast"] == pytest.approx(
        101 / 101 * (2 / 101) / 0.01)
    assert snap["alerts_fired"] == 1


def test_fire_and_clear_walk_a_synthetic_clock():
    tracer = Tracer(capacity=64)
    eng, reg = _engine(tracer=tracer, budget=0.01)
    # 5 bad events inside both windows -> breach on both -> fire
    for i in range(5):
        eng.measure("latency_p99", 1.0, now=float(i))
    events = eng.evaluate(now=5.0)
    assert [e["event"] for e in events] == ["fire"]
    assert eng.snapshot(now=5.0)["firing"] == ["latency_p99"]
    # nothing new for 20s: the 10s fast window drains -> clear
    events = eng.evaluate(now=25.0)
    assert [e["event"] for e in events] == ["clear"]
    assert events[0]["fired_for_s"] == pytest.approx(20.0)
    snap = eng.snapshot(now=25.0)
    assert snap["alerts_fired"] == 1 and snap["alerts_cleared"] == 1
    assert snap["firing"] == []
    names = [s["name"] for s in tracer.spans()]
    assert names.count("slo.fire") == 1
    assert names.count("slo.clear") == 1
    alert = [s for s in tracer.spans() if s["name"] == "slo.alert"]
    assert len(alert) == 1
    assert alert[0]["t1"] - alert[0]["t0"] == pytest.approx(20.0)


def test_min_events_gate_blocks_thin_windows():
    eng, _ = _engine(budget=0.01)
    for i in range(3):                        # 3 < _MIN_EVENTS
        eng.measure("latency_p99", 1.0, now=float(i))
    assert eng.evaluate(now=3.0) == []
    assert eng.snapshot(now=3.0)["alerts_fired"] == 0


def test_slow_window_vetoes_a_fast_blip():
    """A burst of bad events inside the fast window only pages if the
    slow window agrees — here the slow window holds enough good history
    to keep its burn under threshold."""
    eng, _ = _engine(budget=0.05, fast=10.0, slow=50.0)
    for i in range(96):                       # 96 good events, t=0..47.5
        eng.measure("latency_p99", 0.01, now=i * 0.5)
    for i in range(4):                        # 4 bad events at the end
        eng.measure("latency_p99", 1.0, now=48.0 + i * 0.4)
    snap = eng.snapshot(now=49.9)
    obj = snap["objectives"]["latency_p99"]
    # fast window (last 10s): 4 bad of 20 -> burn 4.0, well over
    # threshold; slow window (50s): 4 bad of 100 -> burn 0.8, under
    assert obj["burn_fast"] > 1.0
    assert obj["burn_slow"] <= 1.0
    assert snap["alerts_fired"] == 0


def test_from_config_is_opt_in():
    reg = MetricsRegistry()
    assert SloEngine.from_config(CONFIG, reg, NULL_TRACER) is None
    eng = SloEngine.from_config(
        CONFIG.replace(slo_latency_p99_s=0.5, slo_contract_violations=True),
        reg, NULL_TRACER)
    snap = eng.snapshot()
    assert set(snap["objectives"]) == {"latency_p99", "contract"}
    # unknown measurements are ignored, declared ones land
    eng.measure("recall_min", 0.0)
    eng.measure("contract", 1.0)
    assert snap["objectives"]["contract"]["kind"] == "upper"
    with pytest.raises(ValueError):
        SloEngine(reg, NULL_TRACER, [])       # no objectives: use from_config
    with pytest.raises(ValueError):
        SloObjective("x", "sideways", 1.0)


# ---- exporters -----------------------------------------------------------

def _populated_registry() -> MetricsRegistry:
    reg = MetricsRegistry()
    reg.counter("serve.batches").inc(7)
    reg.gauge("store.live_points").set(123.0)
    h = reg.histogram("serve.latency_s")
    for v in (0.001, 0.002, 0.004, 0.01, 0.05, 1.5):
        h.observe(v)
    reg.window("slo.events.latency_p99").observe(1.0)   # skipped in prom
    return reg


def test_prometheus_golden_format_and_round_trip():
    reg = _populated_registry()
    text = prometheus_text(reg)
    # golden lines: naming, TYPE declarations, the counter suffix
    assert "# TYPE knn_serve_batches_total counter" in text
    assert "knn_serve_batches_total 7" in text
    assert "# TYPE knn_store_live_points gauge" in text
    assert "# TYPE knn_serve_latency_s histogram" in text
    assert 'knn_serve_latency_s_bucket{le="+Inf"} 6' in text
    assert "knn_serve_latency_s_count 6" in text
    parsed = parse_prometheus_text(text)
    assert parsed["knn_serve_batches_total"] == {
        "type": "counter", "value": 7.0}
    assert parsed["knn_store_live_points"]["value"] == 123.0
    hist = parsed["knn_serve_latency_s"]
    assert hist["count"] == 6.0
    assert hist["sum"] == pytest.approx(0.001 + 0.002 + 0.004 + 0.01
                                        + 0.05 + 1.5)
    # cumulative bucket counts are monotone non-decreasing, end at count
    counts = [c for _, c in hist["buckets"]]
    assert counts == sorted(counts)
    assert counts[-1] == hist["count"]
    # windows are an SLO-internal type, not an exposition metric
    assert not any("slo_events" in name for name in parsed)


def test_prometheus_parser_rejects_malformations():
    with pytest.raises(ValueError):           # no TYPE declaration
        parse_prometheus_text("knn_mystery 1.0\n")
    bad_cumulative = (
        "# TYPE knn_h histogram\n"
        'knn_h_bucket{le="1.0"} 5\n'
        'knn_h_bucket{le="2.0"} 3\n'          # decreasing
        'knn_h_bucket{le="+Inf"} 5\n'
        "knn_h_sum 1.0\nknn_h_count 5\n")
    with pytest.raises(ValueError):
        parse_prometheus_text(bad_cumulative)
    inf_mismatch = (
        "# TYPE knn_h histogram\n"
        'knn_h_bucket{le="1.0"} 5\n'
        'knn_h_bucket{le="+Inf"} 5\n'
        "knn_h_sum 1.0\nknn_h_count 9\n")     # +Inf != count
    with pytest.raises(ValueError):
        parse_prometheus_text(inf_mismatch)


def test_otlp_shape_contract():
    reg = _populated_registry()
    doc = otlp_json(reg)
    metrics = doc["resourceMetrics"][0]["scopeMetrics"][0]["metrics"]
    by_name = {m["name"]: m for m in metrics}
    assert by_name["knn_serve_batches_total"]["sum"]["isMonotonic"]
    pt = by_name["knn_serve_latency_s"]["histogram"]["dataPoints"][0]
    # OTLP contract: len(bucketCounts) == len(explicitBounds) + 1
    assert len(pt["bucketCounts"]) == len(pt["explicitBounds"]) + 1
    assert sum(pt["bucketCounts"]) == pt["count"] == 6
    assert pt["sum"] == pytest.approx(1.567)


def test_metric_name_mangling():
    assert metric_name("serve.latency_s") == "knn_serve_latency_s"
    assert metric_name("maint.plan-probe") == "knn_maint_plan_probe"


def test_http_server_serves_all_three_views():
    reg = _populated_registry()
    with ObsHttpServer(reg, port=0,
                       snapshot_fn=lambda: {"hello": "operator"}) as http:
        base = f"http://127.0.0.1:{http.port}"
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            assert r.headers["Content-Type"].startswith("text/plain")
            parsed = parse_prometheus_text(r.read().decode())
        assert parsed["knn_serve_batches_total"]["value"] == 7.0
        with urllib.request.urlopen(f"{base}/metrics.json", timeout=10) as r:
            doc = json.loads(r.read().decode())
        assert "resourceMetrics" in doc
        with urllib.request.urlopen(f"{base}/obs", timeout=10) as r:
            assert json.loads(r.read().decode()) == {"hello": "operator"}
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope", timeout=10)
    http.close()                              # idempotent


# ---- explain reports -----------------------------------------------------

@pytest.fixture(scope="module")
def explain_server(mesh8):
    """A tiny routed approx server over a cluster-per-shard layout —
    the configuration whose explain reports exercise every section."""
    k = 8
    rng = np.random.default_rng(3)
    centers = rng.normal(size=(k, DIM)).astype(np.float32) * 40.0
    pts = np.concatenate([
        c + rng.normal(size=(64, DIM)).astype(np.float32) for c in centers])
    cfg = CONFIG.replace(
        dim=DIM, l=4, l_max=L_MAX, bucket_sizes=(1, 2, 4),
        sampler="selection", route="pruned", search="approx",
        index_buckets=4, max_wait_ms=0.5)
    srv = KnnServer(pts, cfg=cfg, mesh=mesh8, axis_name="x")
    srv.warmup()
    yield srv, centers
    srv.stop()


def test_explain_report_sections_and_recompute(explain_server):
    srv, centers = explain_server
    q = centers[2] + 0.25
    res = srv.query_batch(np.asarray([q]), [4])[0]
    rep = res.explain()
    assert rep["schema"] == EXPLAIN_SCHEMA
    assert set(rep) == {"schema", "batch", "request", "routing", "index",
                        "predict", "timings", "maintenance"}
    assert rep["predict"] == {"enabled": False}
    assert rep["request"]["l"] == 4
    assert rep["request"]["recall_mode"] == "approx"
    assert rep["routing"]["mode"] == "pruned"
    assert len(rep["routing"]["shards"]) == 8
    kept = [s["shard"] for s in rep["routing"]["shards"] if s["kept"]]
    assert kept == rep["routing"]["kept_shards"]
    assert rep["batch"]["shards_touched"] == len(kept)
    # every kept shard's lower bound admits the threshold; every pruned
    # shard's does not — the keep rule, re-read off the report itself
    for s in rep["routing"]["shards"]:
        if s["kept"]:
            assert s["lower"] <= rep["routing"]["threshold_eff"]
        else:
            assert s["lower"] > rep["routing"]["threshold_eff"]
    assert rep["index"]["enabled"]
    assert rep["index"]["kept_matches_recompute"]
    assert rep["index"]["kept_buckets"], "approx query kept no buckets?"
    assert rep["timings"]["latency_s"] > 0.0
    assert rep["maintenance"]["commits_before"] == 0  # static server


def test_explain_determinism_byte_identical(explain_server):
    srv, centers = explain_server
    q = centers[5] - 0.125
    r1 = srv.query_batch(np.asarray([q]), [4])[0]
    r2 = srv.query_batch(np.asarray([q]), [4])[0]
    rep1, rep2 = r1.explain(), r2.explain()
    assert rep1["batch"]["id"] != rep2["batch"]["id"]   # different batches
    j1, j2 = deterministic_json(rep1), deterministic_json(rep2)
    assert j1 == j2                                     # byte-identical
    stable = json.loads(j1)
    assert "timings" not in stable and "maintenance" not in stable
    assert "id" not in stable["batch"]
    # a different query is a different stable report
    r3 = srv.query_batch(np.asarray([centers[1]]), [4])[0]
    assert deterministic_json(r3.explain()) != j1


def test_explain_last_ring_and_jsonl_export(explain_server):
    srv, centers = explain_server
    qs = np.stack([centers[i % 8] for i in range(3)]).astype(np.float32)
    srv.query_batch(qs, [4, 4, 4])
    reports = srv.explain_last(2)
    assert len(reports) == 2
    assert all(r["schema"] == EXPLAIN_SCHEMA for r in reports)
    assert srv.explain_last(0) == []
    buf = io.StringIO()
    n = export_jsonl(srv.explain_last(3), buf)
    lines = [json.loads(x) for x in buf.getvalue().splitlines()]
    assert n == len(lines) == 3
    assert all(r["schema"] == EXPLAIN_SCHEMA for r in lines)


# ---- server integration --------------------------------------------------

def test_server_forced_breach_slo_fires_and_clears(mesh8):
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(512, DIM)).astype(np.float32)
    cfg = CONFIG.replace(
        dim=DIM, l=4, l_max=L_MAX, bucket_sizes=(1, 2, 4, 8),
        sampler="selection", max_wait_ms=0.5,
        slo_latency_p99_s=1e-9,               # nothing is this fast
        slo_fast_window_s=0.3, slo_slow_window_s=0.9)
    srv = KnnServer(pts, cfg=cfg, mesh=mesh8, axis_name="x")
    srv.warmup()
    try:
        qs = rng.normal(size=(8, DIM)).astype(np.float32)
        srv.query_batch(qs, [4] * 8)          # 8 bad events in one dispatch
        snap = srv.obs_snapshot()["slo"]
        assert snap["alerts_fired"] >= 1
        assert "latency_p99" in snap["firing"]
        deadline = time.perf_counter() + 15
        while (snap["alerts_cleared"] == 0
               and time.perf_counter() < deadline):
            time.sleep(0.05)
            snap = srv.obs_snapshot()["slo"]
        assert snap["alerts_cleared"] >= 1 and snap["firing"] == []
    finally:
        srv.close()


def test_server_http_endpoint_from_config(mesh8):
    rng = np.random.default_rng(13)
    pts = rng.normal(size=(256, DIM)).astype(np.float32)
    cfg = CONFIG.replace(dim=DIM, l=4, l_max=L_MAX, bucket_sizes=(1, 2),
                         sampler="selection", obs_http_port=-1)
    srv = KnnServer(pts, cfg=cfg, mesh=mesh8, axis_name="x")
    try:
        srv.query_batch(rng.normal(size=(2, DIM)).astype(np.float32), [4, 4])
        url = f"http://127.0.0.1:{srv._http.port}/metrics"
        with urllib.request.urlopen(url, timeout=10) as r:
            parsed = parse_prometheus_text(r.read().decode())
        assert parsed["knn_serve_latency_s"]["count"] >= 2
    finally:
        srv.close()
    assert srv._http._thread is None or not srv._http._thread.is_alive()
