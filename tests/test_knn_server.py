"""Micro-batched kNN query service: bucketing/padding round-trip,
per-request l masking vs the gather baseline, the O(log l) round smoke
test under the service path, the stop() drain contract, and ServerStats
thread-safety under concurrent observe/snapshot."""

import math
import threading

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.configs.knn_service import CONFIG
from repro.parallel.compat import shard_map
from repro.runtime import KnnServer
from repro.runtime.knn_server import ServerStats

K = 8
DIM = 8
N = K * 256


@pytest.fixture(scope="module")
def pts():
    return np.random.default_rng(3).normal(size=(N, DIM)).astype(np.float32)


def _server(pts, mesh, **overrides):
    kw = dict(dim=DIM, l=8, l_max=32, bucket_sizes=(1, 2, 4, 8))
    kw.update(overrides)
    return KnnServer(pts, cfg=CONFIG.replace(**kw), mesh=mesh,
                     axis_name="x")


def _brute(points, queries, l):
    d = ((queries[:, None, :] - points[None]) ** 2).sum(-1)
    idx = np.argsort(d, axis=1, kind="stable")[:, :l]
    return np.take_along_axis(d, idx, 1), idx


def test_batched_multi_l_matches_simple(mesh8, rng, pts):
    """knn_query_batched with per-row l == knn_simple row by row."""
    l_max = 32
    ls = np.array([1, 5, 32, 17], np.int32)
    q = rng.normal(size=(4, DIM)).astype(np.float32)
    pids = np.arange(N, dtype=np.int32)

    def fn(p, i, qq, la, k):
        res = core.knn_query_batched(p, i, qq, l_max, la, k, axis_name="x")
        sd, si = core.knn_simple(p, i, qq, l_max, axis_name="x")
        return res.dists, res.ids, sd, si

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None), P(None)),
        out_specs=(P(None),) * 4))
    d, i, sd, si = f(pts, pids, q, ls, jax.random.PRNGKey(0))
    d, i, sd, si = map(np.asarray, (d, i, sd, si))
    for b, l in enumerate(ls):
        # row b's first l slots hold exactly the l nearest...
        np.testing.assert_allclose(np.sort(d[b, :l]), sd[b, :l], rtol=1e-5)
        assert set(i[b, :l].tolist()) == set(si[b, :l].tolist())
        # ...and everything past l is sentinel padding
        assert np.all(np.isinf(d[b, l:]))
        assert np.all(i[b, l:] == 2**31 - 1)


def test_batched_zero_l_rows_select_nothing(mesh8, rng, pts):
    """l=0 rows (the micro-batcher's padding) come back all-sentinel."""
    q = rng.normal(size=(3, DIM)).astype(np.float32)
    pids = np.arange(N, dtype=np.int32)
    ls = np.array([4, 0, 9], np.int32)

    def fn(p, i, qq, la, k):
        res = core.knn_query_batched(p, i, qq, 16, la, k, axis_name="x")
        return res.dists, res.ids

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None), P(None)),
        out_specs=(P(None), P(None))))
    d, i = map(np.asarray, f(pts, pids, q, ls, jax.random.PRNGKey(1)))
    assert np.all(np.isinf(d[1]))
    bd, _ = _brute(pts, q, 16)
    np.testing.assert_allclose(np.sort(d[0, :4]), bd[0, :4], rtol=1e-4)
    np.testing.assert_allclose(np.sort(d[2, :9]), bd[2, :9], rtol=1e-4)


def test_server_bucketing_and_padding_round_trip(mesh8, rng, pts):
    """Odd request counts pad to the next bucket and answers still match
    brute force per request, at each request's own l."""
    srv = _server(pts, mesh8)
    qs = rng.normal(size=(5, DIM)).astype(np.float32)
    ls = [1, 3, 32, 17, 8]
    res = srv.query_batch(qs, ls)

    assert srv.stats.queries == 5
    assert srv.stats.batches == 1
    assert srv.stats.bucket_counts == {8: 1}     # 5 -> bucket 8
    assert srv.stats.padded_rows == 3

    for r, q, l in zip(res, qs, ls):
        assert r.l == l and len(r.dists) == l and len(r.ids) == l
        bd, bi = _brute(pts, q[None], l)
        # documented contract: dists arrive ascending, no client-side sort
        np.testing.assert_allclose(r.dists, bd[0], rtol=1e-4)
        assert set(r.ids.tolist()) == set(bi[0].tolist())


def test_server_bucket_for_is_smallest_fit(mesh8, pts):
    srv = _server(pts, mesh8)
    assert srv._bucket_for(1) == 1
    assert srv._bucket_for(2) == 2
    assert srv._bucket_for(3) == 4
    assert srv._bucket_for(8) == 8
    # more pending than the largest bucket: drained in max-bucket chunks
    assert srv._bucket_for(9) == 8


def test_server_padding_no_leak(mesh8, rng, pts):
    """A query answered alone equals the same query inside a padded batch
    (padding rows and neighbors' rows must not interact)."""
    srv = _server(pts, mesh8)
    q = rng.normal(size=(DIM,)).astype(np.float32)
    alone = srv.query_batch(q[None], [16])[0]
    crowd_qs = np.stack([q] + [rng.normal(size=(DIM,)).astype(np.float32)
                               for _ in range(2)])
    crowd = srv.query_batch(crowd_qs, [16, 3, 32])[0]
    np.testing.assert_allclose(np.sort(alone.dists), np.sort(crowd.dists),
                               rtol=1e-6)
    assert set(alone.ids.tolist()) == set(crowd.ids.tolist())


def test_server_gather_baseline_agrees(mesh8, rng, pts):
    """sampler='selection' and sampler='gather' answer identically."""
    sel = _server(pts, mesh8)
    gat = _server(pts, mesh8, sampler="gather")
    qs = rng.normal(size=(4, DIM)).astype(np.float32)
    ls = [2, 32, 9, 1]
    for a, b in zip(sel.query_batch(qs, ls), gat.query_batch(qs, ls)):
        np.testing.assert_allclose(np.sort(a.dists), np.sort(b.dists),
                                   rtol=1e-5)
        assert set(a.ids.tolist()) == set(b.ids.tolist())
    # A/B accounting: gather pays its l_max-word payload in messages
    assert gat.query_batch(qs[:1], [4])[0].rounds == 1
    assert sel.query_batch(qs[:1], [4])[0].rounds > 1


def test_server_values_lookup(mesh8, rng, pts):
    vals = rng.integers(0, 50, N).astype(np.int32)
    srv = KnnServer(pts, vals,
                    cfg=CONFIG.replace(dim=DIM, l=8, l_max=16,
                                       bucket_sizes=(4,)),
                    mesh=mesh8, axis_name="x")
    q = rng.normal(size=(DIM,)).astype(np.float32)
    r = srv.query_batch(q[None], [8])[0]
    _, bi = _brute(pts, q[None], 8)
    assert sorted(r.values.tolist()) == sorted(vals[bi[0]].tolist())


def test_server_values_sentinel_slots(mesh8, rng):
    """Requests for more neighbors than finite points get -1 values in the
    sentinel slots (not an out-of-bounds lookup)."""
    n_small = K * 2
    small = rng.normal(size=(n_small, DIM)).astype(np.float32)
    vals = np.arange(n_small, dtype=np.int32)
    srv = KnnServer(small, vals,
                    cfg=CONFIG.replace(dim=DIM, l=8, l_max=32,
                                       bucket_sizes=(1,)),
                    mesh=mesh8, axis_name="x")
    r = srv.query_batch(rng.normal(size=(1, DIM)).astype(np.float32),
                        [32])[0]
    assert np.all(np.isinf(r.dists[n_small:]))
    assert np.all(r.values[n_small:] == -1)
    assert sorted(r.values[:n_small].tolist()) == vals.tolist()


def test_server_multi_axis_mesh_k_is_axis_size(mesh42, rng, pts):
    """On a multi-axis mesh only the service axis counts as k machines."""
    srv = KnnServer(pts, cfg=CONFIG.replace(dim=DIM, l=8, l_max=16,
                                            bucket_sizes=(2,)),
                    mesh=mesh42, axis_name="model")
    assert srv.k == 2
    assert srv.m_local == N // 2
    q = rng.normal(size=(DIM,)).astype(np.float32)
    r = srv.query_batch(q[None], [8])[0]
    bd, _ = _brute(pts, q[None], 8)
    np.testing.assert_allclose(np.sort(r.dists), bd[0], rtol=1e-4)


def test_server_iterations_log_l_smoke(mesh8, rng, pts):
    """Theorem 2.4 via the service path: with the Lemma 2.3 prune the
    selection runs on <= 11*l survivors, so iterations stay O(log l)
    regardless of n — checked with the repo's standard generous constant."""
    l_max = 32
    srv = _server(pts, mesh8, l_max=l_max, bucket_sizes=(8,))
    qs = rng.normal(size=(8, DIM)).astype(np.float32)
    res = srv.query_batch(qs, [l_max] * 8)
    bound = 8 * math.ceil(math.log2(11 * l_max)) + 16
    assert all(r.iterations <= bound for r in res)
    assert all(r.survivors <= 11 * l_max for r in res)


def test_server_background_batcher(mesh8, rng, pts):
    """Futures submitted while the micro-batcher thread runs resolve to
    the same answers as the synchronous path."""
    srv = _server(pts, mesh8)
    srv.warmup()
    qs = rng.normal(size=(6, DIM)).astype(np.float32)
    with srv.serving():
        futs = [srv.submit(q, 8) for q in qs]
        res = [f.result(timeout=60) for f in futs]
    for r, q in zip(res, qs):
        bd, _ = _brute(pts, q[None], 8)
        np.testing.assert_allclose(np.sort(r.dists), bd[0], rtol=1e-4)


def test_server_determinism_across_fresh_instances(mesh8, rng, pts):
    """Identical PRNG seed + identical store generation => bit-identical
    QueryResult from two fresh KnnServer instances (the dispatch-time
    snapshot-capture contract: nothing about a server's private lifetime
    — construction order, warmup, thread timing — may leak into answers)."""
    from repro.store import MutableStore
    qs = rng.normal(size=(5, DIM)).astype(np.float32)
    ls = [1, 3, 32, 17, 8]

    # static backing, one server warmed up and one not
    a, b = _server(pts, mesh8), _server(pts, mesh8)
    b.warmup()
    for ra, rb in zip(a.query_batch(qs, ls), b.query_batch(qs, ls)):
        assert ra.dists.tobytes() == rb.dists.tobytes()
        assert np.array_equal(ra.ids, rb.ids)
        assert ra.generation == rb.generation == 0

    # mutable backing: both servers share one store generation
    store = MutableStore(DIM, capacity_per_shard=64, axis_name="x")
    ids = store.insert(rng.normal(size=(200, DIM)).astype(np.float32))
    store.flush()
    store.delete(ids[::5])
    store.flush()
    kw = dict(dim=DIM, l=8, l_max=32, bucket_sizes=(1, 2, 4, 8))
    a, b = (KnnServer(store=store, cfg=CONFIG.replace(**kw), seed=0)
            for _ in range(2))
    for ra, rb in zip(a.query_batch(qs, ls), b.query_batch(qs, ls)):
        assert ra.dists.tobytes() == rb.dists.tobytes()
        assert np.array_equal(ra.ids, rb.ids)
        assert ra.generation == rb.generation == store.generation


def test_server_rejects_bad_requests(mesh8, pts):
    srv = _server(pts, mesh8)
    with pytest.raises(ValueError):
        srv.submit(np.zeros(DIM, np.float32), 0)
    with pytest.raises(ValueError):
        srv.submit(np.zeros(DIM, np.float32), srv.cfg.l_max + 1)
    with pytest.raises(ValueError):
        srv.submit(np.zeros(DIM + 1, np.float32), 4)
    with pytest.raises(ValueError, match="route_compute"):
        _server(pts, mesh8, route_compute="gpu")
    srv.flush()


# ---- stop() drain contract -----------------------------------------------

def test_server_stop_drains(mesh8, rng, pts):
    """The documented stop() contract: every request pending at stop()
    entry resolves before stop() returns, each dispatched exactly once
    (stats.queries is the double-dispatch detector — a request served
    twice would count twice), correct against brute force, and stop() is
    idempotent with submit/flush still serving synchronously after."""
    srv = _server(pts, mesh8, max_wait_ms=50.0)
    srv.warmup()
    qs = rng.normal(size=(16, DIM)).astype(np.float32)
    srv.start()
    futs = [srv.submit(q, 8) for q in qs]
    srv.stop()              # requests still lingering in the batcher
    assert all(f.done() for f in futs)
    for f, q in zip(futs, qs):
        r = f.result(timeout=0)
        bd, _ = _brute(pts, q[None], 8)
        np.testing.assert_allclose(np.sort(r.dists), bd[0], rtol=1e-4)
    assert srv.stats.queries == len(qs)
    assert srv._thread is None

    srv.stop()              # idempotent
    f = srv.submit(qs[0], 8)
    srv.flush()
    assert f.done()
    assert srv.stats.queries == len(qs) + 1


def test_server_stop_races_with_itself(mesh8, rng, pts):
    """Concurrent stop() callers: exactly one joins the thread (the
    handle is captured-and-cleared under the lock), every pending
    request resolves, and nothing dispatches twice."""
    srv = _server(pts, mesh8, max_wait_ms=20.0)
    srv.warmup()
    srv.start()
    qs = rng.normal(size=(12, DIM)).astype(np.float32)
    futs = [srv.submit(q, 4) for q in qs]
    stoppers = [threading.Thread(target=srv.stop) for _ in range(3)]
    for t in stoppers:
        t.start()
    for t in stoppers:
        t.join()
    assert srv._thread is None
    assert all(f.done() for f in futs)
    assert srv.stats.queries == len(qs)


# ---- ServerStats thread-safety -------------------------------------------

def test_server_stats_concurrent_observe_and_snapshot():
    """Regression for the unlocked observe()/placement_stats() race:
    writer threads hammer observe() while a reader takes snapshot()s,
    and every snapshot must be internally consistent — the cross-field
    invariants hold inside any single snapshot, and the final totals
    are exact (no lost updates)."""
    stats = ServerStats()
    buckets = (1, 2, 4, 8)
    per_thread = 400
    n_writers = 4
    stop = threading.Event()
    bad = []

    def reader():
        while not stop.is_set():
            s = stats.snapshot()
            if s["batches"] != sum(s["bucket_counts"].values()):
                bad.append(("batches", s))
            if s["queries"] + s["padded_rows"] != sum(
                    b * c for b, c in s["bucket_counts"].items()):
                bad.append(("rows", s))
            if s["routed_batches"] * 8 < s["touched_shards"]:
                bad.append(("touched", s))

    def writer(seed):
        wrng = np.random.default_rng(seed)
        for _ in range(per_thread):
            b = int(wrng.choice(buckets))
            n_real = int(wrng.integers(1, b + 1))
            touched = int(wrng.integers(1, 9)) if b % 2 else None
            stats.observe(b, n_real, touched=touched)

    rt = threading.Thread(target=reader)
    wts = [threading.Thread(target=writer, args=(s,))
           for s in range(n_writers)]
    rt.start()
    for t in wts:
        t.start()
    for t in wts:
        t.join()
    stop.set()
    rt.join()

    assert not bad, bad[0]
    final = stats.snapshot()
    assert final["batches"] == n_writers * per_thread
    assert final["batches"] == sum(final["bucket_counts"].values())
    assert final["queries"] + final["padded_rows"] == sum(
        b * c for b, c in final["bucket_counts"].items())
    # deterministic totals: replay each writer's seeded sequence
    want_q = want_pad = want_t = want_rb = 0
    for s in range(n_writers):
        wrng = np.random.default_rng(s)
        for _ in range(per_thread):
            b = int(wrng.choice(buckets))
            n_real = int(wrng.integers(1, b + 1))
            t = int(wrng.integers(1, 9)) if b % 2 else None
            want_q += n_real
            want_pad += b - n_real
            if t is not None:
                want_t += t
                want_rb += 1
    assert final["queries"] == want_q
    assert final["padded_rows"] == want_pad
    assert final["touched_shards"] == want_t
    assert final["routed_batches"] == want_rb


def test_server_stats_rejects_touched_sentinel():
    """Satellite of the -1 sentinel fix: QueryResult.shards_touched
    defaults to -1 ("never routed"), and a leaked sentinel must never
    enter the prune-rate inputs — it would silently *raise* the
    reported rate.  observe() counts it as invalid instead."""
    stats = ServerStats()
    stats.observe(4, 4, touched=3)
    stats.observe(4, 4, touched=-1)        # the sentinel, leaked
    stats.observe(4, 4, touched=-7)        # any negative, same treatment
    stats.observe(4, 4, touched=0)         # zero is a real observation
    s = stats.snapshot()
    assert s["touched_shards"] == 3
    assert s["routed_batches"] == 2        # touched=3 and touched=0
    assert s["invalid_touched"] == 2
    assert s["batches"] == 4               # batch counting is unaffected


@pytest.mark.parametrize("route", ["exact", "pruned"])
def test_touched_sentinel_never_served_or_observed(mesh8, pts, route):
    """Both routes end to end: every served result carries a
    non-negative shards_touched (the -1 default never escapes
    _dispatch), the prune math saw no invalid observations, and the
    serve.touched_shards histogram observed only real counts — exactly
    one per dispatched batch, k under route="exact"."""
    srv = _server(pts, mesh8, route=route)
    rng = np.random.default_rng(9)
    res = srv.query_batch(rng.normal(size=(6, DIM)).astype(np.float32),
                          [8] * 6)
    assert all(r.shards_touched >= 0 for r in res)
    if route == "exact":
        assert all(r.shards_touched == K for r in res)
    s = srv.stats.snapshot()
    assert s["invalid_touched"] == 0
    hist = srv.obs.metrics.get("serve.touched_shards").snapshot()
    assert hist["count"] == s["batches"]
    assert hist["min"] >= 0
