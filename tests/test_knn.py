"""Algorithm 2 (distributed l-NN) vs brute force, plus the simple-method
baseline, the sample-prune lemma, and the distributed vote heads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.parallel.compat import shard_map

# Property test: hypothesis-driven when installed (requirements-dev.txt),
# seeded-grid fallback otherwise — the property always runs, never skips.
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    given = None

K = 8


def _query(mesh, points, pids, queries, l, key=0, **kw):
    def fn(p, i, q, k):
        res = core.knn_query(p, i, q, l, k, axis_name="x", **kw)
        return (res.dists, res.ids, res.selection.iterations,
                res.prune.applied, res.prune.survivors)

    f = jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(P("x"), P("x"), P(None), P(None)),
        out_specs=(P(None), P(None), P(), P(None), P(None))))
    return f(points, pids, queries, jax.random.PRNGKey(key))


def _brute(points, queries, l):
    d = ((queries[:, None, :] - points[None]) ** 2).sum(-1)
    idx = np.argsort(d, axis=1, kind="stable")[:, :l]
    return np.take_along_axis(d, idx, 1), idx


def _knn_property_case(mesh8, m, dim, l, seed):
    l = min(l, K * m)
    r = np.random.default_rng(seed)
    pts = r.normal(size=(K * m, dim)).astype(np.float32)
    q = r.normal(size=(2, dim)).astype(np.float32)
    pids = np.arange(K * m, dtype=np.int32)
    d, i, iters, applied, surv = _query(mesh8, pts, pids, q, l, key=seed)
    bd, bi = _brute(pts, q, l)
    for b in range(2):
        np.testing.assert_allclose(np.sort(np.asarray(d)[b]), bd[b],
                                   rtol=1e-4, atol=1e-4)
        assert set(np.asarray(i)[b].tolist()) == set(bi[b].tolist())


if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(min_value=4, max_value=64),
        dim=st.integers(min_value=1, max_value=8),
        l=st.integers(min_value=1, max_value=24),
        seed=st.integers(min_value=0, max_value=999),
    )
    def test_knn_property(mesh8, m, dim, l, seed):
        _knn_property_case(mesh8, m, dim, l, seed)
else:
    # Seeded fallback: the same property body over a fixed grid, so the
    # guarantee is still exercised (not bare-skipped) without hypothesis.
    @pytest.mark.parametrize("m,dim,l,seed", [
        (4, 1, 1, 0), (16, 4, 7, 1), (64, 8, 24, 2),
        (5, 3, 13, 3), (32, 2, 24, 4),
    ])
    def test_knn_property(mesh8, m, dim, l, seed):
        _knn_property_case(mesh8, m, dim, l, seed)


def test_knn_matches_simple_method(mesh8, rng):
    """Algorithm 2 and the paper's gather baseline agree exactly."""
    pts = rng.normal(size=(K * 128, 16)).astype(np.float32)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    pids = np.arange(len(pts), dtype=np.int32)
    l = 32

    def fn(p, i, qq, k):
        res = core.knn_query(p, i, qq, l, k, axis_name="x")
        sd, si = core.knn_simple(p, i, qq, l, axis_name="x")
        return res.dists, res.ids, sd, si

    f = jax.jit(shard_map(
        fn, mesh=mesh8, in_specs=(P("x"), P("x"), P(None), P(None)),
        out_specs=(P(None),) * 4))
    d, i, sd, si = f(pts, pids, q, jax.random.PRNGKey(1))
    for b in range(4):
        np.testing.assert_allclose(np.sort(np.asarray(d)[b]),
                                   np.asarray(sd)[b], rtol=1e-5)
        assert set(np.asarray(i)[b].tolist()) == set(
            np.asarray(si)[b].tolist())


def test_prune_lemma_2_3(mesh8, rng):
    """Lemma 2.3: w.h.p. the prune keeps >= l and <= O(l) survivors."""
    l = 128
    pts = rng.normal(size=(K * 2048, 4)).astype(np.float32)
    q = rng.normal(size=(3, 4)).astype(np.float32)
    pids = np.arange(len(pts), dtype=np.int32)
    d, i, iters, applied, surv = _query(mesh8, pts, pids, q, l)
    surv = np.asarray(surv)
    assert np.asarray(applied).all()          # prune accepted (w.h.p. event)
    assert (surv >= l).all()                  # Las Vegas guarantee
    assert (surv <= 11 * l).all()             # Lemma 2.3 bound


def test_knn_no_sampling_path(mesh8, rng):
    pts = rng.normal(size=(K * 64, 4)).astype(np.float32)
    q = rng.normal(size=(2, 4)).astype(np.float32)
    pids = np.arange(len(pts), dtype=np.int32)
    d, i, *_ = _query(mesh8, pts, pids, q, 16, use_sampling=False)
    bd, bi = _brute(pts, q, 16)
    for b in range(2):
        np.testing.assert_allclose(np.sort(np.asarray(d)[b]), bd[b],
                                   rtol=1e-4, atol=1e-4)


def test_knn_multi_pivot(mesh8, rng):
    pts = rng.normal(size=(K * 256, 8)).astype(np.float32)
    q = rng.normal(size=(2, 8)).astype(np.float32)
    pids = np.arange(len(pts), dtype=np.int32)
    d, i, iters, *_ = _query(mesh8, pts, pids, q, 64, num_pivots=K)
    bd, bi = _brute(pts, q, 64)
    for b in range(2):
        assert set(np.asarray(i)[b].tolist()) == set(bi[b].tolist())


def test_knn_classify_and_regress(mesh8, rng):
    n, dim, l, C = K * 256, 8, 16, 5
    from repro.data import gaussian_clusters
    pts, labels = gaussian_clusters(n, dim, C, seed=1)
    q = pts[:4] + 0.01  # queries near known points
    pids = np.arange(n, dtype=np.int32)
    vals = labels.astype(np.float32)

    def fn(p, i, lab, v, qq, k):
        res = core.knn_query(p, i, qq, l, k, axis_name="x",
                             gather_results=False)
        m = p.shape[0]
        start = jax.lax.axis_index("x") * m
        rows = jnp.clip(res.local_ids - start, 0, m - 1)
        pred, hist = core.knn_classify(res.mask, lab[rows], C,
                                       axis_name="x")
        reg = core.knn_regress(res.mask, v[rows], axis_name="x")
        return pred, reg

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P("x"), P("x"), P(None), P(None)),
        out_specs=(P(None), P(None))))
    pred, reg = f(pts, pids, labels, vals, q, jax.random.PRNGKey(2))
    # oracle: brute-force vote
    bd, bi = _brute(pts, q, l)
    want = [np.bincount(labels[bi[b]], minlength=C).argmax()
            for b in range(4)]
    assert np.asarray(pred).tolist() == want
    want_reg = [labels[bi[b]].astype(np.float32).mean() for b in range(4)]
    np.testing.assert_allclose(np.asarray(reg), want_reg, rtol=1e-5)
