"""Adaptive summary maintenance (store/adaptive.py) — unit and store-level
contracts.

What this file pins (DESIGN.md Section 10):

* pivot sets are exact covers, deterministic, and tighter than the single
  aggregate ball on multi-cluster shards — without ever loosening a bound;
* the re-tightening schedule pays at most ONE shard's O(live·dim) exact
  recompute per flush, round-robin, and drives ``summary_slack`` back to
  ~0 where the purely incremental path lets it grow without bound;
* the radius-triggered split schedules a proximity re-deal through the
  existing repack machinery, cannot re-arm the tombstone/imbalance
  compactor, and cannot re-fire on a layout it already failed to improve
  (growth guard + cooldown);
* the knobs thread from KnnServiceConfig.store_kwargs() into the store
  and a mismatched store-backed pruned server fails loudly.

Answer exactness under maintenance lives in tests/test_routing.py (the
multi-pivot extension of the property harness).
"""

import numpy as np
import pytest

from repro.configs.knn_service import CONFIG
from repro.data import drifting_clusters
from repro.store import (AdaptiveMaintainer, MutableStore, build_summaries,
                         compute_pivots, evaluate, lower_bounds,
                         redeal_slack, summary_slack, upper_bounds)
from repro.runtime import KnnServer

DIM = 8
K = 8


def _two_lump_points(rng, n=128, gap=40.0):
    """Interleaved far-apart lumps: under balance placement every shard
    hosts both, the adversarial instance for single-ball summaries."""
    pts = np.empty((n, DIM), np.float32)
    pts[0::2] = (rng.normal(size=(n // 2, DIM)) + gap).astype(np.float32)
    pts[1::2] = (rng.normal(size=(n // 2, DIM)) - gap).astype(np.float32)
    return pts


# ---- pivot math ----------------------------------------------------------

def test_compute_pivots_covers_and_is_deterministic(rng):
    pts = rng.normal(scale=5.0, size=(100, DIM))
    for m in (1, 2, 4, 7):
        piv, rad, cnt = compute_pivots(pts, m)
        assert 1 <= cnt <= m
        d = np.sqrt(((pts[:, None] - piv[None, :cnt]) ** 2).sum(-1))
        # the union of balls covers: every point inside its nearest ball
        assert (d.min(1) <= rad[d.argmin(1)] + 1e-9).all()
        piv2, rad2, cnt2 = compute_pivots(pts, m)
        assert cnt2 == cnt
        assert np.array_equal(piv, piv2) and np.array_equal(rad, rad2)


def test_compute_pivots_degenerate_inputs():
    piv, rad, cnt = compute_pivots(np.zeros((0, DIM)), 4)
    assert cnt == 0
    # all-identical points: traversal stops early, one zero-radius ball
    piv, rad, cnt = compute_pivots(np.ones((10, DIM)), 4)
    assert cnt == 1 and rad[0] == 0.0


def test_multi_pivot_tightens_two_lump_shard(rng):
    """One shard holding two lumps: the aggregate ball spans the gap and
    proves nothing for a query between them; two pivot balls restore the
    bound.  Tightening is one-directional — multi-pivot lb >= single lb,
    ub <= single ub, on every query."""
    pts = _two_lump_points(rng)
    s1 = build_summaries(pts, 1)
    s2 = build_summaries(pts, 1, num_pivots=2)
    q_mid = np.zeros((1, DIM))
    assert lower_bounds(s1, q_mid)[0, 0] <= 1e-9          # inside the ball
    assert lower_bounds(s2, q_mid)[0, 0] > 30.0 ** 2      # outside both
    qs = np.concatenate([q_mid, rng.normal(scale=20.0, size=(8, DIM))])
    assert (lower_bounds(s2, qs) >= lower_bounds(s1, qs) - 1e-9).all()
    assert (upper_bounds(s2, qs) <= upper_bounds(s1, qs) + 1e-9).all()


def test_multi_pivot_bounds_sound_through_store_ops(rng):
    """Frozen adaptive summaries bracket the true per-shard extremes at
    every generation of an interleaved history, for every pivot count."""
    for m in (1, 2, 4):
        store = MutableStore(DIM, capacity_per_shard=64, axis_name="x",
                             summary_pivots=m, placement="affinity",
                             staging_size=10 ** 9)
        pts = _two_lump_points(rng, n=96)
        ids = store.insert(pts)
        store.flush()
        store.delete(ids[::3])
        keep = ids[1::3][:20]
        store.update(keep, rng.normal(size=(20, DIM)).astype(np.float32))
        store.flush()
        s = store.summaries()
        q = rng.normal(scale=10.0, size=(4, DIM))
        lb, ub = lower_bounds(s, q), upper_bounds(s, q)
        live_ids, live_pts = store.live_arrays()
        d = ((q[:, None].astype(np.float64) - live_pts[None]) ** 2).sum(-1)
        slot = np.array([store._slot_of[int(i)] for i in live_ids])
        shard = slot // store.cap
        for j in range(store.k):
            mine = shard == j
            if not mine.any():
                continue
            assert (lb[:, j] <= d[:, mine].min(1) + 1e-6).all(), (m, j)
            assert (ub[:, j] >= d[:, mine].max(1) - 1e-6).all(), (m, j)


# ---- re-tightening schedule ---------------------------------------------

def test_retighten_at_most_one_shard_per_flush(rng):
    store = MutableStore(DIM, capacity_per_shard=128, axis_name="x",
                         retighten_every=1, staging_size=10 ** 9,
                         auto_compact=False)
    ids = store.insert(rng.normal(size=(400, DIM)).astype(np.float32))
    store.flush()
    assert store.stats.retightens == 1     # every shard due; only one paid
    for i in range(5):
        store.delete(ids[i * 10:(i + 1) * 10])
        store.flush()
    assert store.stats.retightens == 6     # exactly one more per apply


def test_retighten_round_robin_serves_every_shard(rng):
    m = AdaptiveMaintainer(K, DIM, retighten_every=1)
    pts = rng.normal(size=(K * 4, DIM))
    valid = np.ones(K * 4, bool)
    for j in range(K):
        for t in range(4):
            m.insert(j, pts[j * 4 + t])
    served = []
    for _ in range(K):
        j = m.retighten_due()
        assert j is not None
        m.retighten(j, pts, valid, 4)
        served.append(j)
    assert sorted(served) == list(range(K))  # nobody starves, nobody twice
    assert m.retighten_due() is None         # all counters reset


def test_retighten_restores_slack_where_incremental_decays(rng):
    """The headline contract: under identical churn, the maintained
    store's covering slack returns to ~0 shard by shard while the
    unmaintained one's only grows."""
    def churn(store):
        ids = store.insert(
            rng_local.normal(size=(240, DIM)).astype(np.float32))
        store.flush()
        for i in range(8):
            store.delete(ids[i * 20:(i + 1) * 20])
            store.insert(
                rng_local.normal(size=(20, DIM)).astype(np.float32))
            store.flush()

    slacks = {}
    for every in (0, 1):
        rng_local = np.random.default_rng(7)   # identical stream for both
        store = MutableStore(DIM, capacity_per_shard=128, axis_name="x",
                             retighten_every=every, staging_size=10 ** 9,
                             auto_compact=False)
        churn(store)
        slacks[every] = store.summary_slack()
    assert (slacks[0] >= -1e-9).all() and (slacks[1] >= -1e-9).all()
    assert slacks[0].max() > 0.5               # incremental decay is real
    assert slacks[1].max() < slacks[0].max()   # maintenance beats it
    # a shard tightened on the very last flush is exactly tight
    assert slacks[1].min() < 1e-9


def test_summary_slack_probe_matches_rebuild(rng):
    store = MutableStore(DIM, capacity_per_shard=64, axis_name="x",
                         staging_size=10 ** 9, auto_compact=False)
    ids = store.insert(rng.normal(scale=4.0, size=(200, DIM))
                       .astype(np.float32))
    store.flush()
    store.delete(ids[::2])
    store.flush()
    assert store.summary_slack().max() > 0.0   # deletes left stale radii
    store.compact()                            # exact rebuild everywhere
    assert store.summary_slack().max() <= 1e-9
    s = store.summaries()
    direct = summary_slack(s, store._pts, store._valid, store.cap)
    assert np.allclose(direct, store.summary_slack())


# ---- split trigger -------------------------------------------------------

def _split_store(rng, **kw):
    kw.setdefault("split_cooldown", 0)
    store = MutableStore(DIM, capacity_per_shard=64, axis_name="x",
                         summary_pivots=2, split_radius_factor=1.0,
                         placement="balance", auto_compact=False, **kw)
    store.insert(_two_lump_points(rng))
    store.flush()
    return store


def test_split_fires_separates_and_does_not_refire(rng):
    store = _split_store(rng)
    assert store.stats.splits == 1
    assert store.stats.compactions == 1
    assert "split" in store.stats.last_compact_reason
    # the proximity re-deal separated the lumps: every shard's covering
    # radius is now cluster-sized, nowhere near the inter-lump gap
    assert store.summaries().radii.max() < 10.0
    # growth guard: radii did not grow since the rebuild, so further
    # flushes (even with cooldown 0) must not re-fire on the same layout
    ids, _ = store.live_arrays()
    store.delete(ids[:4])
    store.flush()
    assert store.stats.splits == 1


def test_split_respects_cooldown(rng):
    store = MutableStore(DIM, capacity_per_shard=128, axis_name="x",
                         summary_pivots=2, split_radius_factor=1.0,
                         split_cooldown=10 ** 6, placement="balance",
                         auto_compact=False, staging_size=10 ** 9)
    store.insert(_two_lump_points(rng))
    store.flush()
    assert store.stats.splits == 1      # the first split is always allowed
    store.insert(_two_lump_points(rng))
    store.flush()                       # same smear again, but inside the
    assert store.stats.splits == 1      # cooldown window: held
    assert store.stats.retightens == 0  # split config without retighten


def test_split_uses_proximity_even_with_round_robin_redeal(rng):
    """A split exists to separate clusters; it must go through the
    proximity re-deal even when compaction-time redeal is round_robin."""
    store = _split_store(rng, redeal="round_robin")
    assert store.stats.splits == 1
    _, live_pts = store.live_arrays()
    # post-split shards are lump-pure: a round-robin deal would leave
    # every shard spanning both lumps (radius ~ gap)
    assert store.summaries().radii.max() < 10.0


def test_split_cannot_rearm_compactor(rng):
    store = _split_store(rng)
    decision = evaluate(store._live, store._used, store.cap,
                        tombstone_frac=store.compact_tombstone_frac,
                        imbalance_frac=store.compact_imbalance_frac)
    assert not decision.compact
    # and the quota clamp it ran under is the compaction-safe one
    assert redeal_slack(store.placement_guard_slack,
                        store.compact_imbalance_frac, store.cap,
                        store.k) * store.k < (
        store.compact_imbalance_frac * store.cap)


def test_singleton_and_empty_shards_never_split():
    m = AdaptiveMaintainer(K, DIM, num_pivots=2, split_radius_factor=0.1)
    assert m.split_candidate() is None          # empty store
    m.insert(0, np.zeros(DIM))
    m.insert(1, np.full(DIM, 100.0))
    assert m.split_candidate() is None          # singletons only


# ---- config / server threading ------------------------------------------

def test_store_kwargs_threads_adaptive_knobs(mesh8):
    cfg = CONFIG.replace(summary_pivots=3, retighten_every=5,
                         split_radius_factor=1.5,
                         store_capacity_per_shard=8)
    store = MutableStore(4, mesh=mesh8, axis_name="x",
                         **cfg.store_kwargs())
    assert store.summary_pivots == 3
    assert store._summ.retighten_every == 5
    assert store._summ.split_radius_factor == 1.5
    ms = store.maintenance_stats()
    assert ms["summary_pivots"] == 3 and ms["retighten_every"] == 5


def test_server_rejects_pivot_mismatch_with_store(mesh8):
    store = MutableStore(DIM, capacity_per_shard=16, mesh=mesh8,
                         axis_name="x", summary_pivots=2)
    cfg = CONFIG.replace(dim=DIM, l=4, l_max=8, bucket_sizes=(1,),
                         route="pruned")          # asks for 1 pivot
    with pytest.raises(ValueError, match="sketch mismatch"):
        KnnServer(store=store, cfg=cfg, mesh=mesh8)
    KnnServer(store=store, cfg=cfg.replace(summary_pivots=2), mesh=mesh8)


def test_invalid_knobs_raise():
    with pytest.raises(ValueError, match="num_pivots"):
        AdaptiveMaintainer(K, DIM, num_pivots=0)
    with pytest.raises(ValueError, match="retighten_every"):
        AdaptiveMaintainer(K, DIM, retighten_every=-1)
    with pytest.raises(ValueError, match="split_radius_factor"):
        AdaptiveMaintainer(K, DIM, split_radius_factor=-0.5)


# ---- end-to-end under drift ----------------------------------------------

def test_drift_stream_served_identical_with_maintenance_on(mesh8):
    """The drifting-cluster workload end to end: with every maintenance
    trigger armed on both stores, a route="pruned" server agrees
    bit-identically with route="exact" at every step of the walk — the
    re-tightens and splits firing mid-stream never change an answer
    (the generator is the bench's — repro.data.drifting_clusters)."""
    cfg = CONFIG.replace(dim=DIM, l=4, l_max=16, bucket_sizes=(4,),
                         placement="affinity", redeal="proximity",
                         store_capacity_per_shard=256, summary_pivots=2,
                         retighten_every=8, split_radius_factor=1.0)
    stores = [MutableStore(DIM, mesh=mesh8, axis_name="x",
                           auto_compact=False, **cfg.store_kwargs())
              for _ in range(2)]
    ex = KnnServer(store=stores[0], cfg=cfg.replace(route="exact"),
                   mesh=mesh8)
    pr = KnnServer(store=stores[1], cfg=cfg.replace(route="pruned"),
                   mesh=mesh8)
    ids_by_step = []
    for s, (pts, centers) in enumerate(
            drifting_clusters(8, 8, DIM, steps=5, drift=6.0, seed=11)):
        step_ids = []
        for st in stores:
            step_ids.append(st.insert(pts))
            if s >= 2:
                st.delete(ids_by_step[s - 2])
            st.flush()
        assert np.array_equal(step_ids[0], step_ids[1])
        ids_by_step.append(step_ids[0])
        q = (centers[np.arange(4) % 8]
             + np.random.default_rng(s).normal(size=(4, DIM))
             ).astype(np.float32)
        ra = ex.query_batch(q, [1, 4, 16, 7])
        rb = pr.query_batch(q, [1, 4, 16, 7])
        for a, b in zip(ra, rb):
            assert a.dists.tobytes() == b.dists.tobytes()
            assert np.array_equal(a.ids, b.ids)
            assert a.generation == b.generation
    # maintenance actually ran on this stream
    assert stores[1].stats.retightens > 0
