"""Prefill + cached decode must reproduce the teacher-forced forward.

Tight tolerance for continuous-path families (dense/ssm/encdec/vlm).  MoE
families route discontinuously: a ~1e-7 numerical difference between the
cached and uncached attention path can flip a router top-k near a tie and
amplify through later layers (verified root cause: with top_k == n_experts
the error collapses to ~4e-4).  Real serving systems live with this
(train/serve dispatch divergence); we assert a loose bound and the
continuous-routing control.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import build_model

TIGHT = ["qwen2-0.5b", "xlstm-125m", "seamless-m4t-large-v2", "pixtral-12b"]
LOOSE = ["phi3.5-moe-42b-a6.6b", "jamba-1.5-large-398b"]


def _roundtrip(cfg, rng, B=2, S=16):
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = rng.normal(
            size=(B, cfg.num_prefix_embeds, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        batch["frames"] = rng.normal(
            size=(B, cfg.frontend_frames, cfg.d_model)).astype(np.float32)

    s_max = S + 8 + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    cache = api.init_cache(jax.random.PRNGKey(1), B, s_max,
                           dtype=jnp.float32)
    lg0, cache = jax.jit(lambda p, b, c: api.prefill(p, b, c))(
        params, batch, cache)
    nxt = jnp.argmax(lg0, -1).astype(jnp.int32)
    lg1, cache = jax.jit(lambda p, t, c: api.decode_step(p, t, c))(
        params, nxt, cache)

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    full, _ = jax.jit(lambda p, b: api.forward(p, b))(params, ext)
    return (float(jnp.max(jnp.abs(lg0 - full[:, -2]))),
            float(jnp.max(jnp.abs(lg1 - full[:, -1]))))


@pytest.mark.parametrize("arch", TIGHT)
def test_decode_matches_teacher_forcing_tight(arch, rng):
    cfg = configs.get(arch).reduced()
    e0, e1 = _roundtrip(cfg, rng)
    assert e0 < 5e-4, f"prefill mismatch {e0}"
    assert e1 < 5e-3, f"decode mismatch {e1}"


@pytest.mark.parametrize("arch", LOOSE)
def test_decode_matches_teacher_forcing_moe(arch, rng):
    cfg = dataclasses.replace(configs.get(arch).reduced(),
                              capacity_factor=8.0)
    e0, e1 = _roundtrip(cfg, rng)
    assert e0 < 5e-3, f"prefill mismatch {e0}"
    assert e1 < 0.2, f"decode mismatch beyond routing-flip scale: {e1}"


def test_moe_decode_continuous_routing_control(rng):
    """With top_k == n_experts routing is continuous: error collapses."""
    cfg = configs.get("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(cfg, moe_top_k=cfg.n_experts,
                              capacity_factor=8.0)
    e0, e1 = _roundtrip(cfg, rng)
    assert e1 < 5e-3, e1
