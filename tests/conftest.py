"""Shared fixtures for the test suite.

Device count: the k-machine-model tests (selection / knn / topk) need a
multi-shard mesh, so we ask the CPU platform for 8 placeholder devices —
deliberately NOT the dry-run's 512 (launch/dryrun.py sets its own flag in
its own process; smoke tests here are mesh-free and indifferent to the
host device count).
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("REPRO_KERNEL_MODE", "interpret")

import jax  # noqa: E402,F401  (import order: flags first)
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.parallel.compat import make_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return make_mesh((8,), ("x",))


@pytest.fixture(scope="session")
def mesh42():
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
