"""End-to-end behaviour tests: the paper's pipeline inside the LM stack.

1. kNN-LM serving: a reduced LM decodes with its logits interpolated
   against a sharded datastore retrieved via Algorithm 2 — every piece of
   the paper (local top-l, sample-prune, distributed selection, sparse
   combine) in one running system.
2. Distributed-selection sampler end-to-end under a (data, model) mesh.
3. The standalone l-NN service path used by launch/serve.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
import repro.core as core
from repro.models import build_model
from repro.models import sharding as shd
from repro.runtime import ServeConfig, Server
from repro.parallel.compat import set_mesh, shard_map


def test_knn_lm_end_to_end(mesh8, rng):
    """LM logits + Algorithm-2 retrieval -> valid mixed distribution."""
    V = 8 * 32                       # sharded vocab
    dm, N, l = 16, 8 * 256, 8
    keys = rng.normal(size=(N, dm)).astype(np.float32)
    values = rng.integers(0, V, size=(N,)).astype(np.int32)
    h = rng.normal(size=(2, dm)).astype(np.float32)
    lm_logits = rng.normal(size=(2, V)).astype(np.float32)

    def step(kk, vv, hh, lml, key):
        store = core.datastore.build_local(kk, vv, axis_name="x")
        ret = core.datastore.retrieve(store, hh, l, key, axis_name="x")
        mixed = core.datastore.interp_logits(lml, ret, 0.5, axis_name="x")
        tok = core.topk_sample(mixed, 8, 0.7, jax.random.fold_in(key, 9),
                               axis_name="x")
        return mixed, tok

    f = jax.jit(shard_map(
        step, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None, "x"), P(None)),
        out_specs=(P(None, "x"), P(None)), check_vma=False))
    mixed, tok = f(keys, values, h, lm_logits, jax.random.PRNGKey(0))
    p = np.exp(np.asarray(mixed))
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-3)
    assert tok.shape == (2,)
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < V).all()


def test_lm_generation_with_selection_sampler(mesh42, rng):
    cfg = configs.get("qwen2-0.5b").reduced()
    api = build_model(cfg)
    with set_mesh(mesh42):
        params = api.init_params(jax.random.PRNGKey(0))
        specs = api.param_specs()
        params = jax.tree.map(
            lambda x, s: jax.device_put(
                x, NamedSharding(mesh42, shd.divisible(s, x.shape, mesh42))),
            params, specs)
        batch = {"tokens": rng.integers(0, cfg.vocab, (4, 8)).astype(
            np.int32)}
        srv = Server(api, params, ServeConfig(max_seq=32, top_k=16,
                                              sampler="selection"),
                     mesh=mesh42, cache_dtype=jnp.float32)
        gen, stats = srv.generate(batch, 6, key=jax.random.PRNGKey(1))
        srv2 = Server(api, params, ServeConfig(max_seq=32, top_k=16,
                                               sampler="gather"),
                      mesh=mesh42, cache_dtype=jnp.float32)
        gen2, _ = srv2.generate(batch, 6, key=jax.random.PRNGKey(1))
    assert gen.shape == (4, 6)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()
    # paper sampler and gather baseline agree token-for-token (same key,
    # same winner set)
    np.testing.assert_array_equal(gen, gen2)


def test_knn_service_path(mesh8, rng):
    """The quickstart/serve.py service: classification over clusters."""
    from repro.data import gaussian_clusters
    n, dim, C, l = 8 * 512, 8, 4, 16
    pts, labels = gaussian_clusters(n, dim, C, seed=2)
    pids = np.arange(n, dtype=np.int32)
    centers_q = np.stack([pts[labels == c][:3].mean(0) for c in range(C)])

    def fn(p, i, lab, q, key):
        res = core.knn_query(p, i, q, l, key, axis_name="x",
                             gather_results=False)
        m = p.shape[0]
        start = jax.lax.axis_index("x") * m
        rows = jnp.clip(res.local_ids - start, 0, m - 1)
        pred, _ = core.knn_classify(res.mask, lab[rows], C, axis_name="x")
        return pred

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P("x"), P(None), P(None)),
        out_specs=P(None)))
    pred = f(pts, pids, labels, centers_q.astype(np.float32),
             jax.random.PRNGKey(0))
    # cluster centers must classify to their own cluster
    assert np.asarray(pred).tolist() == list(range(C))
