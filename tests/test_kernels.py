"""Per-kernel shape/dtype sweeps against the ref.py oracles (interpret mode).

Contract (repo deliverable c): for each Pallas kernel, sweep shapes and
dtypes and assert_allclose against the pure-jnp oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.distance_topk import distance_topk as dtk_kernel
from repro.kernels.l2_distance import l2_distance as l2_kernel
from repro.kernels.local_topk import local_topk as ltk_kernel

SHAPES = [  # (B, d, m)
    (8, 128, 256),
    (16, 256, 512),
    (1, 512, 1024),
    (13, 300, 777),     # padding path
    (4, 64, 96),        # padding path
]
DTYPES = [np.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=1.0) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_l2_distance_sweep(rng, shape, dtype):
    B, d, m = shape
    q = rng.normal(size=(B, d)).astype(np.float32).astype(dtype)
    p = rng.normal(size=(m, d)).astype(np.float32).astype(dtype)
    out = ops.l2_distance(q, p)
    want = ref.l2_distance_ref(q, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("l", [1, 16, 100])
def test_distance_topk_sweep(rng, shape, dtype, l):
    B, d, m = shape
    l = min(l, m)
    q = rng.normal(size=(B, d)).astype(np.float32).astype(dtype)
    p = rng.normal(size=(m, d)).astype(np.float32).astype(dtype)
    v, i = ops.distance_topk(q, p, l)
    rv, ri = ref.distance_topk_ref(q, p, l)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), **_tol(dtype))
    if dtype == np.float32:  # id sets only well-defined without bf16 ties
        for b in range(B):
            assert set(np.asarray(i)[b].tolist()) == set(
                np.asarray(ri)[b].tolist()), b


@pytest.mark.parametrize("shape", [(8, 512), (5, 1000), (16, 4096)])
@pytest.mark.parametrize("l", [1, 7, 128])
def test_local_topk_sweep(rng, shape, l):
    B, m = shape
    l = min(l, m)
    x = rng.normal(size=(B, m)).astype(np.float32)
    v, i = ops.local_topk(x, l)
    rv, ri = ref.local_topk_ref(x, l)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-5)
    assert (np.asarray(i) == np.asarray(ri)).all()


def test_duplicate_values_stable(rng):
    """Tie-break parity with lax.top_k (smaller index wins)."""
    x = np.round(rng.normal(size=(4, 512)), 1).astype(np.float32)
    v, i = ops.local_topk(x, 32)
    rv, ri = ref.local_topk_ref(x, 32)
    assert (np.asarray(i) == np.asarray(ri)).all()


def test_direct_kernel_blocks(rng):
    """Exercise non-default BlockSpec tilings on the raw kernels."""
    q = rng.normal(size=(16, 256)).astype(np.float32)
    p = rng.normal(size=(512, 256)).astype(np.float32)
    for bb, bm, bk in [(8, 128, 128), (16, 256, 256), (8, 512, 128)]:
        out = l2_kernel(q, p, block_b=bb, block_m=bm, block_k=bk,
                        interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.l2_distance_ref(q, p)),
                                   rtol=1e-4, atol=1e-3)
        v, i = dtk_kernel(q, p, 16, block_b=bb, block_m=bm, block_k=bk,
                          interpret=True)
        rv, _ = ref.distance_topk_ref(q, p, 16)
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                                   rtol=1e-4, atol=1e-3)
    x = rng.normal(size=(8, 1024)).astype(np.float32)
    for bb, bm in [(8, 256), (4, 512)]:
        v, i = ltk_kernel(x, 16, block_b=bb, block_m=bm, interpret=True)
        rv, ri = ref.local_topk_ref(x, 16)
        np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-5)


def test_oracle_fallback_large_l(rng):
    """l > MAX_L must route to the oracle transparently."""
    q = rng.normal(size=(4, 64)).astype(np.float32)
    p = rng.normal(size=(2048, 64)).astype(np.float32)
    v, i = ops.distance_topk(q, p, 512)
    rv, ri = ref.distance_topk_ref(q, p, 512)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("l", [255, 256, 257])
def test_specialization_envelope_boundary(rng, l):
    """The l <= MAX_L (256) fused kernel and the l2_distance + lax.top_k
    fallback must agree across the routing seam: l = 255 and 256 run the
    kernel, 257 silently falls back — all three must match the oracle."""
    from repro.kernels.distance_topk import MAX_L
    assert MAX_L == 256            # the seam this test pins
    B, d, m = 4, 32, 512
    q = rng.normal(size=(B, d)).astype(np.float32)
    p = rng.normal(size=(m, d)).astype(np.float32)
    # routing truth, straight from the dispatcher's own gate
    _, reason = ops._fused_gate(l, d, 8, 256, 512)
    assert (reason is None) == (l <= MAX_L)
    v, i = ops.distance_topk(q, p, l)
    rv, ri = ref.distance_topk_ref(q, p, l)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4,
                               atol=1e-3)
    for b in range(B):
        assert set(np.asarray(i)[b].tolist()) == set(
            np.asarray(ri)[b].tolist()), b


@pytest.mark.parametrize("shape", [(8, 128, 256), (13, 300, 777)])
@pytest.mark.parametrize("l", [1, 16])
def test_masked_distance_topk_sweep(rng, shape, l):
    """The fused kernel's masked path (mutable-store tombstones) against
    the masked oracle: masked rows never appear, sentinel ids in +inf
    slots."""
    B, d, m = shape
    q = rng.normal(size=(B, d)).astype(np.float32)
    p = rng.normal(size=(m, d)).astype(np.float32)
    valid = rng.random(m) > 0.4
    v, i = ops.distance_topk(q, p, l, valid=valid)
    rv, ri = ref.masked_distance_topk_ref(q, p, valid, l)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-4,
                               atol=1e-3)
    dead = set(np.flatnonzero(~valid).tolist())
    for b in range(B):
        got = set(np.asarray(i)[b].tolist())
        assert got == set(np.asarray(ri)[b].tolist()), b
        assert not (got & dead), "tombstoned id surfaced"


def test_masked_distance_topk_all_invalid(rng):
    """Fully-masked store shard: all +inf distances, all sentinel ids."""
    q = rng.normal(size=(4, 64)).astype(np.float32)
    p = rng.normal(size=(256, 64)).astype(np.float32)
    v, i = ops.distance_topk(q, p, 8, valid=np.zeros(256, bool))
    assert np.all(np.isinf(np.asarray(v)))
    assert np.all(np.asarray(i) == 2**31 - 1)


def test_masked_l2_distance(rng):
    q = rng.normal(size=(8, 128)).astype(np.float32)
    p = rng.normal(size=(256, 128)).astype(np.float32)
    valid = rng.random(256) > 0.5
    out = np.asarray(ops.l2_distance(q, p, valid=valid))
    want = np.asarray(ref.masked_l2_distance_ref(q, p, valid))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-3)
    assert np.all(np.isinf(out[:, ~valid]))
