"""The in-shard approximate search index (store/index.py, DESIGN.md §13).

What this suite pins:

* **Maintainer exactness** — unlike the routing summaries' undercount
  credits, the bucket index knows each slot's bucket, so under arbitrary
  insert/delete/update churn every live slot stays assigned, per-bucket
  live counts stay *exact* (equal to a bincount oracle), and every ball
  keeps covering its members.
* **The exactness anchor** — with ``oversample`` large enough that the
  cumulative-live walk never reaches its target, ``bucket_keep`` keeps
  every live bucket and the candidate mask equals the valid mask, so a
  ``search="approx"`` server answers *bit-identically* to the exact
  collective on every route/compute mode.
* **The serving contract** — on clustered workloads the approx tier
  prunes candidates (fraction well below 1) while measured recall@l
  against an exact twin stays at/above the floor; answers are tagged
  ``recall_mode="approx"`` and the shadow recall audit stays clean.
* **Generation coupling** — ``serving_snapshot()`` hands out snapshot,
  summaries, and index with equal generations across flushes, repacks,
  and background maintenance; a store/config bucket-knob conflict fails
  at construction like the routing-sketch mismatch.
"""

import numpy as np
import pytest

from repro.configs.knn_service import CONFIG
from repro.runtime import KnnServer
from repro.store import MutableStore
from repro.store.index import (IndexMaintainer, bucket_keep,
                               candidate_fraction, candidate_mask)

DIM = 8
L_MAX = 16
_SENT = 2**31 - 1


# ---- maintainer invariants ----------------------------------------------

def _check_invariants(m, pts, valid):
    """The maintainer's exactness contract against brute-force oracles."""
    k, cap, b = m.k, m.cap, m.num_buckets
    idx = m.freeze(0)
    # every live slot assigned, every dead slot unassigned
    assert ((idx.assign >= 0) == valid).all()
    for j in range(k):
        sl = slice(j * cap, (j + 1) * cap)
        a = idx.assign[sl][valid[sl]]
        # exact live counts: a bincount over the true assignment
        oracle = np.bincount(a, minlength=b) if a.size else np.zeros(b, int)
        assert (idx.live[j] == oracle).all(), j
        # assignment only to occupied bucket slots
        assert (a < idx.count[j]).all()
        # covering: each member within its ball (+ tiny float slack)
        mine = np.flatnonzero(valid[sl])
        for slot, t in zip(mine, idx.assign[sl][valid[sl]]):
            d = np.sqrt(((pts[sl][slot] - idx.centers[j, t]) ** 2).sum())
            assert d <= idx.radii[j, t] * (1 + 1e-9) + 1e-9, (j, t)


def test_maintainer_exact_live_under_churn(rng):
    k, cap, b = 4, 48, 3
    m = IndexMaintainer(k, cap, dim=DIM, num_buckets=b)
    pts = np.zeros((k * cap, DIM))
    valid = np.zeros(k * cap, bool)
    for step in range(600):
        op = rng.integers(0, 3)
        slot = int(rng.integers(0, k * cap))
        p = rng.normal(scale=10.0, size=DIM)
        if op == 0 and not valid[slot]:           # insert into free slot
            m.insert(slot // cap, slot, p)
            pts[slot], valid[slot] = p, True
        elif op == 1 and valid[slot]:             # delete
            m.delete(slot)
            valid[slot] = False
        elif op == 2 and valid[slot]:             # in-place update
            m.update(slot, p)
            pts[slot] = p
    _check_invariants(m, pts, valid)
    # an exact rebuild restores the same invariants from scratch
    m.rebuild(pts, valid)
    _check_invariants(m, pts, valid)
    with pytest.raises(ValueError):
        IndexMaintainer(k, cap, DIM, num_buckets=0)


def test_bucket_keep_anchor_padding_and_shard_gate(rng):
    k, cap, b = 4, 32, 4
    m = IndexMaintainer(k, cap, dim=DIM, num_buckets=b)
    pts = rng.normal(scale=20.0, size=(k * cap, DIM))
    valid = rng.random(k * cap) < 0.7
    m.rebuild(pts, valid)
    idx = m.freeze(3)
    q = rng.normal(scale=20.0, size=(3, DIM))
    ls = np.array([4, 0, 4])
    occ = ((np.arange(b)[None, :] < idx.count[:, None]) & (idx.live > 0))

    # exactness anchor: an unreachable target keeps every live bucket,
    # and the slot mask degenerates to the valid mask (frac == 1.0)
    keep = bucket_keep(idx, q, ls, oversample=1e9)
    assert (keep[0] == occ).all() and (keep[2] == occ).all()
    assert (candidate_mask(idx, keep.any(axis=0), cap) == valid).all()
    assert candidate_fraction(idx, keep.any(axis=0)) == 1.0

    # padding rows (l=0) keep nothing
    assert not keep[1].any()

    # routing gate: a shard the router dropped contributes no buckets
    sk = np.ones((3, k), bool)
    sk[:, 2] = False
    keep_g = bucket_keep(idx, q, ls, shard_keep=sk, oversample=1e9)
    assert not keep_g[:, 2].any()
    assert (keep_g[0, :2] == occ[:2]).all()

    # finite oversample on clustered data actually prunes
    far = np.concatenate([rng.normal(loc=200.0, scale=0.5,
                                     size=(k * cap // 2, DIM)),
                          rng.normal(loc=-200.0, scale=0.5,
                                     size=(k * cap - k * cap // 2, DIM))])
    m.rebuild(far, np.ones(k * cap, bool))
    idx2 = m.freeze(4)
    q2 = np.full((1, DIM), 200.0)
    keep2 = bucket_keep(idx2, q2, np.array([4]), oversample=2.0)
    frac = candidate_fraction(idx2, keep2.any(axis=0))
    assert frac < 0.9                      # the far half was dropped


# ---- serving: the approx tier end to end --------------------------------

def _mk_cfg(**kw):
    base = dict(dim=DIM, l=4, l_max=L_MAX, bucket_sizes=(4,),
                sampler="selection")
    base.update(kw)
    return CONFIG.replace(**base)


def _clustered(rng, k=8, per_shard=24, scale=50.0):
    centers = rng.normal(size=(k, DIM)) * scale
    pts = (centers[:, None, :]
           + rng.normal(size=(k, per_shard, DIM))).reshape(-1, DIM)
    return pts.astype(np.float32), centers


@pytest.mark.parametrize("route,compute", [("exact", "host"),
                                           ("pruned", "host"),
                                           ("pruned", "device")])
def test_huge_oversample_bit_identical_to_exact(mesh8, rng, route, compute):
    """The serving-level exactness anchor, on every route/compute mode:
    search="approx" with an unreachable oversample target is
    byte-identical to the search="exact" twin (same points, same keys).
    """
    pts, centers = _clustered(rng)
    kw = dict(route=route, route_compute=compute)
    se = KnnServer(pts, cfg=_mk_cfg(**kw), mesh=mesh8, axis_name="x")
    sa = KnnServer(pts, cfg=_mk_cfg(search="approx", index_buckets=4,
                                    index_oversample=1e9, **kw),
                   mesh=mesh8, axis_name="x")
    qs = (centers[[0, 3, 5]]
          + rng.normal(size=(3, DIM))).astype(np.float32)
    re_ = se.query_batch(qs, [4, 2, 4])
    ra = sa.query_batch(qs, [4, 2, 4])
    for a, b in zip(re_, ra):
        assert a.dists.tobytes() == b.dists.tobytes()
        assert a.ids.tobytes() == b.ids.tobytes()
        assert a.recall_mode == "exact" and b.recall_mode == "approx"


@pytest.mark.parametrize("route,compute", [("exact", "host"),
                                           ("pruned", "host"),
                                           ("pruned", "device")])
def test_approx_recall_floor_and_candidate_reduction(mesh8, rng, route,
                                                     compute):
    """The measured contract on a clustered workload: recall@l against
    the exact twin stays >= the floor while the candidate fraction
    drops well below 1 — the tier prunes without (measurably) lying.
    The shadow recall audit sees the same thing live."""
    pts, centers = _clustered(rng)
    kw = dict(route=route, route_compute=compute)
    se = KnnServer(pts, cfg=_mk_cfg(**kw), mesh=mesh8, axis_name="x")
    sa = KnnServer(pts, cfg=_mk_cfg(search="approx", index_buckets=4,
                                    obs_audit_every=1, **kw),
                   mesh=mesh8, axis_name="x")
    sa.warmup()
    recalls = []
    for wave in range(4):
        qs = (centers[[wave, wave + 2, wave + 4]]
              + rng.normal(size=(3, DIM))).astype(np.float32)
        re_ = se.query_batch(qs, [4] * 3)
        ra = sa.query_batch(qs, [4] * 3)
        for a, b in zip(re_, ra):
            truth = set(a.ids[a.ids != _SENT].tolist())
            recalls.append(len(truth & set(b.ids.tolist()))
                           / max(len(truth), 1))
    assert min(recalls) >= 0.95, recalls
    snap = sa.obs_snapshot()
    cf = snap["metrics"]["serve.candidate_fraction"]
    assert cf["count"] >= 4
    assert cf["mean"] < 0.75               # clusters actually pruned
    shadow = snap["audit"]["shadow"]
    assert shadow["mode"] == "recall" and shadow["checks"] >= 4
    assert shadow["divergences"] == 0
    assert shadow["recall"]["min"] >= 0.95


def test_store_backed_generation_coupling_through_churn(mesh8, rng):
    """serving_snapshot() hands out (snapshot, summaries, index) with
    equal generations across flushes, tombstone-triggered repacks, and
    the adaptive maintainer's hooks; served answers keep the measured
    recall through the churn; an index-knob conflict fails loudly."""
    cfg = _mk_cfg(search="approx", index_buckets=4, route="pruned",
                  obs_audit_every=1, store_capacity_per_shard=96,
                  store_staging_size=32, summary_pivots=2,
                  retighten_every=4, store_compact_tombstone_frac=0.3)
    store = MutableStore(DIM, mesh=mesh8, axis_name="x",
                         **cfg.store_kwargs())
    assert store.index_buckets == 4
    srv = KnnServer(store=store, cfg=cfg)
    pts, centers = _clustered(rng, per_shard=40)
    store.insert(pts)
    store.flush()
    gens = set()
    for phase in range(3):
        snap, summ, idx = store.serving_snapshot()
        assert idx.generation == snap.generation == summ.generation
        gens.add(snap.generation)
        qs = (centers[[phase, phase + 3]]
              + rng.normal(size=(2, DIM))).astype(np.float32)
        for r in srv.query_batch(qs, [4, 4]):
            assert r.recall_mode == "approx"
            assert r.generation == snap.generation
        # heavy deletes push past the tombstone trigger -> repack ->
        # index rebuilt at the new generation
        live = store.live_arrays()[0]
        store.delete(rng.permutation(live)[:len(live) // 3])
        store.flush()
    assert len(gens) == 3                  # churn really swapped epochs
    assert srv.obs_snapshot()["audit"]["shadow"]["divergences"] == 0

    with pytest.raises(ValueError, match="index mismatch"):
        KnnServer(store=store, cfg=cfg.replace(index_buckets=7))
    # an exact-search server on an indexed store is fine (ignores it)
    exact_srv = KnnServer(store=store, cfg=cfg.replace(search="exact"))
    assert exact_srv.query_batch(centers[:1], [4])[0].recall_mode == "exact"
    store.close()


def test_search_knob_validation():
    with pytest.raises(ValueError, match="search"):
        KnnServer(np.zeros((8, DIM), np.float32),
                  cfg=_mk_cfg(search="fuzzy"))
    with pytest.raises(ValueError, match="index_buckets"):
        KnnServer(np.zeros((8, DIM), np.float32),
                  cfg=_mk_cfg(search="approx", index_buckets=0))
