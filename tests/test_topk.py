"""Distributed top-k over a sharded axis (the vocab sampler) vs lax.top_k."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.parallel.compat import shard_map

K = 8


def _run_topk(mesh, logits, k, method, key=0, num_pivots=1):
    def fn(lg, kk):
        r = core.distributed_topk(lg, k, kk, axis_name="x", method=method,
                                  num_pivots=num_pivots)
        return r.values, r.indices, r.iterations

    f = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(P(None, "x"), P(None)),
        out_specs=(P(None), P(None), P())))
    return f(logits, jax.random.PRNGKey(key))


@pytest.mark.parametrize("method", ["selection", "gather"])
@pytest.mark.parametrize("k", [1, 13, 64])
def test_topk_vs_oracle(mesh8, rng, method, k):
    V = K * 512
    logits = rng.normal(size=(3, V)).astype(np.float32)
    v, i, iters = _run_topk(mesh8, logits, k, method)
    for b in range(3):
        want_i = np.argsort(-logits[b], kind="stable")[:k]
        np.testing.assert_allclose(np.asarray(v)[b], logits[b][want_i],
                                   rtol=1e-6)
        assert set(np.asarray(i)[b].tolist()) == set(want_i.tolist())
        # descending order contract
        assert (np.diff(np.asarray(v)[b]) <= 1e-7).all()


def test_topk_methods_agree(mesh8, rng):
    V = K * 256
    logits = rng.normal(size=(2, V)).astype(np.float32)
    v1, i1, _ = _run_topk(mesh8, logits, 32, "selection")
    v2, i2, _ = _run_topk(mesh8, logits, 32, "gather")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_topk_sample_spmd_coherent(mesh8, rng):
    """Every shard must emit the same sampled token (shared key)."""
    V = K * 256
    logits = rng.normal(size=(4, V)).astype(np.float32)

    def fn(lg, kk):
        t = core.topk_sample(lg, 16, 0.7, kk, axis_name="x")
        # gather from all shards to verify identity
        return jax.lax.all_gather(t, "x")

    f = jax.jit(shard_map(
        fn, mesh=mesh8, in_specs=(P(None, "x"), P(None)),
        out_specs=P(None, "x") if False else P("x"), check_vma=False))
    all_t = np.asarray(f(logits, jax.random.PRNGKey(5)))
    all_t = all_t.reshape(K, -1)
    assert (all_t == all_t[0]).all()


def test_topk_sample_within_topk(mesh8, rng):
    V = K * 128
    logits = rng.normal(size=(8, V)).astype(np.float32)

    def fn(lg, kk):
        return core.topk_sample(lg, 8, 1.0, kk, axis_name="x")

    f = jax.jit(shard_map(
        fn, mesh=mesh8, in_specs=(P(None, "x"), P(None)),
        out_specs=P(None), check_vma=False))
    for s in range(5):
        toks = np.asarray(f(logits, jax.random.PRNGKey(s)))
        for b in range(8):
            top8 = set(np.argsort(-logits[b])[:8].tolist())
            assert int(toks[b]) in top8


def test_greedy_sample(mesh8, rng):
    V = K * 64
    logits = rng.normal(size=(5, V)).astype(np.float32)

    def fn(lg):
        return core.greedy_sample(lg, axis_name="x")

    f = jax.jit(shard_map(fn, mesh=mesh8, in_specs=P(None, "x"),
                              out_specs=P(None)))
    got = np.asarray(f(logits))
    assert (got == np.argmax(logits, -1)).all()
