"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
family-preserving config, run one forward and one train step on CPU,
assert output shapes and the absence of NaNs.  The FULL configs are
exercised only by the allocation-free dry-run (launch/dryrun.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import build_model
from repro.optim import AdamW
from repro.runtime import TrainConfig, init_opt_state, make_train_step

ARCHS = [
    "qwen2.5-14b", "qwen1.5-4b", "qwen2-0.5b", "yi-6b",
    "phi3.5-moe-42b-a6.6b", "granite-moe-3b-a800m", "jamba-1.5-large-398b",
    "pixtral-12b", "seamless-m4t-large-v2", "xlstm-125m",
]


def _batch(cfg, rng, B=2, S=16):
    b = {"tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
         "labels": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
    if cfg.family == "vlm":
        b["prefix_embeds"] = rng.normal(
            size=(B, cfg.num_prefix_embeds, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        b["frames"] = rng.normal(
            size=(B, cfg.frontend_frames, cfg.d_model)).astype(np.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch, rng):
    cfg = configs.get(arch).reduced()
    api = build_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)

    # forward: shapes + finiteness
    logits, aux = jax.jit(lambda p, b: api.forward(p, b))(params, batch)
    S_out = S + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    # one optimizer step: loss finite, params move
    tcfg = TrainConfig(grad_accum=1, peak_lr=1e-3, warmup_steps=1,
                       total_steps=10)
    optimizer = AdamW()
    opt_state = init_opt_state(api, tcfg, optimizer, params)
    step = jax.jit(make_train_step(api, tcfg, optimizer))
    new_params, _, m = step(params, opt_state, batch)
    assert bool(jnp.isfinite(m["loss"])), "NaN loss"
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0, "train step did not update parameters"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_structure(arch):
    """The FULL (unreduced) config must build its parameter tree abstractly
    (ShapeDtypeStructs — no allocation) with the published dimensions."""
    cfg = configs.get(arch)
    api = build_model(cfg)
    shapes = api.param_shapes()
    leaves = jax.tree.leaves(shapes)
    assert all(hasattr(x, "shape") for x in leaves)
    n_params = sum(np.prod(x.shape) for x in leaves)
    # sanity: within 2x of the analytic count (stacking layout included)
    analytic = cfg.param_count()
    assert 0.5 < n_params / analytic < 2.0, (n_params, analytic)


def test_reduced_preserves_family():
    for arch in ARCHS:
        cfg = configs.get(arch)
        red = cfg.reduced()
        assert red.family == cfg.family
        assert (red.n_experts > 0) == (cfg.n_experts > 0)
        assert (red.attn_period == cfg.attn_period)
        assert red.is_encdec == cfg.is_encdec
