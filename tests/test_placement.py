"""Locality-aware placement (store/placement.py) — the invariants.

Three contracts under test (DESIGN.md Section 9):

* **Placement never changes answers.**  Where a point lives only decides
  how much routing can prune, never what the service returns: under
  interleaved insert/delete/update/compact histories, answers stay
  bit-identical across ``placement`` in {balance, affinity} x ``redeal``
  in {round_robin, proximity}, and identical to ``route="exact"``.
* **The affinity guardrail bounds skew.**  Insert-only histories keep
  ``max_live - min_live <= guard_slack + 1`` after every flush — the
  balance condition (Duan/Qiao/Cheng) the policy may never trade away
  for locality.
* **Proximity re-deal preserves the repack contract.**  Ids stable,
  dense per-shard prefixes, quota-bounded balance, deterministic — and
  cluster-coherent where round-robin smears.

Property-based via hypothesis when installed (requirements-dev.txt);
otherwise the same case bodies run over a seeded parameter grid, so the
properties are exercised either way (never bare-skipped).
"""

import numpy as np
import pytest

from repro.configs.knn_service import CONFIG
from repro.data import sharded_clusters
from repro.runtime import KnnServer
from repro.store import (AffinityPlacement, BalancePlacement, MutableStore,
                         PlacementView, make_placement, repack_proximity,
                         route_shards)
from repro.store.placement import lloyd_centroids

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    given = None

K = 8
DIM = 8
CAP = 128
B = 4
L_MAX = 256
COMBOS = (("balance", "round_robin"), ("balance", "proximity"),
          ("affinity", "round_robin"), ("affinity", "proximity"))


def _view(live, used, cap, centroids=None, radii=None):
    k = len(live)
    live = np.asarray(live, np.int64)
    used = np.asarray(used, np.int64)
    if centroids is None:
        centroids = np.zeros((k, DIM))
        occupied = np.zeros(k, bool)
    else:
        centroids = np.asarray(centroids, np.float64)
        occupied = live > 0
    radii = np.zeros(k) if radii is None else np.asarray(radii, np.float64)
    return PlacementView(live=live, used=used, cap=cap, centroids=centroids,
                         radii=radii, occupied=occupied)


# ---- answers are placement-invariant (the tentpole property) -------------

def test_answers_bit_identical_across_placement_and_redeal(mesh8):
    """One long interleaved insert/delete/update/compact history, applied
    identically to one store per placement x redeal combination; after
    every phase all pruned servers must answer bit-identically, and
    identically to a route="exact" reference — placement decides the
    layout, the layout decides the pruning, and neither may reach the
    answer bytes."""
    rng = np.random.default_rng(42)
    clusters, centers = sharded_clusters(K, 40, DIM, rng=rng)
    stores = {c: MutableStore(DIM, capacity_per_shard=CAP, axis_name="x",
                              staging_size=64, placement=c[0], redeal=c[1],
                              placement_guard_slack=8)
              for c in COMBOS}
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=(B,))
    servers = {c: KnnServer(store=s, cfg=cfg.replace(route="pruned"))
               for c, s in stores.items()}
    exact = KnnServer(store=stores[COMBOS[0]], cfg=cfg.replace(route="exact"))

    def everybody(fn):
        for s in stores.values():
            fn(s)

    def check(tag):
        q = np.concatenate([
            (centers[rng.integers(0, K, B - 1)]
             + rng.normal(size=(B - 1, DIM))),
            rng.normal(size=(1, DIM))]).astype(np.float32)
        ls = [1, 8, 256, 33]
        ref = exact.query_batch(q, ls)
        for combo, srv in servers.items():
            res = srv.query_batch(q, ls)
            for a, b in zip(ref, res):
                assert a.dists.tobytes() == b.dists.tobytes(), (tag, combo)
                assert np.array_equal(a.ids, b.ids), (tag, combo)
                assert a.generation == b.generation, (tag, combo)

    # phase 1: clustered streaming ingest (cluster-interleaved order)
    stream = clusters[rng.permutation(len(clusters))]
    for i in range(0, len(stream), 80):
        everybody(lambda s: (s.insert(stream[i:i + 80]), s.flush()))
    check("ingest")

    # phase 2: interleaved deletes + inserts + updates in one flush
    ids = stores[COMBOS[0]].live_arrays()[0]
    victims, moved = ids[::3][:50], ids[1::3][:20]
    fresh = (centers[rng.integers(0, K, 60)]
             + rng.normal(size=(60, DIM))).astype(np.float32)
    new_pos = rng.normal(size=(len(moved), DIM)).astype(np.float32)

    def phase2(s):
        s.delete(victims)
        s.insert(fresh)
        s.update(moved, new_pos)
        s.flush()
    everybody(phase2)
    check("churn")

    # phase 3: forced compaction — the point where the redeal modes
    # diverge most (round-robin smears, proximity re-clusters)
    everybody(lambda s: s.compact())
    check("compact")

    # phase 4: post-redeal inserts land through the policy again
    tail = (centers[rng.integers(0, K, 48)]
            + rng.normal(size=(48, DIM))).astype(np.float32)
    everybody(lambda s: (s.insert(tail), s.flush()))
    check("post-redeal ingest")

    # and the locality the whole subsystem exists for: on the clustered
    # workload the affinity+proximity store prunes at least as hard as
    # every other combo (strictly harder than balance in practice)
    q = (centers[rng.integers(0, K, B)]
         + rng.normal(size=(B, DIM))).astype(np.float32)
    touched = {c: route_shards(stores[c].summaries(), q,
                               np.full(B, 8)).sum(1).mean()
               for c in COMBOS}
    assert touched[("affinity", "proximity")] <= min(touched.values()) + 1e-9


# ---- the guardrail bound --------------------------------------------------

def _guardrail_case(g, seed, redeal):
    rng = np.random.default_rng(seed)
    clusters, _ = sharded_clusters(K, 40, DIM, rng=rng)
    stream = clusters[rng.permutation(len(clusters))]
    store = MutableStore(DIM, capacity_per_shard=CAP, axis_name="x",
                         staging_size=16, placement="affinity",
                         placement_guard_slack=g, redeal=redeal,
                         auto_compact=False)
    for i in range(0, len(stream), 16):
        store.insert(stream[i:i + 16])
        store.flush()
        live = store.live_per_shard
        assert live.max() - live.min() <= g + 1, (g, seed, i)
    # the bound survives a re-deal: post-compact inserts flow through the
    # guardrail again, and the proximity quota itself is slack-bounded
    store.compact()
    n = store.live_count
    assert store.live_per_shard.max() <= -(-n // K) + g + 1
    store.insert(stream[:16] * 0.5)
    store.flush()
    if redeal == "round_robin":       # compact left max-min <= 1
        live = store.live_per_shard
        assert live.max() - live.min() <= g + 1


if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(g=st.integers(min_value=0, max_value=12),
           seed=st.integers(min_value=0, max_value=99),
           redeal=st.sampled_from(("round_robin", "proximity")))
    def test_affinity_guardrail_bound(g, seed, redeal):
        _guardrail_case(g, seed, redeal)
else:
    @pytest.mark.parametrize("redeal", ("round_robin", "proximity"))
    @pytest.mark.parametrize("g", (0, 3, 8))
    def test_affinity_guardrail_bound(g, redeal):
        for seed in (0, 7):
            _guardrail_case(g, seed, redeal)


# ---- proximity re-deal: the repack contract -------------------------------

def _redeal_case(seed, n_live, slack):
    rng = np.random.default_rng(seed)
    cap = max(2, -(-n_live // K) + 3)
    total = K * cap
    pts = np.zeros((total, DIM), np.float32)
    ids = np.full(total, 2**31 - 1, np.int32)
    valid = np.zeros(total, bool)
    slots = rng.choice(total, size=n_live, replace=False)
    pts[slots] = rng.normal(scale=4.0, size=(n_live, DIM))
    ids[slots] = rng.permutation(10 * total)[:n_live]
    valid[slots] = True
    before = {int(i): pts[s].copy() for i, s in zip(ids[slots], slots)}

    res = repack_proximity(pts, ids, valid, K, cap, id_sentinel=2**31 - 1,
                           balance_slack=slack)
    # id set preserved, each id still naming the same point
    assert set(res.slot_of) == set(before)
    for i, s in res.slot_of.items():
        assert res.valid[s] and res.ids[s] == i
        assert np.array_equal(res.points[s], before[i])
    # dense prefixes, used == live, quota-bounded balance
    for j in range(K):
        sl = slice(j * cap, (j + 1) * cap)
        assert res.valid[sl][:res.live[j]].all()
        assert not res.valid[sl][res.live[j]:].any()
        assert (res.ids[sl][res.live[j]:] == 2**31 - 1).all()
    assert np.array_equal(res.used, res.live)
    assert res.live.sum() == n_live
    if n_live:
        assert res.live.max() <= min(cap, -(-n_live // K) + slack)
    # deterministic: same inputs, same layout
    res2 = repack_proximity(pts, ids, valid, K, cap, id_sentinel=2**31 - 1,
                            balance_slack=slack)
    assert np.array_equal(res.points, res2.points)
    assert np.array_equal(res.ids, res2.ids)


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=999),
           n_live=st.integers(min_value=0, max_value=200),
           slack=st.integers(min_value=0, max_value=16))
    def test_repack_proximity_contract(seed, n_live, slack):
        _redeal_case(seed, n_live, slack)
else:
    @pytest.mark.parametrize("seed,n_live,slack", [
        (0, 0, 0), (1, 1, 0), (2, 7, 2), (3, 64, 0), (4, 173, 5),
        (5, 200, 16), (6, 99, 1)])
    def test_repack_proximity_contract(seed, n_live, slack):
        _redeal_case(seed, n_live, slack)


def test_repack_proximity_single_shard():
    """k=1 degenerates to a dense repack (no second-best centroid to
    regret over); the contract must hold all the same."""
    rng = np.random.default_rng(3)
    cap = 16
    pts = rng.normal(size=(cap, DIM)).astype(np.float32)
    ids = np.arange(cap, dtype=np.int32)
    valid = np.ones(cap, bool)
    valid[::4] = False
    res = repack_proximity(pts, ids, valid, 1, cap, id_sentinel=2**31 - 1)
    assert res.live[0] == valid.sum()
    assert res.valid[:res.live[0]].all() and not res.valid[res.live[0]:].any()
    assert set(res.slot_of) == set(ids[valid].tolist())


def test_repack_proximity_is_cluster_coherent():
    """Equal-size well-separated clusters re-deal to exactly one cluster
    per shard (the locality round-robin destroys), even from scratch —
    farthest-point seeding plus Lloyd must find them without shard-summary
    seeds."""
    per = 24
    pts32, centers = sharded_clusters(K, per, DIM, seed=9)
    rng = np.random.default_rng(9)
    order = rng.permutation(K * per)           # scatter clusters over slots
    cap = per + 4
    total = K * cap
    pts = np.zeros((total, DIM), np.float32)
    ids = np.full(total, 2**31 - 1, np.int32)
    valid = np.zeros(total, bool)
    pts[:K * per] = pts32[order]
    ids[:K * per] = np.arange(K * per)
    valid[:K * per] = True

    res = repack_proximity(pts, ids, valid, K, cap, id_sentinel=2**31 - 1,
                           balance_slack=0)
    for j in range(K):
        pj = res.points[j * cap:(j + 1) * cap][:res.live[j]]
        labels = np.argmin(((pj[:, None, :].astype(np.float64)
                             - centers[None]) ** 2).sum(-1), axis=1)
        assert len(set(labels.tolist())) == 1, j


def test_lloyd_centroids_deterministic_and_degenerate_safe():
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(50, DIM))
    a = lloyd_centroids(pts, K, iters=3)
    b = lloyd_centroids(pts, K, iters=3)
    assert np.array_equal(a, b)
    # identical seeds may not collapse the iteration: all-equal seed rows
    # must still yield k usable centroids
    seeds = np.zeros((K, DIM))
    c = lloyd_centroids(pts, K, seed_centroids=seeds, iters=4)
    assert c.shape == (K, DIM)
    assert np.isfinite(c).all()
    # fewer points than centroids: every point is still owned
    few = rng.normal(size=(3, DIM))
    c = lloyd_centroids(few, K, iters=2)
    assert np.isfinite(c).all()


# ---- policy units ---------------------------------------------------------

def test_make_placement_factory():
    assert isinstance(make_placement("balance"), BalancePlacement)
    aff = make_placement("affinity", guard_slack=5)
    assert isinstance(aff, AffinityPlacement) and aff.guard_slack == 5
    custom = BalancePlacement()
    assert make_placement(custom) is custom            # pluggable path
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement("proximity")
    with pytest.raises(ValueError, match="guard_slack"):
        AffinityPlacement(guard_slack=-1)


def test_balance_policy_matches_original_rule():
    pol = BalancePlacement()
    v = _view(live=[3, 1, 1, 5], used=[3, 1, 1, 5], cap=8)
    assert pol.pick(None, v) == 1                      # emptiest, lowest idx
    v = _view(live=[0, 0], used=[2, 2], cap=2)
    assert pol.pick(None, v) == -1                     # no tail anywhere


def test_affinity_policy_guardrail_and_fallbacks():
    cents = np.zeros((4, DIM))
    cents[:, 0] = [0.0, 10.0, 20.0, 30.0]
    radii = np.full(4, 1.0)
    pol = AffinityPlacement(guard_slack=2)
    p = np.zeros(DIM)
    p[0] = 19.0                                        # nearest: shard 2
    v = _view(live=[4, 4, 4, 4], used=[4, 4, 4, 4], cap=16,
              centroids=cents, radii=radii)
    assert pol.pick(p, v) == 2
    # guardrail: shard 2 too far above the minimum -> next-nearest wins
    v = _view(live=[4, 4, 7, 4], used=[4, 4, 7, 4], cap=16,
              centroids=cents, radii=radii)
    assert pol.pick(p, v) == 1
    # high-water mark: a full shard is never picked, however near
    v = _view(live=[4, 4, 4, 4], used=[4, 4, 16, 4], cap=16,
              centroids=cents, radii=radii)
    assert pol.pick(p, v) != 2
    # tombstone corner: the min-live shard has no tail and the guardrail
    # empties the eligible set -> balance fallback over open shards
    v = _view(live=[0, 9, 9, 9], used=[16, 9, 9, 9], cap=16,
              centroids=cents, radii=radii)
    assert pol.pick(p, v) == 1
    # outsider + empty eligible shard -> seed the empty one
    v = _view(live=[4, 4, 4, 0], used=[4, 4, 4, 0], cap=16,
              centroids=cents, radii=radii)
    far = np.zeros(DIM)
    far[0] = 100.0
    assert pol.pick(far, v) == 3


def test_store_rejects_bad_placement_config():
    with pytest.raises(ValueError, match="unknown placement"):
        MutableStore(DIM, capacity_per_shard=8, axis_name="x",
                     placement="nearest")
    with pytest.raises(ValueError, match="redeal"):
        MutableStore(DIM, capacity_per_shard=8, axis_name="x",
                     redeal="lloyd")


def test_store_accepts_custom_policy_instance():
    class FirstOpen(BalancePlacement):
        name = "first-open"

        def pick(self, point, view):
            open_ = np.flatnonzero(view.used < view.cap)
            return int(open_[0]) if len(open_) else -1

    store = MutableStore(DIM, capacity_per_shard=4, axis_name="x",
                         placement=FirstOpen(), auto_compact=False)
    assert store.placement == "first-open"
    store.insert(np.zeros((6, DIM), np.float32))
    store.flush()
    assert store.live_per_shard[0] == 4                # filled shard 0 first
    assert store.live_per_shard[1] == 2


def test_config_store_kwargs_round_trip():
    cfg = CONFIG.replace(placement="affinity", redeal="proximity",
                         placement_guard_slack=7,
                         store_capacity_per_shard=32)
    store = MutableStore(DIM, axis_name="x", **cfg.store_kwargs())
    assert store.placement == "affinity"
    assert store.redeal == "proximity"
    assert store.placement_guard_slack == 7
    assert store.cap == 32
    assert store.summary_projections == cfg.route_num_projections
