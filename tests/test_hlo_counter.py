"""Trip-aware HLO cost model vs controlled programs."""

import jax
import jax.numpy as jnp

from repro.launch import hlo_counter
from repro.parallel.compat import shard_map


def test_nested_scan_flops_exact():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(f).lower(a, w).compile().as_text()
    res = hlo_counter.analyze(txt)
    assert abs(res["flops"] / (15 * 2 * 128**3) - 1.0) < 0.05


def test_scan_vs_unroll_agree():
    """The counter must give (approximately) the same flops for the scanned
    and unrolled forms — the property cost_analysis lacks."""
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    def unrolled(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    fs = hlo_counter.analyze(
        jax.jit(scanned).lower(a, w).compile().as_text())["flops"]
    fu = hlo_counter.analyze(
        jax.jit(unrolled).lower(a, w).compile().as_text())["flops"]
    assert abs(fs / fu - 1.0) < 0.05


def test_collective_counting(mesh8):
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.psum(x, "x")

    txt = jax.jit(shard_map(
        f, mesh=mesh8, in_specs=P("x"), out_specs=P(None))).lower(
        jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile().as_text()
    res = hlo_counter.analyze(txt)
    # one all-reduce of (1, 1024) f32 per device: 2*(7/8)*4096 bytes
    assert res["collective_counts"].get("all-reduce", 0) >= 1
    assert res["wire_bytes"] > 0


def test_shape_bytes():
    from repro.launch.hlo_analysis import _shape_bytes
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,4]{1,0}") == 16
    assert _shape_bytes("(f32[8], s32[8])") == 8 * 4 + 8 * 4
    assert _shape_bytes("pred[16]") == 16
