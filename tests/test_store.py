"""Mutable sharded store: staging/flush semantics, validity threading,
compaction/rebalance, and epoch-swapped serving.

The load-bearing invariant (ISSUE 2 acceptance): for ANY interleaving of
insert/delete/update/compact, `knn_query` over the mutable store returns
exactly the brute-force l-NN of the *live* points — deleted points never
surface, inserted points surface immediately once their generation is
visible — and an epoch swap under concurrent submit load drops zero
in-flight queries.
"""

import threading

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.configs.knn_service import CONFIG
from repro.parallel.compat import shard_map
from repro.runtime import KnnServer
from repro.store import MutableStore, StoreFullError

K = 8
DIM = 4
CAP = 32                      # slots per shard -> 256 total
NEVER = 10**9                 # staging_size that never auto-flushes

_SENTINEL = 2**31 - 1


def _mk_store(mesh, **kw):
    kw.setdefault("staging_size", NEVER)
    return MutableStore(DIM, capacity_per_shard=CAP, mesh=mesh,
                        axis_name="x", **kw)


def _mk_server(store, **overrides):
    kw = dict(dim=DIM, l=8, l_max=16, bucket_sizes=(4,))
    kw.update(overrides)
    return KnnServer(store=store, cfg=CONFIG.replace(**kw))


def _brute_ids(ids, pts, q, l):
    """Set of the l nearest live ids (distances are a.s. distinct)."""
    if len(ids) == 0:
        return set()
    d = ((q[None] - pts) ** 2).sum(-1)
    return set(np.asarray(ids)[np.argsort(d, kind="stable")[:l]].tolist())


def _check_result(r, live_ids, live_pts, q, l):
    """r's finite slots == brute-force l-NN of the live set; the rest are
    sentinels (deleted points must never surface, not even at +inf)."""
    l_eff = min(l, len(live_ids))
    assert set(r.ids[:l_eff].tolist()) == _brute_ids(live_ids, live_pts, q,
                                                     l_eff)
    assert np.all(np.isfinite(r.dists[:l_eff]))
    assert np.all(np.isinf(r.dists[l_eff:]))
    assert np.all(r.ids[l_eff:] == _SENTINEL)


# ---- staging / visibility -------------------------------------------------


def test_staged_ops_invisible_until_flush(mesh8, rng):
    st = _mk_store(mesh8)
    srv = _mk_server(st)
    q = rng.normal(size=DIM).astype(np.float32)

    st.insert(rng.normal(size=(20, DIM)).astype(np.float32))
    assert st.pending_ops == 20 and st.live_count == 0
    r = srv.query_batch(q[None], [8])[0]
    assert r.generation == 0
    assert np.all(np.isinf(r.dists)) and np.all(r.ids == _SENTINEL)

    gen = st.flush()
    assert gen == 1 and st.pending_ops == 0 and st.live_count == 20
    r = srv.query_batch(q[None], [8])[0]
    assert r.generation == 1
    ids, pts = st.live_arrays()
    _check_result(r, ids, pts, q, 8)


def test_autoflush_at_staging_size(mesh8, rng):
    st = _mk_store(mesh8, staging_size=16)
    st.insert(rng.normal(size=(15, DIM)).astype(np.float32))
    assert st.generation == 0            # below threshold: still staged
    st.insert(rng.normal(size=(1, DIM)).astype(np.float32))
    assert st.generation == 1 and st.live_count == 16


def test_staging_validation(mesh8, rng):
    st = _mk_store(mesh8)
    ids = st.insert(rng.normal(size=(4, DIM)).astype(np.float32))
    with pytest.raises(ValueError):      # duplicate staged id
        st.insert(np.zeros(DIM, np.float32), ids=[int(ids[0])])
    with pytest.raises(KeyError):
        st.delete([999])
    with pytest.raises(KeyError):
        st.update([999], np.zeros((1, DIM), np.float32))
    st.flush()
    # delete staged-then-flushed id, then double delete
    st.delete([int(ids[0])])
    with pytest.raises(KeyError):
        st.delete([int(ids[0])])
    st.flush()
    # ids are single-use forever: re-inserting a deleted id must fail
    # (this is what keeps the id -> value map monotone for old epochs)
    with pytest.raises(ValueError):
        st.insert(np.zeros(DIM, np.float32), ids=[int(ids[0])])
    # and auto-assigned ids never collide with anything ever used
    new = st.insert(np.zeros(DIM, np.float32))
    assert int(new[0]) > int(ids.max())


def test_staging_is_atomic_per_call(mesh8, rng):
    """A rejected batch stages nothing: no partial inserts/deletes leak
    into a later flush."""
    st = _mk_store(mesh8)
    ids = st.insert(rng.normal(size=(st.total - 2, DIM)).astype(np.float32))
    st.flush()
    # insert overflowing by one: whole batch rejected, nothing staged
    with pytest.raises(StoreFullError):
        st.insert(rng.normal(size=(3, DIM)).astype(np.float32))
    assert st.pending_ops == 0
    # delete with one bad id: whole batch rejected
    with pytest.raises(KeyError):
        st.delete([int(ids[0]), 10**6])
    # delete with an intra-batch duplicate: rejected
    with pytest.raises(KeyError):
        st.delete([int(ids[1]), int(ids[1])])
    # update with one bad id: rejected
    with pytest.raises(KeyError):
        st.update([int(ids[0]), 10**6],
                  np.zeros((2, DIM), np.float32))
    assert st.pending_ops == 0
    st.flush()
    assert st.live_count == st.total - 2    # nothing leaked


def test_store_full_raises_at_staging(mesh8, rng):
    st = _mk_store(mesh8)
    st.insert(rng.normal(size=(st.total, DIM)).astype(np.float32))
    with pytest.raises(StoreFullError):
        st.insert(np.zeros(DIM, np.float32))
    st.flush()
    # deleting frees projected capacity again
    st.delete([0])
    st.insert(np.zeros(DIM, np.float32))
    st.flush()
    assert st.live_count == st.total


def test_update_moves_point(mesh8, rng):
    st = _mk_store(mesh8)
    ids = st.insert(rng.normal(size=(32, DIM)).astype(np.float32) + 10.0)
    st.flush()
    srv = _mk_server(st)
    q = rng.normal(size=DIM).astype(np.float32)
    target = int(ids[7])
    st.update([target], q[None])         # exact hit: distance 0
    st.flush()
    r = srv.query_batch(q[None], [1])[0]
    assert r.ids[0] == target and r.dists[0] < 1e-6


def test_values_follow_mutations(mesh8, rng):
    st = _mk_store(mesh8, with_values=True)
    pts = rng.normal(size=(10, DIM)).astype(np.float32)
    ids = st.insert(pts, values=np.arange(100, 110))
    st.flush()
    srv = _mk_server(st, l_max=16)
    q = pts[3]
    r = srv.query_batch(q[None], [2])[0]
    assert r.values[0] == 103            # nearest is the point itself
    st.delete([int(ids[3])])
    st.flush()
    r = srv.query_batch(q[None], [2])[0]
    assert 103 not in r.values.tolist()


# ---- the core invariant ---------------------------------------------------


def test_interleaving_property(mesh8, rng):
    """Random interleavings of insert/delete/update/compact: after every
    flush the served answer equals brute force over exactly the live set."""
    st = _mk_store(mesh8)
    srv = _mk_server(st)
    srv.warmup()
    model: dict[int, np.ndarray] = {}    # id -> point (the oracle)

    for rnd in range(12):
        action = rng.choice(["insert", "delete", "update", "compact"],
                            p=[0.45, 0.25, 0.15, 0.15])
        if action == "insert" or not model:
            n = int(rng.integers(1, min(40, st.total - len(model)) + 1))
            pts = rng.normal(size=(n, DIM)).astype(np.float32)
            ids = st.insert(pts)
            model.update(zip(ids.tolist(), pts))
        elif action == "delete":
            n = int(rng.integers(1, max(2, len(model) // 2)))
            victims = rng.choice(sorted(model), size=n, replace=False)
            st.delete(victims)
            for v in victims:
                del model[int(v)]
        elif action == "update":
            n = int(rng.integers(1, max(2, len(model) // 2)))
            chosen = rng.choice(sorted(model), size=n, replace=False)
            pts = rng.normal(size=(n, DIM)).astype(np.float32)
            st.update(chosen, pts)
            model.update(zip((int(c) for c in chosen), pts))
        else:
            st.compact()
        st.flush()

        # mirror invariants
        assert st.live_count == len(model)
        ids, pts = st.live_arrays()
        assert sorted(ids.tolist()) == sorted(model)
        np.testing.assert_array_equal(
            pts, np.stack([model[i] for i in ids.tolist()]))
        assert int(np.asarray(st.snapshot().valid).sum()) == len(model)

        # served answers == brute force over the live set
        qs = rng.normal(size=(3, DIM)).astype(np.float32)
        for q, r in zip(qs, srv.query_batch(qs, [8, 8, 8])):
            assert r.generation == st.generation
            _check_result(r, ids, pts, q, 8)


def test_knn_query_point_valid_direct(mesh8, rng):
    """core.knn_query with a point_valid mask == brute force over the
    masked subset (validity threaded through Algorithm 2 itself)."""
    N = K * 64
    pts = rng.normal(size=(N, DIM)).astype(np.float32)
    pids = np.arange(N, dtype=np.int32)
    valid = rng.random(N) > 0.5
    q = rng.normal(size=(2, DIM)).astype(np.float32)
    l = 12

    def fn(p, i, v, qq, key):
        res = core.knn_query(p, i, qq, l, key, axis_name="x",
                             point_valid=v)
        return res.dists, res.ids

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P("x"), P(None), P(None)),
        out_specs=(P(None), P(None))))
    d, i = map(np.asarray, f(pts, pids, valid, q, jax.random.PRNGKey(0)))
    for b in range(2):
        want = _brute_ids(pids[valid], pts[valid], q[b], l)
        assert set(i[b].tolist()) == want
        assert not (set(i[b].tolist()) & set(pids[~valid].tolist()))


def test_store_gather_sampler_agrees(mesh8, rng):
    """The gather baseline honors the valid mask identically."""
    st = _mk_store(mesh8)
    ids = st.insert(rng.normal(size=(120, DIM)).astype(np.float32))
    st.flush()
    st.delete(ids[::3])
    st.flush()
    sel = _mk_server(st)
    gat = _mk_server(st, sampler="gather")
    qs = rng.normal(size=(4, DIM)).astype(np.float32)
    for a, b in zip(sel.query_batch(qs, [8] * 4),
                    gat.query_batch(qs, [8] * 4)):
        np.testing.assert_allclose(a.dists, b.dists, rtol=1e-5)
        assert a.ids.tolist() == b.ids.tolist()


# ---- compaction / rebalance ----------------------------------------------


def test_tombstone_compaction_trigger(mesh8, rng):
    st = _mk_store(mesh8, compact_tombstone_frac=0.3,
                   compact_imbalance_frac=10.0)
    ids = st.insert(rng.normal(size=(200, DIM)).astype(np.float32))
    st.flush()
    assert st.stats.compactions == 0
    st.delete(rng.choice(ids, size=120, replace=False))
    st.flush()                           # density 0.6 > 0.3
    assert st.stats.compactions == 1
    assert "tombstone_density" in st.stats.last_compact_reason
    # repack rebalances to within one point and re-densifies shards
    live = st.live_per_shard
    assert live.max() - live.min() <= 1
    # and answers are unaffected
    srv = _mk_server(st)
    q = rng.normal(size=DIM).astype(np.float32)
    lid, lpts = st.live_arrays()
    _check_result(srv.query_batch(q[None], [8])[0], lid, lpts, q, 8)


def test_imbalance_compaction_trigger(mesh8, rng):
    st = _mk_store(mesh8, compact_tombstone_frac=10.0,
                   compact_imbalance_frac=0.25)
    ids = st.insert(rng.normal(size=(st.total, DIM)).astype(np.float32))
    st.flush()
    # concentrated deletes: balance-aware placement dealt sequential
    # inserts round-robin, so every K-th id lives on the same shard —
    # deleting them empties that shard while the others stay full
    st.delete(ids[::K])
    st.flush()
    assert st.stats.compactions == 1
    assert "imbalance" in st.stats.last_compact_reason
    live = st.live_per_shard
    assert live.max() - live.min() <= 1


def test_forced_compaction_reclaims_tombstones(mesh8, rng):
    """All shards at their high-water mark + global space free: the flush
    must repack instead of failing."""
    st = _mk_store(mesh8, auto_compact=False)
    ids = st.insert(rng.normal(size=(st.total, DIM)).astype(np.float32))
    st.flush()
    st.delete(ids[: st.total // 2])
    st.flush()                           # tombstones everywhere, no tail
    st.insert(rng.normal(size=(st.total // 4, DIM)).astype(np.float32))
    st.flush()
    assert st.stats.forced_compactions == 1
    assert st.live_count == st.total // 2 + st.total // 4
    ids2, pts2 = st.live_arrays()
    srv = _mk_server(st)
    q = rng.normal(size=DIM).astype(np.float32)
    _check_result(srv.query_batch(q[None], [8])[0], ids2, pts2, q, 8)


def test_compaction_is_id_stable(mesh8, rng):
    st = _mk_store(mesh8)
    pts = rng.normal(size=(100, DIM)).astype(np.float32)
    ids = st.insert(pts)
    st.flush()
    ids_b, pts_b = st.live_arrays()
    before = {int(i): p for i, p in zip(ids_b, pts_b)}
    st.compact()
    ids_a, pts_a = st.live_arrays()
    assert sorted(ids_a.tolist()) == sorted(ids.tolist())
    for i, p in zip(ids_a.tolist(), pts_a):
        np.testing.assert_array_equal(p, before[i])


# ---- epoch-swapped serving ------------------------------------------------


def test_epoch_swap_under_load_drops_nothing(mesh8, rng):
    """Concurrent submit load across continuous epoch swaps: every future
    resolves, and each answer is exactly the brute-force l-NN of the live
    set of the generation it reports."""
    st = _mk_store(mesh8, track_history=True)
    st.insert(rng.normal(size=(64, DIM)).astype(np.float32))
    st.flush()
    srv = _mk_server(st)
    srv.warmup()

    stop = threading.Event()

    def mutate():
        # net-zero churn: two epoch swaps per cycle, can never fill the
        # store, keeps swapping until told to stop
        r = np.random.default_rng(5)
        while not stop.is_set():
            ids = st.insert(r.normal(size=(8, DIM)).astype(np.float32))
            st.flush()
            st.delete(ids)
            st.flush()

    t = threading.Thread(target=mutate, daemon=True)
    queries = [rng.normal(size=DIM).astype(np.float32) for _ in range(24)]
    with srv.serving():
        t.start()
        futs = [srv.submit(q, 8) for q in queries[:12]]
        results = [f.result(timeout=120) for f in futs]      # zero drops
        # deterministic swap between the waves: wave-2 dispatches must
        # capture a generation strictly newer than every wave-1 answer
        st.insert(rng.normal(size=(4, DIM)).astype(np.float32))
        forced_gen = st.flush()
        futs = [srv.submit(q, 8) for q in queries[12:]]
        results += [f.result(timeout=120) for f in futs]     # zero drops
        stop.set()
        t.join()

    gens = [r.generation for r in results]
    assert min(gens) >= 1 and max(gens) <= st.generation
    assert min(g for g in gens[12:]) >= forced_gen > max(gens[:12]), \
        "in-flight queries crossed the epoch swap the wrong way"
    # full exactness against the *reported* generation's live set
    for q, r in zip(queries, results):
        ids_g, pts_g = st.history(r.generation)
        _check_result(r, ids_g, pts_g, q, 8)


def test_epoch_swap_exactness_per_generation(mesh8, rng):
    """Synchronous variant of the swap test with full exactness: the same
    query re-asked across generations tracks each generation's live set."""
    st = _mk_store(mesh8, track_history=True)
    srv = _mk_server(st)
    q = rng.normal(size=DIM).astype(np.float32)
    for _ in range(6):
        ids = st.insert(rng.normal(size=(16, DIM)).astype(np.float32))
        st.flush()
        st.delete(ids[:10])
        st.flush()
        r = srv.query_batch(q[None], [8])[0]
        assert r.generation == st.generation
        ids_g, pts_g = st.history(r.generation)
        _check_result(r, ids_g, pts_g, q, 8)


def test_empty_store_serves_sentinels(mesh8, rng):
    st = _mk_store(mesh8)
    srv = _mk_server(st)
    r = srv.query_batch(rng.normal(size=(1, DIM)).astype(np.float32),
                        [8])[0]
    assert np.all(np.isinf(r.dists)) and np.all(r.ids == _SENTINEL)
    # drain to empty after being populated
    ids = st.insert(rng.normal(size=(30, DIM)).astype(np.float32))
    st.flush()
    st.delete(ids)
    st.flush()
    r = srv.query_batch(rng.normal(size=(1, DIM)).astype(np.float32),
                        [8])[0]
    assert np.all(np.isinf(r.dists)) and np.all(r.ids == _SENTINEL)


def test_server_store_mesh_conflict_rejected(mesh8, rng):
    st = _mk_store(mesh8)
    with pytest.raises(ValueError):
        KnnServer(np.zeros((8, DIM), np.float32), store=st)
    # an equal mesh is accepted (jax may or may not intern Mesh objects;
    # the guard compares by equality, never identity)...
    from repro.parallel.compat import make_mesh
    twin = make_mesh((8,), ("x",))
    KnnServer(store=st, cfg=CONFIG.replace(dim=DIM, l_max=16,
                                           bucket_sizes=(4,)), mesh=twin)
    # ...a genuinely different one is not
    other = make_mesh((4, 2), ("data", "x"))
    with pytest.raises(ValueError):
        KnnServer(store=st, cfg=CONFIG.replace(dim=DIM, l_max=16,
                                               bucket_sizes=(4,)),
                  mesh=other)
