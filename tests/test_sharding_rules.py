"""Logical-axis sharding rules and divisibility filtering."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding as shd
from repro.parallel.compat import set_mesh


def test_spec_resolution_default():
    s = shd.spec("batch", None, "mlp")
    assert s == P(("pod", "data"), None, "model")


def test_spec_rule_override():
    with shd.use_rules(shd.Rules(batch=None, kv_seq="data")):
        assert shd.spec("batch", "kv_seq") == P(None, "data")
    assert shd.spec("kv_seq") == P(None)  # default restored


def test_spec_filters_missing_mesh_axes(mesh42):
    with set_mesh(mesh42):  # no "pod" axis
        s = shd.spec("batch", "vocab")
        assert s == P("data", "model")


def test_divisible_drops_nondividing_axes(mesh42):
    # (40, 30): 40 % 4 == 0 -> keep data; 30 % 2 == 0 -> keep model
    assert shd.divisible(P("data", "model"), (40, 30), mesh42) \
        == P("data", "model")
    # 2 % 4 != 0 -> dropped
    assert shd.divisible(P("data"), (2,), mesh42) == P(None)
    # tuple axes: keep prefix that divides
    got = shd.divisible(P(("data", "model")), (4,), mesh42)
    assert got == P("data")
    # batch 1 decodes to fully replicated
    assert shd.divisible(P(("data", "model")), (1,), mesh42) == P(None)


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert (y == x).all()
