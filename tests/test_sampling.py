"""Algorithm 2's sample-prune step (Lemma 2.3) in isolation."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import sampling
from repro.parallel.compat import shard_map

K = 8


def _prune(mesh, d, l, key=0):
    def fn(dd, kk):
        r = sampling.sample_prune(dd, kk, l, axis_name="x")
        return r.valid, r.radius, r.survivors, r.applied

    f = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(P(None, "x"), P(None)),
        out_specs=(P(None, "x"), P(None), P(None), P(None)),
        check_vma=False))
    return f(d, jax.random.PRNGKey(key))


def test_prune_never_loses_true_topl(mesh8, rng):
    """Las Vegas property: whether or not the radius was accepted, the
    survivor set contains the l smallest elements."""
    L = 64
    for seed in range(5):
        r = np.random.default_rng(seed)
        d = r.exponential(size=(2, K * L)).astype(np.float32)
        valid, radius, surv, applied = _prune(mesh8, d, L, key=seed)
        valid = np.asarray(valid)
        for b in range(2):
            top = np.argsort(d[b])[:L]
            assert valid[b][top].all(), "prune cut a true neighbor"


def test_prune_bounds(mesh8, rng):
    L = 128
    d = rng.exponential(size=(1, K * L)).astype(np.float32)
    valid, radius, surv, applied = _prune(mesh8, d, L)
    assert bool(np.asarray(applied).all())
    s = int(np.asarray(surv)[0])
    assert L <= s <= 11 * L          # Lemma 2.3 envelope


def test_prune_with_sentinels(mesh8, rng):
    """Sentinel +inf entries are 'fake data' and never survive (Step 7)."""
    L = 32
    d = rng.exponential(size=(1, K * L)).astype(np.float32)
    d[:, ::3] = np.inf
    valid, radius, surv, applied = _prune(mesh8, d, L)
    assert not np.asarray(valid)[0][::3].any()


def test_prune_survivor_envelope_sweep(mesh8):
    """benchmarks/bench_prune.py's Lemma 2.3 envelope, CI-enforced: over
    many seeded instances the survivor count lands in [l, 11l], the Las
    Vegas verification accepts, and the true l-NN set always survives.
    Seeds are fixed, so the w.h.p. events are frozen facts, not flakes."""
    L = 64
    trials = 12
    d_all = np.stack([np.random.default_rng(t).exponential(
        size=(K * L,)).astype(np.float32) for t in range(trials)])[:, None, :]

    def fn(dd, kk):
        r = sampling.sample_prune(dd, kk, L, axis_name="x")
        return r.valid, r.survivors, r.applied

    f = jax.jit(shard_map(
        fn, mesh=mesh8, in_specs=(P(None, "x"), P(None)),
        out_specs=(P(None, "x"), P(None), P(None)), check_vma=False))
    for t in range(trials):
        valid, surv, applied = f(d_all[t], jax.random.PRNGKey(t))
        assert bool(np.asarray(applied)[0]), f"trial {t}: prune rejected"
        s = int(np.asarray(surv)[0])
        assert L <= s <= 11 * L, f"trial {t}: {s} outside [{L}, {11 * L}]"
        top = np.argsort(d_all[t, 0])[:L]
        assert np.asarray(valid)[0][top].all(), \
            f"trial {t}: prune cut a true neighbor"


def test_sample_counts_match_paper_constants():
    assert sampling.sample_count(1024) == int(np.ceil(12 * np.log(1024)))
    assert sampling.radius_index(1024) == int(np.ceil(21 * np.log(1024)))
