"""Algorithm 1 (distributed randomized selection) vs the numpy oracle.

Property-based: for arbitrary inputs (duplicates, +inf sentinels, every
rank l), the selected set must be exactly the l smallest under the
composite (value, id) order — Definition 1.1.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

# Property test: hypothesis-driven when installed (requirements-dev.txt),
# seeded-grid fallback otherwise — the property always runs, never skips.
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    given = None

from repro.core.selection import (SelectionResult, select_l_smallest,
                                  selected_mask)
from repro.parallel.compat import shard_map

K = 8  # shards


def _run(mesh, vals, ids, l, key=0, num_pivots=1, valid=None):
    res_spec = SelectionResult(P(None), P(None), P(), P(None))
    has_valid = valid is not None

    def fn(v, i, l, key, valid=None):
        res = select_l_smallest(v, i, l, key, axis_name="x",
                                valid=valid, num_pivots=num_pivots)
        return res, selected_mask(v, i, res, valid=valid)

    in_specs = [P(None, "x"), P(None, "x"), P(None), P(None)]
    args = [vals, ids, l, jax.random.PRNGKey(key)]
    if has_valid:
        in_specs.append(P(None, "x"))
        args.append(valid)
    f = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(res_spec, P(None, "x"))))
    return f(*args)


def _oracle_check(vals, mask, l_arr, valid=None):
    mask = np.asarray(mask)
    for b in range(vals.shape[0]):
        v = vals[b]
        sel = np.flatnonzero(mask[b])
        pool = np.arange(v.shape[0])
        if valid is not None:
            pool = pool[np.asarray(valid)[b]]
        l = min(int(l_arr[b]), pool.size)
        assert sel.size == l, (sel.size, l)
        # composite order: value then index — lexsort
        order = pool[np.lexsort((pool, v[pool]))][:l]
        assert set(sel.tolist()) == set(order.tolist())


def _selection_property_case(mesh8, m, l_frac, dup, seed):
    n = K * m
    r = np.random.default_rng(seed)
    vals = r.normal(size=(1, n)).astype(np.float32)
    if dup:
        vals = np.round(vals, 1)  # force many ties
    ids = np.arange(n, dtype=np.int32)[None].repeat(1, 0)
    l = np.array([max(1, int(l_frac * n))], np.int32)
    res, mask = _run(mesh8, vals, ids, l, key=seed)
    assert bool(np.asarray(res.converged).all())
    _oracle_check(vals, mask, l)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=32),
        l_frac=st.floats(min_value=0.0, max_value=1.0),
        dup=st.booleans(),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_selection_property(mesh8, m, l_frac, dup, seed):
        _selection_property_case(mesh8, m, l_frac, dup, seed)
else:
    # Seeded fallback: the same property body over a fixed grid, so the
    # guarantee is still exercised (not bare-skipped) without hypothesis.
    @pytest.mark.parametrize("m,l_frac,dup,seed", [
        (1, 0.0, False, 0), (1, 1.0, True, 1), (8, 0.5, True, 2),
        (32, 0.1, False, 3), (32, 0.9, True, 4), (17, 0.33, False, 5),
    ])
    def test_selection_property(mesh8, m, l_frac, dup, seed):
        _selection_property_case(mesh8, m, l_frac, dup, seed)


@pytest.mark.parametrize("num_pivots", [1, K])
@pytest.mark.parametrize("l", [1, 7, 64, 256])
def test_selection_ranks(mesh8, rng, num_pivots, l):
    n = 256
    vals = rng.normal(size=(2, n)).astype(np.float32)
    ids = np.broadcast_to(np.arange(n, dtype=np.int32), (2, n)).copy()
    res, mask = _run(mesh8, vals, ids, np.array([l, l], np.int32),
                     num_pivots=num_pivots)
    _oracle_check(vals, mask, [l, l])


def test_selection_multi_pivot_fewer_iterations(mesh8, rng):
    n = 4096
    vals = rng.normal(size=(1, n)).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)[None]
    l = np.array([n // 3], np.int32)
    r1, _ = _run(mesh8, vals, ids, l, key=3, num_pivots=1)
    rk, _ = _run(mesh8, vals, ids, l, key=3, num_pivots=K)
    # beyond-paper optimization: k pivots/iteration cuts rounds ~log k fold
    assert int(rk.iterations) < int(r1.iterations)


def test_selection_with_sentinels(mesh8, rng):
    n = 128
    vals = rng.normal(size=(1, n)).astype(np.float32)
    vals[:, 50:] = np.inf
    ids = np.arange(n, dtype=np.int32)[None]
    for l in (1, 50, 128):
        res, mask = _run(mesh8, vals, ids, np.array([l], np.int32))
        assert int(np.asarray(mask).sum()) == l


def test_selection_valid_mask(mesh8, rng):
    n = 256
    vals = rng.normal(size=(1, n)).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)[None]
    valid = (rng.random((1, n)) < 0.5)
    l = np.array([max(1, int(valid.sum()) // 2)], np.int32)
    res, mask = _run(mesh8, vals, ids, l, valid=valid)
    assert not np.any(np.asarray(mask) & ~valid)
    _oracle_check(vals, mask, l, valid=valid)


def test_selection_iterations_bound(mesh8, rng):
    """Theorem 2.2: O(log n) rounds w.h.p. — generous constant check."""
    n = 8192
    vals = rng.normal(size=(1, n)).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)[None]
    res, _ = _run(mesh8, vals, ids, np.array([n // 2], np.int32))
    assert int(res.iterations) <= 8 * int(np.ceil(np.log2(n))) + 16
    assert bool(np.asarray(res.converged).all())
