"""Optimizer substrate: AdamW convergence, clipping, schedule, compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, global_norm, warmup_cosine
from repro.optim import compress as compress_mod


def test_adamw_converges_quadratic():
    opt = AdamW(weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    target = jnp.array([1.0, 2.0, -1.0])
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(p)
        return opt.update(g, s, p, 0.05)

    for _ in range(400):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    opt = AdamW(clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    new_params, new_state = opt.update(g, state, params, 1.0)
    # post-clip first moment bounded by (1-b1) * clip_norm
    assert float(jnp.abs(new_state.m["w"]).max()) <= 0.11


def test_schedule_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6          # warmup ascends
    assert abs(lrs[10] - 1.0) < 0.01              # peak
    assert lrs[-1] < 0.2                          # decays toward final_frac
    assert min(lrs[10:]) >= 0.1 - 1e-6            # floor


def test_compress_error_feedback_unbiased():
    """Error feedback: sum of compressed grads tracks sum of raw grads."""
    rng = np.random.default_rng(0)
    g_raw = [rng.normal(size=(64,)).astype(np.float32) * 1e-3
             for _ in range(50)]
    residual = compress_mod.init_residual({"w": jnp.zeros(64)})
    total_c = np.zeros(64, np.float64)
    for g in g_raw:
        q, residual = compress_mod.compress({"w": jnp.asarray(g)}, residual)
        total_c += np.asarray(q["w"], np.float64)
    total_raw = np.sum(np.asarray(g_raw, np.float64), axis=0)
    # residual carries the unflushed remainder
    total_c += np.asarray(residual["w"], np.float64)
    np.testing.assert_allclose(total_c, total_raw, atol=5e-5)


def test_global_norm():
    t = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    assert abs(float(global_norm(t)) - np.sqrt(9 * 4 + 16 * 9)) < 1e-4
