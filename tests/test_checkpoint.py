"""Checkpoint substrate: roundtrip, atomicity, keep-N, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager, serialization


def _tree(rng):
    return {
        "layer": {"w": rng.normal(size=(16, 8)).astype(np.float32),
                  "b": rng.normal(size=(8,)).astype(np.float32)},
        "count": np.int32(7),
        "stack": rng.normal(size=(3, 4, 4)).astype(np.float32),
    }


def test_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(10, tree, blocking=True)
    assert mgr.all_steps() == [10]
    out = mgr.restore(10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_pruning(tmp_path, rng):
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_wait(tmp_path, rng):
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, tree)          # async
    mgr.wait()
    assert mgr.latest_step() == 5


def test_no_tmp_dirs_left(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(rng), blocking=True)
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp_")]


def test_elastic_restore_across_shardings(tmp_path, rng, mesh8):
    """Save sharded on an 8-way mesh, restore onto a different sharding —
    the elastic-rescale path (mesh shape changes between runs)."""
    x = rng.normal(size=(8, 32)).astype(np.float32)
    sharded = jax.device_put(x, NamedSharding(mesh8, P("x", None)))
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"x": sharded}, blocking=True)

    # restore replicated (different "mesh")
    out = mgr.restore(1, {"x": x})
    np.testing.assert_array_equal(np.asarray(out["x"]), x)

    # restore onto a different partitioning of the same mesh
    out2 = mgr.restore(1, {"x": x}, mesh=mesh8,
                       specs={"x": P(None, "x")})
    np.testing.assert_array_equal(np.asarray(out2["x"]), x)
    assert out2["x"].sharding.spec == P(None, "x")


def test_shard_metadata_written(tmp_path, rng, mesh8):
    x = rng.normal(size=(8, 4)).astype(np.float32)
    sharded = jax.device_put(x, NamedSharding(mesh8, P("x", None)))
    serialization.save_pytree({"x": sharded}, str(tmp_path / "d"))
    restored = serialization.load_pytree(str(tmp_path / "d"), {"x": x})
    np.testing.assert_array_equal(restored["x"], x)
