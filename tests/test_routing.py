"""Pruned shard routing — the exactness property harness.

The contract under test (DESIGN.md Section 8): ``route="pruned"`` may
mask any shard whose per-shard pivot summary (store/summaries.py) proves
it cannot hold an l-NN winner, and the answer must stay **bit-identical**
to ``route="exact"`` — same distance bytes, same ids, same order — for
every l, on every instance family (clustered, uniform, adversarial
all-points-equidistant), and at every moment of a mutable store's life
(mid-stream after interleaved inserts/deletes/updates/compaction).

Property-based via hypothesis when installed (requirements-dev.txt);
otherwise the same case body runs over a seeded parameter grid, so the
property is exercised either way (never bare-skipped).
"""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

import repro.core as core
from repro.configs.knn_service import CONFIG
from repro.data import sharded_clusters
from repro.kernels import ops as kops
from repro.kernels import routing as routing_mod
from repro.parallel.compat import shard_map
from repro.runtime import KnnServer
from repro.store import (MutableStore, build_summaries, lower_bounds,
                         route_shards, summary_invariants, upper_bounds)

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    given = None

K = 8
DIM = 8
M = 64                     # points per shard (core-level harness)
N = K * M
B = 4                      # query rows per instance
L_MAX = 256                # static buffer bound; l in {1, 8, 256} all fit
L_SET = (1, 8, 256)
FAMILIES = ("clustered", "uniform", "equidistant", "offset")
SCALES = (1.0, 1e-3)


def _instance(family: str, seed: int, scale: float):
    """(points (N, DIM) f32 contiguous-by-shard, queries (B, DIM) f32)."""
    rng = np.random.default_rng(seed)
    if family in ("clustered", "offset"):
        # "offset" pushes the clusters far from the origin: f32 pipeline
        # distances quantize to multiples of ulp(|q|^2), so any routing
        # margin that is merely *relative* to the threshold prunes
        # computed-distance winners — pipeline_error_bound must hold the
        # line (it mostly disables pruning at this scale, by design).
        shift = 2000.0 if family == "offset" else 0.0
        pts, centers = sharded_clusters(K, M, DIM, shift=shift, rng=rng)
        q = centers[rng.integers(0, K, B)] + rng.normal(size=(B, DIM))
    elif family == "uniform":
        pts = rng.normal(size=(N, DIM))
        q = rng.normal(size=(B, DIM))
    else:  # adversarial: every point exactly equidistant from the origin
        # signed scaled one-hots: |p|^2 == c^2 bit-exactly in f32, so the
        # query at the origin ties every point and every shard — routing
        # must keep them all and tie-breaking must not change.
        eye = np.eye(DIM)[np.arange(N) % DIM]
        sign = np.where(rng.random(N) < 0.5, 1.0, -1.0)
        pts = eye * sign[:, None] * 3.0
        q = np.zeros((B, DIM))
        q[B // 2:] = eye[rng.integers(0, N, B - B // 2)] * 3.0  # exact hits
    return (pts * scale).astype(np.float32), (q * scale).astype(np.float32)


@pytest.fixture(scope="module")
def routing_fn(mesh8):
    """One compile for the whole harness: exact and pruned Algorithm 2
    side by side under the same PRNG key."""
    def fn(p, i, q, la, key, active):
        ex = core.knn_query_batched(p, i, q, L_MAX, la, key, axis_name="x")
        pr = core.knn_query_batched(p, i, q, L_MAX, la, key, axis_name="x",
                                    shard_active=active)
        return ex.dists, ex.ids, pr.dists, pr.ids

    return jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None), P(None), P("x")),
        out_specs=(P(None),) * 4))


def _routing_case(routing_fn, family, seed, scale, l):
    pts, q = _instance(family, seed, scale)
    pids = np.arange(N, dtype=np.int32)
    la = np.full(B, l, np.int32)
    summ = build_summaries(pts, K)
    active_rows = route_shards(summ, q, la, slack=CONFIG.route_slack)
    active = active_rows.any(axis=0)

    d_ex, i_ex, d_pr, i_pr = routing_fn(pts, pids, q, la,
                                        jax.random.PRNGKey(seed), active)
    d_ex, i_ex, d_pr, i_pr = map(np.asarray, (d_ex, i_ex, d_pr, i_pr))
    assert d_ex.tobytes() == d_pr.tobytes(), (family, seed, scale, l)
    assert np.array_equal(i_ex, i_pr), (family, seed, scale, l)
    # every reported winner must live in a shard routing kept active
    real = i_ex != 2**31 - 1
    assert active[(i_ex[real] // M)].all()
    # and the lower bounds themselves must be sound: lb <= true min <= ub
    d_all = ((q[:, None, :].astype(np.float64)
              - pts[None].astype(np.float64)) ** 2).sum(-1)
    per_shard_min = d_all.reshape(B, K, M).min(-1)
    assert (lower_bounds(summ, q) <= per_shard_min + 1e-9).all()
    assert (upper_bounds(summ, q) >= per_shard_min - 1e-9).all()


if given is not None:
    @settings(max_examples=15, deadline=None)
    @given(
        family=st.sampled_from(FAMILIES),
        seed=st.integers(min_value=0, max_value=999),
        scale=st.sampled_from(SCALES),
        l=st.sampled_from(L_SET),
    )
    def test_routing_exactness_property(routing_fn, family, seed, scale, l):
        _routing_case(routing_fn, family, seed, scale, l)
else:
    @pytest.mark.parametrize("l", L_SET)
    @pytest.mark.parametrize("scale", SCALES)
    @pytest.mark.parametrize("family", FAMILIES)
    def test_routing_exactness_property(routing_fn, family, scale, l):
        for seed in (0, 7):
            _routing_case(routing_fn, family, seed, scale, l)


# ---- routing decision unit properties (host-side, no device work) --------

def test_route_shards_prefix_never_pruned():
    """Shards inside the cumulative-live prefix satisfy lb <= ub <= T and
    must survive routing, so the active set always holds >= min(l, live)
    points — the selection downstream stays exact."""
    pts, q = _instance("clustered", 3, 1.0)
    s = build_summaries(pts, K)
    for l in (1, 8, 64, 256, 1024):
        active = route_shards(s, q, np.full(B, l, np.int64))
        assert (s.live[None, :] * active).sum(-1).min() >= min(l, N)


def test_route_shards_padding_rows_route_nowhere():
    pts, q = _instance("uniform", 0, 1.0)
    s = build_summaries(pts, K)
    active = route_shards(s, q, np.array([0, 8, 0, 1]))
    assert not active[0].any() and not active[2].any()
    assert active[1].any() and active[3].any()


def test_route_shards_empty_shards_always_pruned():
    pts, q = _instance("uniform", 1, 1.0)
    valid = np.ones(N, bool)
    valid[:2 * M] = False                      # shards 0 and 1 empty
    s = build_summaries(pts, K, valid=valid)
    active = route_shards(s, q, np.full(B, 8))
    assert not active[:, :2].any()
    # l beyond the live count keeps every live shard
    active = route_shards(s, q, np.full(B, N))
    assert active[:, 2:].all()


def test_routing_exact_far_from_origin(mesh8):
    """Regression: clusters offset ~2000 from the origin at dim=32.  The
    f32 distance expansion quantizes to multiples of ~ulp(|q|^2) (~8
    here) while inter-cluster bound gaps stay O(10^2), so a margin that
    scales only with the threshold prunes shards holding the *computed*
    winner.  pipeline_error_bound makes the margin absolute in the
    coordinate magnitude; answers must stay bit-identical."""
    dim, m = 32, 64

    def fn(p, i, q, la, key, active):
        ex = core.knn_query_batched(p, i, q, 8, la, key, axis_name="x")
        pr = core.knn_query_batched(p, i, q, 8, la, key, axis_name="x",
                                    shard_active=active)
        return ex.dists, ex.ids, pr.dists, pr.ids

    f = jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P(None), P(None), P(None), P("x")),
        out_specs=(P(None),) * 4))
    n = K * m
    for seed in range(8):
        rng = np.random.default_rng(seed)
        pts, centers = sharded_clusters(K, m, dim, shift=2000.0, rng=rng)
        q = (centers[rng.integers(0, K, B)]
             + rng.normal(size=(B, dim))).astype(np.float32)
        la = np.full(B, 8, np.int32)
        summ = build_summaries(pts, K)
        active = route_shards(summ, q, la).any(axis=0)
        d_ex, i_ex, d_pr, i_pr = map(np.asarray, f(
            pts, np.arange(n, dtype=np.int32), q, la,
            jax.random.PRNGKey(seed), active))
        assert d_ex.tobytes() == d_pr.tobytes(), seed
        assert np.array_equal(i_ex, i_pr), seed


def test_route_shards_equidistant_prunes_nothing():
    """The adversarial tie instance: every shard's bounds coincide, so no
    shard may be ruled out (slack keeps the test conservative)."""
    pts, q = _instance("equidistant", 5, 1.0)
    s = build_summaries(pts, K)
    active = route_shards(s, q[:1], np.array([8]))
    assert active.all()


# ---- device-side routing kernel parity (kernels/routing.py) ---------------

ROUTE_PIVOTS = (1, 2, 4)


def _device_route_case(family, seed, pivots, l):
    """The kernel-parity contract: the Pallas routing prologue's per-row
    keep mask equals the host f64 ``route_shards`` decision bit for bit.
    The kernel computes in f32, but both sides share the decision
    structure (lower bound vs slacked threshold + magnitude-absolute
    error margin), and the margins dwarf f32 evaluation wobble — so the
    masks agree exactly, not just the downstream answers."""
    pts, q = _instance(family, seed, 1.0)
    s = build_summaries(pts, K, num_pivots=pivots)
    la = np.full(B, l, np.int64)
    host = route_shards(s, q, la, slack=CONFIG.route_slack)
    dev = np.asarray(kops.route_mask(q, la, routing_mod.pack_summaries(s),
                                     slack=CONFIG.route_slack))
    assert np.array_equal(host, dev), (family, seed, pivots, l)


if given is not None:
    @settings(max_examples=25, deadline=None)
    @given(family=st.sampled_from(FAMILIES),
           seed=st.integers(min_value=0, max_value=999),
           pivots=st.sampled_from(ROUTE_PIVOTS),
           l=st.sampled_from(L_SET))
    def test_route_mask_matches_host_router(family, seed, pivots, l):
        _device_route_case(family, seed, pivots, l)
else:
    @pytest.mark.parametrize("l", L_SET)
    @pytest.mark.parametrize("pivots", ROUTE_PIVOTS)
    @pytest.mark.parametrize("family", FAMILIES)
    def test_route_mask_matches_host_router(family, pivots, l):
        for seed in (0, 7):
            _device_route_case(family, seed, pivots, l)


def test_route_mask_tombstones_and_mixed_ls():
    """Kernel parity where the inputs are ugliest: dead rows scattered
    through every shard, two shards fully tombstoned, one store fully
    empty, and per-row l mixing 0 (padding rows) with live requests."""
    rng = np.random.default_rng(11)
    pts, q = _instance("clustered", 11, 1.0)
    la = np.array([0, 1, 8, 256], np.int64)
    for pivots in ROUTE_PIVOTS:
        valid = rng.random(N) > 0.3
        valid[:M] = False
        valid[3 * M:4 * M] = False
        s = build_summaries(pts, K, valid=valid, num_pivots=pivots)
        host = route_shards(s, q, la, slack=CONFIG.route_slack)
        dev = np.asarray(kops.route_mask(
            q, la, routing_mod.pack_summaries(s), slack=CONFIG.route_slack))
        assert np.array_equal(host, dev), pivots
        assert not dev[0].any()                  # l=0 rows route nowhere
        assert not dev[:, 0].any() and not dev[:, 3].any()
    s = build_summaries(pts, K, valid=np.zeros(N, bool))
    dev = np.asarray(kops.route_mask(
        q, la, routing_mod.pack_summaries(s), slack=CONFIG.route_slack))
    assert not dev.any()                         # empty store: keep nothing


def test_route_mask_equidistant_ties_keep_everything():
    """The adversarial tie instance through the kernel: every shard's
    bounds coincide, so the sort-free threshold (min upper bound whose
    cumulative live count covers l, ties included) may prune nothing —
    exactly like the host router's stable-argsort prefix."""
    pts, q = _instance("equidistant", 5, 1.0)
    for pivots in ROUTE_PIVOTS:
        s = build_summaries(pts, K, num_pivots=pivots)
        la = np.full(B, 8, np.int64)
        dev = np.asarray(kops.route_mask(
            q, la, routing_mod.pack_summaries(s), slack=CONFIG.route_slack))
        assert dev.all()
        assert np.array_equal(dev,
                              route_shards(s, q, la,
                                           slack=CONFIG.route_slack))


def test_route_mask_ref_matches_dispatcher():
    """The jnp reference path (route_mask_ref — what "oracle" mode and
    the unaligned-lane fallback run) is the same math as the kernel
    body, so it must agree with the dispatcher output bit for bit."""
    pts, q = _instance("uniform", 23, 1.0)
    s = build_summaries(pts, K, num_pivots=2)
    la = np.full(B, 8, np.int64)
    packed = routing_mod.pack_summaries(s)
    dev = np.asarray(kops.route_mask(q, la, packed,
                                     slack=CONFIG.route_slack))
    ref = np.asarray(routing_mod.route_mask_ref(
        q.astype(np.float32), la.astype(np.int32).reshape(-1, 1), *packed,
        dim_real=DIM, slack=CONFIG.route_slack)) != 0
    assert np.array_equal(dev, ref)


# ---- server-level: end-to-end A/B over the service path ------------------

def _server_pair(mesh8, pts=None, stores=None, **overrides):
    kw = dict(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=(4,))
    kw.update(overrides)
    mk = lambda route, backing: KnnServer(
        points=backing if stores is None else None,
        store=None if stores is None else backing,
        cfg=CONFIG.replace(**kw, route=route), mesh=mesh8, axis_name="x")
    if stores is None:
        return mk("exact", pts), mk("pruned", pts)
    return mk("exact", stores[0]), mk("pruned", stores[1])


def _assert_identical(res_exact, res_pruned):
    for a, b in zip(res_exact, res_pruned):
        assert a.dists.tobytes() == b.dists.tobytes()
        assert np.array_equal(a.ids, b.ids)
        assert a.generation == b.generation
        if a.values is not None or b.values is not None:
            assert np.array_equal(a.values, b.values)


def test_server_pruned_identical_and_cheaper_on_clusters(mesh8):
    """The acceptance contract: identical answers, strictly fewer
    k-machine messages, and shards_touched < k on a clustered workload."""
    pts, q = _instance("clustered", 11, 1.0)
    ex, pr = _server_pair(mesh8, pts=pts)
    # identity holds for any l mix, up to l_max (which spans half the set
    # and legitimately touches everything)
    ls = [1, 8, 256, 40]
    _assert_identical(ex.query_batch(q, ls), pr.query_batch(q, ls))
    # small-l batches are where routing pays: shards_touched is the batch
    # *union*, so keep the wide request out of this bucket
    ls = [1, 8, 4, 2]
    ra, rb = ex.query_batch(q, ls), pr.query_batch(q, ls)
    _assert_identical(ra, rb)
    assert all(r.shards_touched == K for r in ra)
    assert all(r.shards_touched < K for r in rb)
    assert all(b.messages < a.messages for a, b in zip(ra, rb))


def test_server_pruned_identical_gather_sampler(mesh8):
    """The gather baseline prunes identically (knn_simple path)."""
    pts, q = _instance("clustered", 13, 1.0)
    ex, pr = _server_pair(mesh8, pts=pts, sampler="gather", l_max=32)
    ra, rb = ex.query_batch(q, [1, 8, 32, 5]), pr.query_batch(q, [1, 8, 32, 5])
    _assert_identical(ra, rb)
    assert all(b.messages < a.messages for a, b in zip(ra, rb))


def _mutate_both(stores, fn):
    for s in stores:
        fn(s)


def test_server_pruned_identical_under_mutation(mesh8):
    """Mid-stream exactness: after every phase of an interleaved
    insert/delete/update/compact history, pruned answers stay
    bit-identical — routing summaries travel with the snapshot
    generation, so they can never describe a different epoch than the one
    answering (the generation-coupling invariant)."""
    rng = np.random.default_rng(42)
    batch1, centers = sharded_clusters(K, 30, DIM, rng=rng)
    stores = [MutableStore(DIM, capacity_per_shard=M, axis_name="x")
              for _ in range(2)]
    ex, pr = _server_pair(mesh8, stores=stores)
    q = (centers[rng.integers(0, K, B)]
         + rng.normal(size=(B, DIM))).astype(np.float32)
    ls = [1, 8, 256, 77]

    def check():
        ra, rb = ex.query_batch(q, ls), pr.query_batch(q, ls)
        _assert_identical(ra, rb)
        for s in stores:
            snap, summ = s.routing_snapshot()
            assert summ.generation == snap.generation
        return rb

    # phase 1: clustered ingest
    _mutate_both(stores, lambda s: (s.insert(batch1), s.flush()))
    check()

    # phase 2: interleaved deletes + inserts + updates
    ids = stores[0].live_arrays()[0]
    victims = ids[::3][:60]
    batch2 = rng.normal(size=(40, DIM)).astype(np.float32)
    moved = ids[1::3][:20]
    new_pos = rng.normal(size=(20, DIM)).astype(np.float32)

    def phase2(s):
        s.delete(victims)
        s.insert(batch2)
        s.update(moved, new_pos)
        s.flush()
    _mutate_both(stores, phase2)
    check()

    # phase 3: forced compaction (summaries rebuilt exactly)
    _mutate_both(stores, lambda s: s.compact())
    check()

    # phase 4: delete down to a handful -> compact leaves shards empty,
    # so pruning must fire even on a store-backed server
    keep = stores[0].live_arrays()[0][:5]
    _mutate_both(
        stores,
        lambda s: (s.delete(np.setdiff1d(s.live_arrays()[0], keep)),
                   s.compact()))
    rb = check()
    assert all(r.shards_touched < K for r in rb)
    assert stores[0].generation == stores[1].generation


def test_server_rejects_sketch_mismatch_with_store(mesh8):
    """Store-backed pruned servers route with the *store's* sketch; a
    conflicting service config must fail loudly, not be ignored."""
    store = MutableStore(DIM, capacity_per_shard=16, axis_name="x",
                         summary_projections=4)
    cfg = CONFIG.replace(dim=DIM, l=4, l_max=8, bucket_sizes=(1,),
                         route="pruned")        # asks for 8 projections
    with pytest.raises(ValueError, match="sketch mismatch"):
        KnnServer(store=store, cfg=cfg, mesh=mesh8)
    # matching config constructs fine
    KnnServer(store=store,
              cfg=cfg.replace(route_num_projections=4), mesh=mesh8)


# ---- adaptive multi-pivot exactness (store/adaptive.py) -------------------

ADAPTIVE_PIVOTS = (1, 2, 4)
ADAPTIVE_SHIFTS = (0.0, 2000.0)


@pytest.fixture(scope="module")
def adaptive_fn(mesh8):
    """One compile for the adaptive harness: exact and pruned Algorithm 2
    side by side over a store snapshot's capacity-padded, valid-masked
    buffers (every case re-uses this executable; only the host-side
    routing decision and the store history vary)."""
    def fn(p, i, v, q, la, key, active):
        ex = core.knn_query_batched(p, i, q, L_MAX, la, key, axis_name="x",
                                    point_valid=v)
        pr = core.knn_query_batched(p, i, q, L_MAX, la, key, axis_name="x",
                                    point_valid=v, shard_active=active)
        return ex.dists, ex.ids, pr.dists, pr.ids

    return jax.jit(shard_map(
        fn, mesh=mesh8,
        in_specs=(P("x"), P("x"), P("x"), P(None), P(None), P(None),
                  P("x")),
        out_specs=(P(None),) * 4))


def _adaptive_routing_case(adaptive_fn, pivots, seed, shift):
    """Multi-pivot exactness at the f32 edge: 2k clusters over k shards
    (so shards host two clusters — the layout pivot sets exist for),
    optionally far from the origin (where computed distances quantize to
    multiples of ulp(|q|²) and the magnitude-absolute error margin must
    hold the line), with every maintenance trigger armed — answers must
    stay bit-identical to route="exact" after every phase of an
    interleaved insert/delete/update/compact history."""
    rng = np.random.default_rng(seed)
    clusters = 2 * K
    centers = rng.normal(scale=8.0, size=(clusters, DIM)) + shift
    store = MutableStore(DIM, capacity_per_shard=M, axis_name="x",
                         placement="affinity", redeal="proximity",
                         summary_pivots=pivots, retighten_every=6,
                         split_radius_factor=1.2, staging_size=10 ** 9)
    q = (centers[rng.integers(0, clusters, B)]
         + rng.normal(size=(B, DIM))).astype(np.float32)
    la = np.array([1, 8, 256, 40], np.int32)

    def check():
        snap, summ = store.routing_snapshot()
        assert summ.generation == snap.generation
        if pivots > 1:
            assert summ.pivots is not None
        active = route_shards(summ, q, la, slack=CONFIG.route_slack).any(0)
        d_ex, i_ex, d_pr, i_pr = map(np.asarray, adaptive_fn(
            snap.points, snap.ids, snap.valid, q, la,
            jax.random.PRNGKey(seed), active))
        assert d_ex.tobytes() == d_pr.tobytes(), (pivots, seed, shift)
        assert np.array_equal(i_ex, i_pr), (pivots, seed, shift)

    # phase 1: two-clusters-per-shard ingest, flushed in waves so the
    # re-tightening schedule and (when armed) the split trigger run
    for c in range(clusters):
        store.insert((centers[c]
                      + rng.normal(size=(24, DIM))).astype(np.float32))
        if c % 4 == 3:
            store.flush()
    store.flush()
    check()

    # phase 2: interleaved deletes + inserts + updates
    ids = store.live_arrays()[0]
    store.delete(ids[::3])
    store.insert((centers[rng.integers(0, clusters)]
                  + rng.normal(size=(30, DIM))).astype(np.float32))
    moved = ids[1::3][:16]
    store.update(moved, (centers[rng.integers(0, clusters, 16)]
                         + rng.normal(size=(16, DIM))).astype(np.float32))
    store.flush()
    check()

    # phase 3: forced compaction (exact rebuild of every pivot set)
    store.compact()
    check()


if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(pivots=st.sampled_from(ADAPTIVE_PIVOTS),
           seed=st.integers(min_value=0, max_value=999),
           shift=st.sampled_from(ADAPTIVE_SHIFTS))
    def test_adaptive_multipivot_exactness(adaptive_fn, pivots, seed, shift):
        _adaptive_routing_case(adaptive_fn, pivots, seed, shift)
else:
    @pytest.mark.parametrize("shift", ADAPTIVE_SHIFTS)
    @pytest.mark.parametrize("pivots", ADAPTIVE_PIVOTS)
    def test_adaptive_multipivot_exactness(adaptive_fn, pivots, shift):
        for seed in (0, 7):
            _adaptive_routing_case(adaptive_fn, pivots, seed, shift)


def test_server_device_route_identical_static(mesh8):
    """route_compute="device" is a pure relocation of the routing
    decision: identical answers, identical touched-shard accounting,
    and the pruning still fires (< k shards on the clustered family)."""
    pts, q = _instance("clustered", 17, 1.0)
    mk = lambda rc: KnnServer(
        pts, cfg=CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=(4,),
                                route="pruned", route_compute=rc),
        mesh=mesh8, axis_name="x")
    host, dev = mk("host"), mk("device")
    dev.warmup()                     # device prologue compiles per bucket
    for ls in ([1, 8, 256, 40], [1, 8, 4, 2]):
        rh, rd = host.query_batch(q, ls), dev.query_batch(q, ls)
        _assert_identical(rh, rd)
        assert all(a.shards_touched == b.shards_touched
                   for a, b in zip(rh, rd))
    assert all(r.shards_touched < K for r in rd)
    assert dev.placement_stats()["prune_rate"] > 0


def test_server_device_route_identical_under_mutation(mesh8):
    """Store-backed device routing across a mutation history: after every
    phase (ingest waves arming re-tighten + split, interleaved
    deletes/updates, forced compaction) the device-routed server answers
    byte-identically to the host-routed twin, and the packed-summary
    cache follows the frozen summaries object across generations."""
    rng = np.random.default_rng(29)
    clusters = 2 * K
    centers = rng.normal(scale=8.0, size=(clusters, DIM))
    mk_store = lambda: MutableStore(
        DIM, capacity_per_shard=M, axis_name="x", placement="affinity",
        redeal="proximity", summary_pivots=2, retighten_every=6,
        split_radius_factor=1.2, staging_size=10 ** 9)
    stores = [mk_store(), mk_store()]
    kw = dict(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=(4,), route="pruned",
              summary_pivots=2)
    host, dev = (KnnServer(store=s,
                           cfg=CONFIG.replace(**kw, route_compute=rc),
                           mesh=mesh8)
                 for s, rc in zip(stores, ("host", "device")))
    q = (centers[rng.integers(0, clusters, B)]
         + rng.normal(size=(B, DIM))).astype(np.float32)
    ls = [1, 8, 256, 40]

    def check():
        rh, rd = host.query_batch(q, ls), dev.query_batch(q, ls)
        _assert_identical(rh, rd)
        assert all(a.shards_touched == b.shards_touched
                   for a, b in zip(rh, rd))

    for c in range(clusters):
        batch = (centers[c] + rng.normal(size=(20, DIM))).astype(np.float32)
        _mutate_both(stores, lambda s: s.insert(batch))
        if c % 4 == 3:
            _mutate_both(stores, lambda s: s.flush())
            check()
    ids = stores[0].live_arrays()[0]
    _mutate_both(stores, lambda s: (s.delete(ids[::3]), s.flush()))
    check()
    _mutate_both(stores, lambda s: s.compact())
    check()


def test_summary_covering_invariants_under_mutation(rng):
    """The maintainer's bounds stay *covering* through any op sequence:
    every live point within the shard radius, every projection inside its
    interval, live counts exact (violations are float64-rounding only)."""
    store = MutableStore(DIM, capacity_per_shard=32, axis_name="x",
                         staging_size=16)
    pts = rng.normal(scale=5.0, size=(180, DIM)).astype(np.float32)
    ids = store.insert(pts)
    store.flush()
    store.delete(ids[::4])
    store.update(ids[1::4], rng.normal(size=(len(ids[1::4]), DIM))
                 .astype(np.float32))
    store.flush()
    inv = summary_invariants(store.summaries(), store._pts, store._valid,
                             store.cap)
    assert inv["live_mismatch"] == 0
    assert inv["radius_violation"] <= 1e-9
    assert inv["projection_violation"] <= 1e-9
    # compaction re-tightens: rebuilt bounds still cover
    store.compact()
    inv = summary_invariants(store.summaries(), store._pts, store._valid,
                             store.cap)
    assert inv["radius_violation"] <= 1e-9
    assert inv["projection_violation"] <= 1e-9


def test_pivot_live_undercount_and_threshold_soundness(rng):
    """Per-pivot routing accounting under heavy deletes: the per-ball
    live credits stay a *safe undercount* of true ball membership
    (insert credits exactly one ball; delete debits every occupied
    containing ball, so no ball is ever over-credited), per-shard
    totals never exceed the live count, and the ball-granular
    cumulative-live threshold inside route_shards stays sound — the
    kept mask always contains every shard holding a true f64 top-l
    winner, for every row."""
    store = MutableStore(DIM, capacity_per_shard=M, axis_name="x",
                         summary_pivots=4, staging_size=10 ** 9)
    clusters = 2 * K
    centers = rng.normal(scale=30.0, size=(clusters, DIM))
    for c in range(clusters):
        store.insert((centers[c]
                      + rng.normal(size=(20, DIM))).astype(np.float32))
    store.flush()

    la = np.array([1, 8, 256, 40], np.int32)
    for wave in range(3):
        ids = store.live_arrays()[0]
        store.delete(rng.permutation(ids)[: int(len(ids) * 0.45)])
        store.insert((centers[rng.integers(0, clusters)]
                      + rng.normal(size=(8, DIM))).astype(np.float32))
        store.flush()
        summ = store.summaries()
        pts_, valid_ = store._pts, store._valid

        # (a) undercount oracle, ball by ball
        for j in range(K):
            sl = slice(j * store.cap, (j + 1) * store.cap)
            live_pts = pts_[sl][valid_[sl]].astype(np.float64)
            assert (summ.pivot_live[j] >= 0).all()
            assert summ.pivot_live[j].sum() <= valid_[sl].sum()
            for p in range(int(summ.pivot_count[j])):
                if len(live_pts):
                    d = np.sqrt(((live_pts - summ.pivots[j, p]) ** 2)
                                .sum(-1))
                    r = summ.pivot_radii[j, p]
                    true_in = int((d <= r * (1 + 1e-9) + 1e-9).sum())
                else:
                    true_in = 0
                assert summ.pivot_live[j, p] <= true_in, (wave, j, p)

        # (b) bound soundness: kept shards cover the true f64 winners
        q = (centers[rng.integers(0, clusters, B)]
             + rng.normal(size=(B, DIM))).astype(np.float32)
        mask = route_shards(summ, q, la, slack=CONFIG.route_slack)
        slots = np.flatnonzero(valid_)
        for b_ in range(B):
            d = ((pts_[slots].astype(np.float64)
                  - q[b_].astype(np.float64)) ** 2).sum(-1)
            top = slots[np.argsort(d, kind="stable")[:int(la[b_])]]
            for shard in set(top // store.cap):
                assert mask[b_, shard], (wave, b_, shard)
