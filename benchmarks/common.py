"""Shared benchmark plumbing.

The k-machine-model benchmarks need k >= 2 host devices; like
launch/dryrun.py (which claims 512), the benchmark entrypoint claims its
own process-local device count — nothing leaks into tests or other runs.
"""

from __future__ import annotations

import os

K_MACHINES = 8

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={K_MACHINES} "
        + os.environ.get("XLA_FLAGS", ""))

import datetime  # noqa: E402
import subprocess  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from repro.parallel.compat import make_mesh


def kmachine_mesh(k: int = K_MACHINES):
    return make_mesh((k,), ("x",))


def stamp(report: dict) -> dict:
    """Attach provenance metadata to a BENCH_*.json report (in place).

    Every emitted report carries ``meta.git_commit`` / ``meta.timestamp``
    / ``meta.jax_version`` so the benchmark trajectory across PRs is
    reconstructable from the JSON artifacts alone.
    """
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except Exception:
        commit = ""
    report["meta"] = {
        "git_commit": commit or "unknown",
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "jax_version": jax.__version__,
    }
    return report


def time_fn(fn, *args, repeats: int = 20, warmup: int = 3) -> float:
    """Median wall seconds per call (fn must return jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def obs_section(srv) -> dict:
    """Compact ``obs`` payload for a BENCH_*.json, read off one
    ``KnnServer.obs_snapshot()``: audit verdicts (Theorem-1 contract +
    shadow-exact), the per-stage p50/p99 latency breakdown from the
    unified registry (src/repro/obs/metrics.py), kernel-fallback
    counters, and tracer ring stats.  ``make obs-smoke``
    (benchmarks/check_obs.py) asserts on these fields."""
    snap = srv.obs_snapshot()
    stages = {}
    for name, payload in snap["metrics"].items():
        if (name.startswith(("serve.", "maint.", "store."))
                and isinstance(payload, dict) and "p50" in payload):
            stages[name] = {"count": payload["count"],
                            "mean": payload["mean"],
                            "p50": payload["p50"],
                            "p99": payload["p99"]}
    contract = snap["audit"]["contract"]
    shadow = snap["audit"]["shadow"]
    return {
        "stages": stages,
        "contract_checks": contract["checks"],
        "contract_violations": contract["violations"],
        "contract_details": contract["details"],
        "shadow_every": shadow["every"],
        "shadow_checks": shadow["checks"],
        "shadow_divergences": shadow["divergences"],
        "kernel_fallbacks": snap["kernel"],
        "trace": snap["trace"],
    }
