"""Theorem 2.4 — message complexity O(k log l).

Counts the actual messages our SPMD implementation exchanges per query
(derived from the measured iteration count and the implementation's
collective schedule: 1 gather + 1 psum per iteration, 1 sampling gather,
1 verification psum, 1 output pack) and checks the O(k log l) envelope.
In the k-machine accounting, one all-gather/psum over k machines costs
k-1 messages on a star and 2(k-1) on the all-to-all ICI analogue — we
report the star count, matching the paper's leader-centric accounting.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import kmachine_mesh, row
import repro.core as core
from repro.parallel.compat import shard_map


def run(emit=print):
    rng = np.random.default_rng(0)
    dim = 8
    for k in (2, 4, 8):
        mesh = kmachine_mesh(k)
        n = k * (1 << 13)
        pts = rng.normal(size=(n, dim)).astype(np.float32)
        pids = np.arange(n, dtype=np.int32)
        for l in (32, 256):
            q = rng.normal(size=(1, dim)).astype(np.float32)

            def fn(p, i, qq, key):
                r = core.knn_query(p, i, qq, l, key, axis_name="x")
                return r.selection.iterations

            f = jax.jit(shard_map(
                fn, mesh=mesh,
                in_specs=(P("x"), P("x"), P(None), P(None)),
                out_specs=P()))
            iters = float(f(pts, pids, q, jax.random.PRNGKey(0)))
            # collective phases: sampling(1) + verify(1) + iters*(2) + out(2)
            phases = 4 + 2 * iters
            messages = (k - 1) * phases
            bound = k * max(np.log(l), 1.0)
            emit(row(f"messages/k{k}_l{l}", messages,
                     f"iters={iters:.0f};messages={messages:.0f};"
                     f"k_log_l={bound:.0f};ratio={messages/bound:.2f}"))


if __name__ == "__main__":
    run()
