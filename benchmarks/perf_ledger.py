"""Perf-regression ledger: the repo's tracked bench trajectory.

``BENCH_serve.json`` is overwritten by every run — before this module
the repo had *no* memory of whether a commit made serving worse.  Now
every ``bench_serve`` run (smoke and full-size) appends one stamped
summary row to ``BENCH_history.jsonl`` — a tracked, append-only ledger
keyed by ``git_commit`` — and ``benchmarks/check_perf.py`` compares the
current run against a rolling baseline of prior rows with
noise-tolerant bounds, failing CI on a regression.

A row is deliberately small and flat (one JSON object per line): the
headline qps/p50/p99 of the selection sampler, the routed arm's qps and
touched-shard count, the approximate tier's candidate fraction and
measured recall floor, the ensemble-prediction arm's accuracy and
per-query message bill, and the contract/shadow audit counters.  Smoke
and full-size rows carry a ``smoke`` flag and are baselined separately
— their absolute numbers differ by an order of magnitude.

Stdlib + nothing: this module is imported by CI gates that must not
depend on jax being importable.
"""

from __future__ import annotations

import json
import statistics
from typing import Optional

SCHEMA = "knn.perf.v1"

# The numeric fields a baseline is computed over (median per field
# across the window of prior same-flag rows).
NUMERIC_FIELDS = (
    "qps", "p50_ms", "p99_ms", "routed_qps", "shards_touched",
    "candidate_fraction", "recall_min",
    "predict_accuracy", "predict_messages",
)


def summarize(report: dict) -> dict:
    """One ledger row from a full ``bench_serve`` report dict (the
    ``BENCH_serve.json`` payload, after ``common.stamp``)."""
    sel = report.get("selection", {})
    pruned = report.get("routing", {}).get("pruned", {})
    clustered = report.get("index", {}).get("clustered", {})
    ensemble = report.get("predict", {}).get("ensemble", {})
    obs = report.get("obs", {})
    meta = report.get("meta", {})
    return {
        "schema": SCHEMA,
        "git_commit": meta.get("git_commit", "unknown"),
        "timestamp": meta.get("timestamp", ""),
        "jax_version": meta.get("jax_version", ""),
        "smoke": bool(report.get("smoke", False)),
        "n_points": report.get("n_points"),
        "qps": sel.get("qps"),
        "p50_ms": sel.get("p50_ms"),
        "p99_ms": sel.get("p99_ms"),
        "routed_qps": pruned.get("qps"),
        "shards_touched": pruned.get("mean_shards_touched"),
        "candidate_fraction": clustered.get("candidate_fraction_mean"),
        "recall_min": clustered.get("recall_min"),
        "predict_accuracy": ensemble.get("accuracy"),
        "predict_messages": ensemble.get("mean_messages"),
        "contract_checks": obs.get("contract_checks"),
        "contract_violations": obs.get("contract_violations"),
        "shadow_checks": obs.get("shadow_checks"),
        "shadow_divergences": obs.get("shadow_divergences"),
    }


def append_row(row: dict, path: str) -> None:
    """Append one row to the JSONL ledger (created if absent)."""
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")


def load_history(path: str) -> list:
    """All ledger rows, oldest first; [] for a missing file.  A
    malformed line raises — the ledger is tracked, corruption is a
    repo bug, not an operational condition to paper over."""
    try:
        f = open(path)
    except FileNotFoundError:
        return []
    with f:
        return [json.loads(line) for line in f if line.strip()]


def baseline(history: list, *, smoke: bool,
             window: int = 5) -> Optional[dict]:
    """Rolling baseline: per-field median over the newest ``window``
    rows with the same smoke flag.  None when no prior row matches
    (bootstrap — the first run of a flavor has nothing to regress
    against)."""
    same = [r for r in history
            if bool(r.get("smoke", False)) == bool(smoke)][-window:]
    if not same:
        return None
    base = {"rows": len(same),
            "commits": [r.get("git_commit", "unknown") for r in same]}
    for field in NUMERIC_FIELDS:
        vals = [float(r[field]) for r in same
                if r.get(field) is not None]
        base[field] = statistics.median(vals) if vals else None
    return base
