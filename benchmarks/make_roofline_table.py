"""Regenerate the EXPERIMENTS.md roofline table from results/dryrun/*.json."""

import glob
import json
import sys


def fmt(x):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) < 1e-2 or abs(x) >= 1e4:
        return f"{x:.2e}"
    return f"{x:.3f}"


def main(results_dir="results/dryrun", mesh="single"):
    rows = []
    for p in sorted(glob.glob(f"{results_dir}/*__{mesh}.json")):
        r = json.load(open(p))
        cell = r["cell"].rsplit("|", 1)[0]
        arch, shape = cell.split("|")
        if r["status"] == "SKIP":
            rows.append((arch, shape, "SKIP(full-attention)", "", "", "",
                         "", "", ""))
            continue
        if r["status"] != "OK":
            rows.append((arch, shape, "FAIL", "", "", "", "", "", ""))
            continue
        ro = r["roofline"]
        mem = r["memory"]
        tot = sum(v for k, v in mem.items()
                  if isinstance(v, (int, float)) and k.endswith("device"))
        rows.append((
            arch, shape, ro["dominant"],
            fmt(ro["compute_s"]), fmt(ro["memory_s"]),
            fmt(ro["collective_s"]),
            fmt(ro["model_flops"]),
            fmt(ro["useful_flops_frac"]),
            f"{tot/2**30:.1f}",
        ))
    print("| arch | shape | bottleneck | compute_s | memory_s | "
          "collective_s | MODEL_FLOPS | useful_frac | GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for row in rows:
        print("| " + " | ".join(str(x) for x in row) + " |")


if __name__ == "__main__":
    main(*sys.argv[1:])
