"""Lemma 2.3 — the sample-prune survivor envelope — and the shard-routing
prune rate.

Over many random instances: survivor counts land in [l, 11 l] w.h.p., the
verification (Las Vegas hardening) acceptance rate is ~1, and the true
l-NN set always survives.  (The envelope assertions are also CI-enforced:
tests/test_sampling.py test_prune_survivor_envelope_sweep.)

The routing section measures the *other* prune in the stack — per-shard
pivot summaries (store/summaries.py): what fraction of the k shards the
lower-bound test rules out per query, on clustered vs uniform instances,
with the exactness invariant (every true l-NN winner lives in a kept
shard) checked on every query.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import kmachine_mesh, row
from repro.core import sampling
from repro.data import sharded_clusters
from repro.parallel.compat import shard_map
from repro.store import build_summaries, route_shards


def run_routing(emit=print, k: int = 8, m: int = 2048, dim: int = 32,
                n_queries: int = 64):
    """Summary-routing prune rate + exactness spot-check (host-only)."""
    rng = np.random.default_rng(0)
    clustered, centers = sharded_clusters(k, m, dim, rng=rng)
    instances = {
        "clustered": clustered,
        "uniform": rng.normal(size=(k * m, dim)),
    }
    for name, pts in instances.items():
        pts = pts.astype(np.float32)
        if name == "clustered":
            q = centers[rng.integers(0, k, n_queries)] + rng.normal(
                size=(n_queries, dim))
        else:
            q = rng.normal(size=(n_queries, dim))
        q = q.astype(np.float32)
        summ = build_summaries(pts, k)
        for l in (8, 128):
            active = route_shards(summ, q, np.full(n_queries, l))
            # exactness: all true l-NN ids must live in kept shards
            d = ((q[:, None, :].astype(np.float64)
                  - pts[None].astype(np.float64)) ** 2).sum(-1)
            top = np.argsort(d, axis=1, kind="stable")[:, :l]
            ok = all(active[b, top[b] // m].all() for b in range(n_queries))
            touched = active.sum(-1)
            emit(row(f"route/{name}_l{l}", float(touched.mean()),
                     f"mean_touched={touched.mean():.2f}/{k};"
                     f"max={touched.max()};exact={'1' if ok else '0'}"))


def run(emit=print):
    k = 8
    mesh = kmachine_mesh(k)
    rng = np.random.default_rng(0)
    for l in (64, 256, 1024):
        def fn(d, key):
            r = sampling.sample_prune(d, key, l, axis_name="x")
            return r.survivors, r.applied

        f = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(P(None, "x"), P(None)),
            out_specs=(P(None), P(None)), check_vma=False))
        surv, acc, lost = [], 0, 0
        trials = 50
        for t in range(trials):
            d = rng.exponential(size=(1, k * l)).astype(np.float32)
            s, a = f(d, jax.random.PRNGKey(t))
            surv.append(int(np.asarray(s)[0]))
            acc += int(np.asarray(a)[0])
        surv = np.array(surv)
        emit(row(f"prune/l{l}", float(surv.mean()),
                 f"mean_survivors={surv.mean():.0f};max={surv.max()};"
                 f"bound_11l={11*l};within_bound="
                 f"{(surv <= 11*l).mean():.2f};accept_rate={acc/trials:.2f}"))
    run_routing(emit)


if __name__ == "__main__":
    run()
