"""Lemma 2.3 — the sample-prune survivor envelope.

Over many random instances: survivor counts land in [l, 11 l] w.h.p., the
verification (Las Vegas hardening) acceptance rate is ~1, and the true
l-NN set always survives.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import kmachine_mesh, row
from repro.core import sampling
from repro.parallel.compat import shard_map


def run(emit=print):
    k = 8
    mesh = kmachine_mesh(k)
    rng = np.random.default_rng(0)
    for l in (64, 256, 1024):
        def fn(d, key):
            r = sampling.sample_prune(d, key, l, axis_name="x")
            return r.survivors, r.applied

        f = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=(P(None, "x"), P(None)),
            out_specs=(P(None), P(None)), check_vma=False))
        surv, acc, lost = [], 0, 0
        trials = 50
        for t in range(trials):
            d = rng.exponential(size=(1, k * l)).astype(np.float32)
            s, a = f(d, jax.random.PRNGKey(t))
            surv.append(int(np.asarray(s)[0]))
            acc += int(np.asarray(a)[0])
        surv = np.array(surv)
        emit(row(f"prune/l{l}", float(surv.mean()),
                 f"mean_survivors={surv.mean():.0f};max={surv.max()};"
                 f"bound_11l={11*l};within_bound="
                 f"{(surv <= 11*l).mean():.2f};accept_rate={acc/trials:.2f}"))


if __name__ == "__main__":
    run()
