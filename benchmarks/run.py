"""Benchmark harness — one module per paper table/figure/claim.

Prints ``name,us_per_call,derived`` CSV rows:

  bench_fig2      paper Figure 2 (Alg 2 vs simple method)
  bench_rounds    Theorems 2.2 / 2.4 (round complexity, k-independence)
  bench_messages  Theorem 2.4 (message complexity O(k log l))
  bench_prune     Lemma 2.3 (sample-prune survivor envelope)
  bench_topk      sampler-level selection-vs-gather crossover
  bench_kernels   fused distance+top-l traffic model vs oracle timing
"""

from benchmarks import common  # noqa: F401  (claims the 8-device mesh)


def main() -> None:
    from benchmarks import (bench_fig2, bench_kernels, bench_messages,
                            bench_prune, bench_rounds, bench_topk)
    print("name,us_per_call,derived")
    for mod in (bench_rounds, bench_fig2, bench_messages, bench_prune,
                bench_topk, bench_kernels):
        mod.run(emit=print)


if __name__ == "__main__":
    main()
