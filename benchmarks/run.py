"""Benchmark harness — one module per paper table/figure/claim.

Prints ``name,us_per_call,derived`` CSV rows:

  bench_fig2      paper Figure 2 (Alg 2 vs simple method)
  bench_rounds    Theorems 2.2 / 2.4 (round complexity, k-independence)
  bench_messages  Theorem 2.4 (message complexity O(k log l))
  bench_prune     Lemma 2.3 (sample-prune survivor envelope)
  bench_topk      sampler-level selection-vs-gather crossover
  bench_kernels   fused distance+top-l traffic model vs oracle timing
  bench_serve     micro-batched query service qps + p50/p99 latency
                  (also standalone: emits BENCH_serve.json — see its header)
  bench_ingest    mutable-store ingest throughput + latency under ingest
                  (also standalone: emits BENCH_ingest.json — see its header)

Paste the CSV into the EXPERIMENTS.md "Benchmark results" table.
"""

from benchmarks import common  # noqa: F401  (claims the 8-device mesh)


def main() -> None:
    from benchmarks import (bench_fig2, bench_ingest, bench_kernels,
                            bench_messages, bench_prune, bench_rounds,
                            bench_serve, bench_topk)
    print("name,us_per_call,derived")
    for mod in (bench_rounds, bench_fig2, bench_messages, bench_prune,
                bench_topk, bench_kernels, bench_serve, bench_ingest):
        mod.run(emit=print)


if __name__ == "__main__":
    main()
