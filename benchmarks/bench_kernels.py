"""Kernel-layer benchmark: fused distance+top-l vs unfused oracle.

On this CPU container the Pallas kernels run in interpret mode (Python) —
meaningless to time.  What IS meaningful on CPU: the oracle pipeline's
wall time (XLA-fused jnp) as the baseline the TPU kernel must beat, and
the ANALYTIC HBM-traffic model of both variants (the quantity the fused
kernel optimizes; see kernels/distance_topk.py header).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro.kernels import ref


def run(emit=print):
    rng = np.random.default_rng(0)
    # CPU-feasible timing shape
    B, d, m, l = 64, 512, 1 << 13, 64
    q = rng.normal(size=(B, d)).astype(np.float32)
    p = rng.normal(size=(m, d)).astype(np.float32)
    f = jax.jit(lambda q, p: ref.distance_topk_ref(q, p, l))
    t = time_fn(lambda: f(q, p), repeats=5)
    emit(row(f"kernels/oracle_timing_B{B}_m{m}", t * 1e6,
             f"oracle_us={t*1e6:.0f};flops={2.0*B*m*d:.2e}"))

    # traffic model at serving shapes (kNN-LM decode batches): the fused
    # kernel's win grows as the (B, m) score matrix starts dominating the
    # (m, d) point reads — i.e. exactly the high-QPS regime.
    for (B, d, m, l) in [(256, 1024, 1 << 20, 64), (2048, 512, 1 << 20, 64),
                         (8192, 512, 1 << 20, 64)]:
        unfused_hbm = 4.0 * (B * d + m * d + 2 * B * m + B * l * 2)
        fused_hbm = 4.0 * (B * d + m * d + B * l * 2)
        flops = 2.0 * B * m * d
        emit(row(f"kernels/traffic_model_B{B}_d{d}", flops / fused_hbm,
                 f"flops={flops:.2e};hbm_unfused={unfused_hbm:.2e};"
                 f"hbm_fused={fused_hbm:.2e};"
                 f"traffic_saving={unfused_hbm/fused_hbm:.1f}x;"
                 f"intensity_fused={flops/fused_hbm:.0f};"
                 f"intensity_unfused={flops/unfused_hbm:.0f};"
                 f"v5e_crossover_intensity=240"))


if __name__ == "__main__":
    run()
