"""Sampler crossover — selection (paper) vs gather (simple method) at the
vocab-top-k layer, the production face of Figure 2.

Reports per-token wall time on the simulated mesh plus the wire-byte model:
gather moves k_machines x k_sel (val,id) pairs; selection moves O(log k_sel)
scalar rounds + the k winners.  On real ICI the crossover sits where
latency x rounds beats bytes / bandwidth — both sides are recorded so the
EXPERIMENTS.md analysis can place it.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import kmachine_mesh, row, time_fn
import repro.core as core
from repro.parallel.compat import shard_map


def run(emit=print):
    k = 8
    mesh = kmachine_mesh(k)
    rng = np.random.default_rng(0)
    V, B = k * 19008, 8          # ~152k vocab over 8 machines
    logits = rng.normal(size=(B, V)).astype(np.float32)

    for ksel in (8, 64, 256):
        for method in ("selection", "gather"):
            def fn(lg, key):
                r = core.distributed_topk(lg, ksel, key, axis_name="x",
                                          method=method)
                return r.values, r.iterations

            f = jax.jit(shard_map(
                fn, mesh=mesh, in_specs=(P(None, "x"), P(None)),
                out_specs=(P(None), P())))
            key = jax.random.PRNGKey(0)
            t = time_fn(lambda: f(logits, key), repeats=10)
            _, iters = f(logits, key)
            if method == "gather":
                wire = k * ksel * 8 * B
            else:
                wire = (float(iters) * k * (3 * 4) * B
                        + 2 * ksel * 4 * B + k * 4 * B)
            emit(row(f"topk/{method}_k{ksel}", t * 1e6,
                     f"us={t*1e6:.0f};wire_bytes={wire:.0f};"
                     f"iters={float(iters):.0f}"))


if __name__ == "__main__":
    run()
