"""CI gate for the observability plane (make obs-smoke).

Validates the artifacts a ``bench_serve.py --smoke`` run just emitted —
the ``obs`` and ``index`` sections of the BENCH JSON and the
flight-recorder JSONL — against the PR's acceptance bar:

  * zero Theorem-1 contract violations and zero shadow-exact divergences,
    with both auditors demonstrably *active* (checks > 0);
  * the ``search="approx"`` contract (ISSUE 8): on both index A/B arms
    (clustered and drifting) measured recall@l stays at/above the
    configured floor with the recall-mode shadow auditor active and
    clean, and the clustered arm achieves >= 3x candidate reduction;
  * the span export parses, reassembles into well-formed trees
    (``repro.obs.trace.build_trees`` — no torn, orphaned, or
    time-inverted spans), and contains at least one *complete* routed
    query (a request tree with queued + serve children AND a dispatch
    tree with snapshot, route, kernel, and resolve stages) racing at
    least one committed maintenance cycle;
  * the per-stage latency breakdown is present (p50/p99 per stage);
  * the label-prediction contract (ISSUE 10): the exact arm matched the
    single-machine oracle vote on every query, and every ensemble arm
    held the accuracy floor under the one-message-per-shard bill
    (messages == shards_touched, one round) with the accuracy-mode
    shadow auditor active and clean;
  * the operator layer (ISSUE 9): the ``index`` section carries a
    well-formed query-explain report for a routed approx query whose
    kept-bucket set matched the recomputed keep rule; the ``obs``
    section's forced-breach SLO fired AND cleared (with the slo.* spans
    present in the trace artifact); and the ``--prom`` Prometheus text
    file parses under the strict round-trip parser with the serving
    histograms present and internally consistent.

Pure stdlib + the obs package; exits non-zero with a named reason on the
first failed check.

  PYTHONPATH=src:. python benchmarks/check_obs.py \
      --bench /tmp/BENCH_serve_smoke.json --trace /tmp/BENCH_trace.jsonl \
      --prom /tmp/BENCH_prom_smoke.txt
"""

import argparse
import collections
import json
import sys

from repro.obs.explain import SCHEMA as EXPLAIN_SCHEMA
from repro.obs.export import parse_prometheus_text
from repro.obs.trace import build_trees


def fail(msg: str):
    print(f"check_obs: FAIL: {msg}")
    sys.exit(1)


def check_bench(path: str) -> dict:
    with open(path) as f:
        report = json.load(f)
    obs = report.get("obs")
    if not obs:
        fail(f"{path} has no 'obs' section")
    if obs["contract_checks"] <= 0:
        fail("contract auditor never ran (checks == 0)")
    if obs["contract_violations"] != 0:
        fail(f"Theorem-1 contract violated: {obs['contract_details']}")
    if obs["shadow_every"] <= 0:
        fail("shadow auditor disabled (obs_audit_every == 0)")
    if obs["shadow_checks"] <= 0:
        fail("shadow auditor never ran (checks == 0)")
    if obs["shadow_divergences"] != 0:
        fail(f"shadow-exact divergence: {obs['shadow_divergences']}")
    stages = obs.get("stages", {})
    for required in ("serve.kernel_s", "serve.resolve_s", "serve.latency_s"):
        payload = stages.get(required)
        if not payload or payload["count"] <= 0:
            fail(f"stage histogram {required} missing or empty")
        if not (0 <= payload["p50"] <= payload["p99"]):
            fail(f"stage {required}: p50/p99 not ordered")
    print(f"check_obs: bench ok — contract {obs['contract_checks']} checks"
          f"/0 violations, shadow {obs['shadow_checks']} checks"
          f"/0 divergences, {len(stages)} stage histograms")
    return obs


def check_index(path: str):
    """The ``search="approx"`` recall contract, re-asserted from the
    JSON artifact (the bench also asserts inline; this gate catches a
    report produced by an older script or a hand-edited artifact)."""
    with open(path) as f:
        report = json.load(f)
    idx = report.get("index")
    if not idx:
        fail(f"{path} has no 'index' section")
    floor = idx["recall_floor"]
    for arm_name in ("clustered", "drifting"):
        arm = idx.get(arm_name)
        if not arm:
            fail(f"index section missing the {arm_name!r} arm")
        if arm["recall_count"] <= 0:
            fail(f"index/{arm_name}: recall never measured")
        if arm["recall_min"] < floor:
            fail(f"index/{arm_name}: recall@l {arm['recall_min']:.3f} "
                 f"below the {floor} floor")
        shadow = arm["shadow"]
        if shadow["mode"] != "recall":
            fail(f"index/{arm_name}: shadow auditor not in recall mode")
        if shadow["checks"] <= 0:
            fail(f"index/{arm_name}: recall shadow auditor never ran")
        if shadow["divergences"] != 0:
            fail(f"index/{arm_name}: {shadow['divergences']} recall-floor "
                 f"violations flagged by the shadow auditor")
    if idx["clustered"]["candidate_reduction"] < 3.0:
        fail(f"index/clustered: candidate reduction "
             f"{idx['clustered']['candidate_reduction']:.2f}x below 3x")
    print(f"check_obs: index ok — clustered recall_min "
          f"{idx['clustered']['recall_min']:.3f} at "
          f"{idx['clustered']['candidate_reduction']:.1f}x reduction, "
          f"drifting recall_min {idx['drifting']['recall_min']:.3f} "
          f"(floor {floor})")


def check_explain(path: str):
    """The query-explain acceptance (ISSUE 9): the clustered approx arm
    must carry one well-formed report for a routed approx query, and
    the report itself must attest that its kept-bucket set matched the
    from-scratch recompute of the keep rule (the bench asserts this
    inline; the gate re-reads it from the artifact)."""
    with open(path) as f:
        report = json.load(f)
    rep = report.get("index", {}).get("explain")
    if not rep:
        fail(f"{path} index section has no 'explain' report")
    if rep.get("schema") != EXPLAIN_SCHEMA:
        fail(f"explain schema {rep.get('schema')!r} != {EXPLAIN_SCHEMA!r}")
    for key in ("batch", "request", "routing", "index", "timings",
                "maintenance"):
        if key not in rep:
            fail(f"explain report missing the {key!r} section")
    if rep["request"]["recall_mode"] != "approx":
        fail("explain report is not for an approx query")
    if rep["routing"]["mode"] != "pruned":
        fail("explain report is not for a routed (pruned) query")
    shards = rep["routing"]["shards"]
    kept = [s["shard"] for s in shards if s["kept"]]
    if kept != rep["routing"]["kept_shards"]:
        fail(f"explain routing inconsistent: per-shard rows keep {kept}, "
             f"kept_shards says {rep['routing']['kept_shards']}")
    for s in shards:
        if s["kept"] and not (s["lower"] <= rep["routing"]["threshold_eff"]):
            fail(f"explain shard {s['shard']}: kept but lower bound "
                 f"{s['lower']} above threshold_eff")
    if not rep["index"]["enabled"]:
        fail("explain report has the index tier disabled")
    if not rep["index"]["kept_matches_recompute"]:
        fail("explain kept-bucket set does not match the recomputed "
             "keep rule")
    print(f"check_obs: explain ok — row {rep['request']['row']} "
          f"(l={rep['request']['l']}) kept shards "
          f"{rep['routing']['kept_shards']}, "
          f"{len(rep['index']['kept_buckets'])} buckets, recompute match")


def check_predict(path: str):
    """The label-prediction contract (ISSUE 10), re-asserted from the
    JSON artifact: the exact arm matched the single-machine oracle vote
    on every query; every ensemble arm held the accuracy floor under
    the one-message-per-shard bill (messages == shards_touched, one
    round) with the accuracy-mode shadow auditor active and clean."""
    with open(path) as f:
        report = json.load(f)
    pred = report.get("predict")
    if not pred:
        fail(f"{path} has no 'predict' section")
    floor = pred["accuracy_floor"]
    exact = pred.get("exact")
    if not exact:
        fail("predict section missing the 'exact' arm")
    if exact["oracle_mismatches"] != 0:
        fail(f"predict/exact: {exact['oracle_mismatches']} answers "
             f"diverged from the single-machine oracle vote")
    for arm_name in ("ensemble", "ensemble_k1"):
        arm = pred.get(arm_name)
        if not arm:
            fail(f"predict section missing the {arm_name!r} arm")
        if not arm["bill_messages_eq_touched"]:
            fail(f"predict/{arm_name}: per-query messages == "
                 f"shards_touched was not asserted")
        if arm["mean_rounds"] != 1.0:
            fail(f"predict/{arm_name}: mean rounds "
                 f"{arm['mean_rounds']} != 1 (one-message protocol)")
        if arm["accuracy"] < floor:
            fail(f"predict/{arm_name}: accuracy {arm['accuracy']:.3f} "
                 f"below the {floor} floor")
        shadow = arm["shadow"]
        if shadow["mode"] != "accuracy":
            fail(f"predict/{arm_name}: shadow auditor not in "
                 f"accuracy mode")
        if shadow["checks"] <= 0:
            fail(f"predict/{arm_name}: accuracy shadow auditor "
                 f"never ran")
        if shadow["divergences"] != 0:
            fail(f"predict/{arm_name}: {shadow['divergences']} "
                 f"agreement-floor violations flagged")
    if len(pred.get("bill", [])) < 3:
        fail("predict section missing the accuracy-vs-message-bill "
             "table")
    print(f"check_obs: predict ok — exact oracle-identical on "
          f"{exact['queries']} queries at {exact['mean_messages']:.0f} "
          f"msgs/query; ensemble {pred['ensemble']['accuracy']:.3f} "
          f"accuracy at {pred['ensemble']['mean_messages']:.0f} "
          f"msgs/query (floor {floor})")


def check_slo(path: str):
    """The forced-breach SLO scenario: the bench ran an impossible
    latency objective, so the artifact must show the alert both fired
    and cleared, with nothing left firing."""
    with open(path) as f:
        report = json.load(f)
    slo = report.get("obs", {}).get("slo")
    if not slo:
        fail(f"{path} obs section has no 'slo' snapshot")
    if slo["alerts_fired"] < 1:
        fail("forced-breach SLO never fired")
    if slo["alerts_cleared"] < 1:
        fail("forced-breach SLO never cleared")
    if slo["firing"]:
        fail(f"SLO still firing at export time: {slo['firing']}")
    if "latency_p99" not in slo["objectives"]:
        fail("latency_p99 objective missing from the SLO snapshot")
    print(f"check_obs: slo ok — {slo['alerts_fired']} fired / "
          f"{slo['alerts_cleared']} cleared, none firing")


def check_prom(path: str):
    """The exposition artifact: strict-parse the Prometheus text the
    bench fetched over HTTP (the parser enforces TYPE lines, strictly
    increasing le bounds, cumulative monotonicity, and +Inf == count)
    and require the serving histograms."""
    with open(path) as f:
        text = f.read()
    try:
        parsed = parse_prometheus_text(text)
    except ValueError as exc:
        fail(f"{path} is not valid Prometheus text exposition: {exc}")
    if not parsed:
        fail(f"{path} parsed to zero metrics")
    for required in ("knn_serve_latency_s", "knn_serve_kernel_s"):
        payload = parsed.get(required)
        if not payload:
            fail(f"prometheus export missing {required}")
        if payload.get("type") == "histogram" and payload["count"] <= 0:
            fail(f"prometheus histogram {required} is empty")
    print(f"check_obs: prom ok — {len(parsed)} metrics parsed from "
          f"{path}")


def check_trace(path: str):
    records = []
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as exc:
                fail(f"{path}:{line_no}: bad JSONL line: {exc}")
    if not records:
        fail(f"{path} is empty")
    try:
        trees = build_trees(records)
    except ValueError as exc:
        fail(f"span export is not well-formed: {exc}")

    by_name = collections.Counter(r["name"] for r in records)
    children_of = collections.defaultdict(set)
    for r in records:
        if r["parent"]:
            children_of[r["parent"]].add(r["name"])

    complete_requests = sum(
        1 for r in records
        if r["name"] == "request"
        and {"queued", "serve"} <= children_of[r["span"]])
    complete_dispatches = sum(
        1 for r in records
        if r["name"] == "dispatch"
        and {"snapshot", "route", "kernel",
             "resolve"} <= children_of[r["span"]])
    if complete_requests == 0:
        fail("no complete request tree (queued + serve children)")
    if complete_dispatches == 0:
        fail("no complete dispatch tree "
             "(snapshot + route + kernel + resolve)")
    if by_name["maint.commit"] == 0:
        fail("no committed maintenance cycle in the trace window")
    if by_name["maint.cycle"] == 0 or by_name["maint.prepare"] == 0:
        fail("maintenance cycle/prepare spans missing")
    # the bench exports the trace after the forced-breach SLO cleared,
    # so the fire/clear transitions and the closed alert interval must
    # all be present as spans
    for slo_span in ("slo.fire", "slo.clear", "slo.alert"):
        if by_name[slo_span] == 0:
            fail(f"SLO span {slo_span!r} missing from the trace export")
    print(f"check_obs: trace ok — {len(records)} spans, {len(trees)} trees, "
          f"{complete_requests} complete request trees, "
          f"{complete_dispatches} complete dispatch trees, "
          f"{by_name['maint.commit']} maintenance commits, "
          f"{by_name['slo.alert']} slo alert intervals")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="/tmp/BENCH_serve_smoke.json")
    ap.add_argument("--trace", default="/tmp/BENCH_trace_smoke.jsonl")
    ap.add_argument("--prom", default="/tmp/BENCH_prom_smoke.txt")
    args = ap.parse_args()
    check_bench(args.bench)
    check_index(args.bench)
    check_explain(args.bench)
    check_predict(args.bench)
    check_slo(args.bench)
    check_prom(args.prom)
    check_trace(args.trace)
    print("check_obs: PASS")


if __name__ == "__main__":
    main()
