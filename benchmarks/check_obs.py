"""CI gate for the observability plane (make obs-smoke).

Validates the artifacts a ``bench_serve.py --smoke`` run just emitted —
the ``obs`` and ``index`` sections of the BENCH JSON and the
flight-recorder JSONL — against the PR's acceptance bar:

  * zero Theorem-1 contract violations and zero shadow-exact divergences,
    with both auditors demonstrably *active* (checks > 0);
  * the ``search="approx"`` contract (ISSUE 8): on both index A/B arms
    (clustered and drifting) measured recall@l stays at/above the
    configured floor with the recall-mode shadow auditor active and
    clean, and the clustered arm achieves >= 3x candidate reduction;
  * the span export parses, reassembles into well-formed trees
    (``repro.obs.trace.build_trees`` — no torn, orphaned, or
    time-inverted spans), and contains at least one *complete* routed
    query (a request tree with queued + serve children AND a dispatch
    tree with snapshot, route, kernel, and resolve stages) racing at
    least one committed maintenance cycle;
  * the per-stage latency breakdown is present (p50/p99 per stage).

Pure stdlib + the obs package; exits non-zero with a named reason on the
first failed check.

  PYTHONPATH=src:. python benchmarks/check_obs.py \
      --bench /tmp/BENCH_serve_smoke.json --trace /tmp/BENCH_trace.jsonl
"""

import argparse
import collections
import json
import sys

from repro.obs.trace import build_trees


def fail(msg: str):
    print(f"check_obs: FAIL: {msg}")
    sys.exit(1)


def check_bench(path: str) -> dict:
    with open(path) as f:
        report = json.load(f)
    obs = report.get("obs")
    if not obs:
        fail(f"{path} has no 'obs' section")
    if obs["contract_checks"] <= 0:
        fail("contract auditor never ran (checks == 0)")
    if obs["contract_violations"] != 0:
        fail(f"Theorem-1 contract violated: {obs['contract_details']}")
    if obs["shadow_every"] <= 0:
        fail("shadow auditor disabled (obs_audit_every == 0)")
    if obs["shadow_checks"] <= 0:
        fail("shadow auditor never ran (checks == 0)")
    if obs["shadow_divergences"] != 0:
        fail(f"shadow-exact divergence: {obs['shadow_divergences']}")
    stages = obs.get("stages", {})
    for required in ("serve.kernel_s", "serve.resolve_s", "serve.latency_s"):
        payload = stages.get(required)
        if not payload or payload["count"] <= 0:
            fail(f"stage histogram {required} missing or empty")
        if not (0 <= payload["p50"] <= payload["p99"]):
            fail(f"stage {required}: p50/p99 not ordered")
    print(f"check_obs: bench ok — contract {obs['contract_checks']} checks"
          f"/0 violations, shadow {obs['shadow_checks']} checks"
          f"/0 divergences, {len(stages)} stage histograms")
    return obs


def check_index(path: str):
    """The ``search="approx"`` recall contract, re-asserted from the
    JSON artifact (the bench also asserts inline; this gate catches a
    report produced by an older script or a hand-edited artifact)."""
    with open(path) as f:
        report = json.load(f)
    idx = report.get("index")
    if not idx:
        fail(f"{path} has no 'index' section")
    floor = idx["recall_floor"]
    for arm_name in ("clustered", "drifting"):
        arm = idx.get(arm_name)
        if not arm:
            fail(f"index section missing the {arm_name!r} arm")
        if arm["recall_count"] <= 0:
            fail(f"index/{arm_name}: recall never measured")
        if arm["recall_min"] < floor:
            fail(f"index/{arm_name}: recall@l {arm['recall_min']:.3f} "
                 f"below the {floor} floor")
        shadow = arm["shadow"]
        if shadow["mode"] != "recall":
            fail(f"index/{arm_name}: shadow auditor not in recall mode")
        if shadow["checks"] <= 0:
            fail(f"index/{arm_name}: recall shadow auditor never ran")
        if shadow["divergences"] != 0:
            fail(f"index/{arm_name}: {shadow['divergences']} recall-floor "
                 f"violations flagged by the shadow auditor")
    if idx["clustered"]["candidate_reduction"] < 3.0:
        fail(f"index/clustered: candidate reduction "
             f"{idx['clustered']['candidate_reduction']:.2f}x below 3x")
    print(f"check_obs: index ok — clustered recall_min "
          f"{idx['clustered']['recall_min']:.3f} at "
          f"{idx['clustered']['candidate_reduction']:.1f}x reduction, "
          f"drifting recall_min {idx['drifting']['recall_min']:.3f} "
          f"(floor {floor})")


def check_trace(path: str):
    records = []
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as exc:
                fail(f"{path}:{line_no}: bad JSONL line: {exc}")
    if not records:
        fail(f"{path} is empty")
    try:
        trees = build_trees(records)
    except ValueError as exc:
        fail(f"span export is not well-formed: {exc}")

    by_name = collections.Counter(r["name"] for r in records)
    children_of = collections.defaultdict(set)
    for r in records:
        if r["parent"]:
            children_of[r["parent"]].add(r["name"])

    complete_requests = sum(
        1 for r in records
        if r["name"] == "request"
        and {"queued", "serve"} <= children_of[r["span"]])
    complete_dispatches = sum(
        1 for r in records
        if r["name"] == "dispatch"
        and {"snapshot", "route", "kernel",
             "resolve"} <= children_of[r["span"]])
    if complete_requests == 0:
        fail("no complete request tree (queued + serve children)")
    if complete_dispatches == 0:
        fail("no complete dispatch tree "
             "(snapshot + route + kernel + resolve)")
    if by_name["maint.commit"] == 0:
        fail("no committed maintenance cycle in the trace window")
    if by_name["maint.cycle"] == 0 or by_name["maint.prepare"] == 0:
        fail("maintenance cycle/prepare spans missing")
    print(f"check_obs: trace ok — {len(records)} spans, {len(trees)} trees, "
          f"{complete_requests} complete request trees, "
          f"{complete_dispatches} complete dispatch trees, "
          f"{by_name['maint.commit']} maintenance commits")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="/tmp/BENCH_serve_smoke.json")
    ap.add_argument("--trace", default="/tmp/BENCH_trace_smoke.jsonl")
    args = ap.parse_args()
    check_bench(args.bench)
    check_index(args.bench)
    check_trace(args.trace)
    print("check_obs: PASS")


if __name__ == "__main__":
    main()
