"""Mutable-store ingest throughput + query latency under concurrent ingest.

Two phases against ``store.MutableStore`` (DESIGN.md Section 7):

  1. **Ingest throughput** — staged insert/delete/update batches applied
     via the on-device scatter path; points/sec per mutation kind, plus
     the cost of one forced compaction (full repack + re-upload).
     Runs twice: once with the default balance/round-robin store and
     once with ``placement="affinity"`` + ``redeal="proximity"``
     (store/placement.py), pricing the locality-aware write path.
  2. **Query latency under ingest** — the serving-plane A/B (DESIGN.md
     Section 11): one store-backed ``KnnServer`` with pruned device-side
     routing and ``maintenance="background"`` is measured twice with the
     same closed-loop query driver — first against a quiet store, then
     while a drifting-cluster ingest thread streams insert+delete waves
     (epoch swaps land continuously and the background worker re-tightens,
     splits, and compacts mid-run; the phase asserts at least one
     re-tighten AND one split actually fired).  The headline number is
     ``p99_ratio_vs_quiet``: how much serve-path tail latency concurrent
     ingest costs when maintenance runs off the flush path.  Also
     reported: generations spanned, worker counters, that zero
     in-flight queries were dropped across every swap, and the ``obs``
     payload (src/repro/obs/) — Theorem-1 contract checks, sampled
     shadow-exact replays, and the per-stage latency breakdown for the
     whole run.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src:. python benchmarks/bench_ingest.py --out BENCH_ingest.json
"""

try:
    from benchmarks import common  # noqa: F401  (claims the 8-device mesh)
except ImportError:  # run as a plain script
    import common

import argparse
import json
import threading
import time

import numpy as np

from repro.configs.knn_service import CONFIG

DIM = 32
L_MAX = 32
# store shape/staging come from the service config — the single source of
# service tuning (configs/knn_service.py)
CAP_PER_SHARD = CONFIG.store_capacity_per_shard
STAGING = CONFIG.store_staging_size
INGEST_BATCHES = 40            # measured apply cycles in phase 1
QUERIES_UNDER_INGEST = 160     # closed-loop queries in phase 2
BUCKETS = (1, 2, 4, 8)


def _mk_store(rng, cap, staging, prefill=0, placement="balance",
              redeal="round_robin"):
    from repro.store import MutableStore
    # store construction kwargs come from the service config (the single
    # source of service tuning), with the placement policy under test
    # swapped in (store/placement.py)
    kw = CONFIG.replace(store_capacity_per_shard=cap,
                        store_staging_size=staging, placement=placement,
                        redeal=redeal).store_kwargs()
    store = MutableStore(DIM, mesh=common.kmachine_mesh(), axis_name="x",
                         **kw)
    if prefill:
        store.insert(rng.normal(size=(prefill, DIM)).astype(np.float32))
        store.flush()
    return store


def _phase_ingest(rng, cap, staging, batches, placement="balance",
                  redeal="round_robin") -> dict:
    """Staged batch -> flush (scatter apply) throughput per mutation kind."""
    store = _mk_store(rng, cap, staging, placement=placement, redeal=redeal)
    total = store.total

    def timed_cycles(op) -> float:
        t0 = time.perf_counter()
        for _ in range(batches):
            op()
            store.flush()
        return time.perf_counter() - t0

    # inserts (store fills to batches*staging points)
    wall_ins = timed_cycles(lambda: store.insert(
        rng.normal(size=(staging, DIM)).astype(np.float32)))
    live_ids, _ = store.live_arrays()

    # updates (rewrite random live points in place)
    wall_upd = timed_cycles(lambda: store.update(
        rng.choice(live_ids, size=staging, replace=False),
        rng.normal(size=(staging, DIM)).astype(np.float32)))

    # deletes (drain half of what was inserted; may trigger auto-compaction)
    victims = iter(rng.permutation(live_ids)[: batches * staging // 2])
    wall_del = timed_cycles(lambda: store.delete(
        [next(victims) for _ in range(staging // 2)]))

    # one forced repack: full re-upload cost
    t0 = time.perf_counter()
    store.compact()
    wall_compact = time.perf_counter() - t0

    n = batches * staging
    return {
        "capacity_total": total,
        "staging_size": staging,
        "batches": batches,
        "placement": store.placement,
        "redeal": store.redeal,
        "insert_pts_per_s": n / wall_ins,
        "update_pts_per_s": n / wall_upd,
        "delete_pts_per_s": (n // 2) / wall_del,
        "compact_s": wall_compact,
        "auto_compactions": store.stats.compactions - 1,  # minus the forced one
        "last_compact_reason": store.stats.last_compact_reason,
        "final_live": store.live_count,
        "final_generation": store.generation,
    }


def _phase_under_ingest(rng, cap, staging, n_queries) -> dict:
    """Quiet-vs-ingest serve-latency A/B with background maintenance.

    One pruned, device-routed server over a ``maintenance="background"``
    store: phase A measures closed-loop p50/p99 against the quiet store;
    phase B repeats the measurement while a drifting-cluster ingest
    thread streams insert+delete waves — drift inflates covering radii,
    so the background worker's re-tighten AND split paths both fire
    mid-run (asserted), not just the scatter apply.
    """
    from repro.runtime import KnnServer
    from repro.store import MutableStore

    k = common.K_MACHINES
    n_clusters = 2 * k
    centers = rng.normal(scale=25.0, size=(n_clusters, DIM))
    cfg = CONFIG.replace(
        dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
        route="pruned", route_compute="device", summary_pivots=2,
        placement="affinity", redeal="proximity",
        retighten_every=4, split_radius_factor=1.2,
        maintenance="background",
        store_capacity_per_shard=cap, store_staging_size=staging,
        # full obs surface on: this phase races queries against epoch
        # swaps and maintenance commits, exactly where the Theorem-1
        # contract and shadow-exact auditors earn their keep
        obs_trace=True, obs_audit_every=8)
    store = MutableStore(DIM, mesh=common.kmachine_mesh(), axis_name="x",
                         **cfg.store_kwargs())
    prefill_per = (cap * k // 2) // n_clusters
    for c in range(n_clusters):
        store.insert((centers[c] + rng.normal(size=(prefill_per, DIM)))
                     .astype(np.float32))
    store.flush()
    srv = KnnServer(store=store, cfg=cfg)
    srv.warmup()

    def measure(qrng):
        lat, gens = [], []
        for _ in range(8):       # warmup queries outside the window
            c = int(qrng.integers(0, n_clusters))
            srv.submit((centers[c] + qrng.normal(size=DIM))
                       .astype(np.float32), 8).result(timeout=120)
        t0 = time.perf_counter()
        for _ in range(n_queries):
            c = int(qrng.integers(0, n_clusters))
            res = srv.submit((centers[c] + qrng.normal(size=DIM))
                             .astype(np.float32), 8).result(timeout=120)
            lat.append(res.latency_s)
            gens.append(res.generation)
        wall = time.perf_counter() - t0
        lat = np.asarray(lat)
        return {"qps": n_queries / wall,
                "p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3),
                "generations_spanned": int(max(gens) - min(gens))}

    stop = threading.Event()
    mutations = {"applied": 0}

    def ingest_loop():
        # Net-zero drifting churn: each cycle inserts a wave into one
        # cluster whose center has moved, then deletes the oldest live
        # wave — the store can never fill, radii inflate under the
        # drift (arming split), and deletes make shards due for
        # re-tightening.  Two epoch swaps per cycle.  The cycle is
        # paced (a short sleep between waves) so the A/B measures
        # serving-plane interference — lock windows, epoch swaps,
        # maintenance commits — rather than raw CPU oversubscription
        # of the host-thread "machines"; an unthrottled busy-loop
        # ingester on the 8-device host simulation just measures the
        # scheduler.
        r = np.random.default_rng(11)
        drifted = centers.copy()
        step = r.normal(size=(n_clusters, DIM))
        step *= 3.0 / np.linalg.norm(step, axis=1, keepdims=True)
        waves = []          # FIFO of inserted-wave ids (oldest deleted)
        while not stop.is_set():
            c = mutations["applied"] % n_clusters
            drifted[c] += step[c]
            waves.append(store.insert(
                (drifted[c] + r.normal(size=(staging // 4, DIM)))
                .astype(np.float32)))
            store.flush()
            if len(waves) > 1:
                store.delete(waves.pop(0))
                store.flush()
            mutations["applied"] += 1
            time.sleep(0.1)

    with srv.serving():
        quiet = measure(np.random.default_rng(21))
        t = threading.Thread(target=ingest_loop, daemon=True)
        t.start()
        under = measure(np.random.default_rng(22))
        # the A/B is only meaningful if maintenance actually churned:
        # hold the ingest open (bounded) until both paths have fired
        deadline = time.perf_counter() + 120
        while ((store.stats.retightens == 0 or store.stats.splits == 0)
               and time.perf_counter() < deadline):
            time.sleep(0.02)
        stop.set()
        t.join()
    store.close()

    assert store.stats.retightens > 0, "no re-tighten fired mid-run"
    assert store.stats.splits > 0, "no split fired mid-run"
    worker = store.maintenance_stats()["worker"]
    assert worker["errors"] == 0, worker["error"]

    return {
        "queries": n_queries,
        "maintenance": "background",
        "route": cfg.route,
        "route_compute": cfg.route_compute,
        "quiet": quiet,
        "under_ingest": under,
        "p99_ratio_vs_quiet": under["p99_ms"] / quiet["p99_ms"],
        "ingest_cycles": mutations["applied"],
        "dropped_queries": 0,   # every submit() above resolved (else: raise)
        "retightens": store.stats.retightens,
        "splits": store.stats.splits,
        "worker": worker,
        "final_live": store.live_count,
        "compactions": store.stats.compactions,
        # audited-serving verdicts + per-stage p50/p99 for the whole
        # quiet-vs-ingest run (benchmarks/common.py obs_section)
        "obs": common.obs_section(srv),
    }


def run(emit=print, out_path=None, smoke: bool = False) -> dict:
    cap = 256 if smoke else CAP_PER_SHARD
    staging = 32 if smoke else STAGING
    batches = 6 if smoke else INGEST_BATCHES
    n_queries = 24 if smoke else QUERIES_UNDER_INGEST
    rng = np.random.default_rng(7)

    report = {
        "dim": DIM, "l_max": L_MAX, "k_machines": common.K_MACHINES,
        "smoke": smoke,
        "ingest": _phase_ingest(rng, cap, staging, batches),
        # placement-policy write-path cost (store/placement.py): the
        # affinity pick consults centroids per applied insert, and the
        # proximity re-deal runs Lloyd at the forced compaction — this
        # entry prices both against the balance/round-robin baseline
        # above.
        "ingest_affinity": _phase_ingest(rng, cap, staging, batches,
                                         placement="affinity",
                                         redeal="proximity"),
        "under_ingest": _phase_under_ingest(rng, cap, staging, n_queries),
    }
    ing, und = report["ingest"], report["under_ingest"]
    emit(common.row(
        "ingest_insert", 1e6 * staging / ing["insert_pts_per_s"],
        f"pts_per_s={ing['insert_pts_per_s']:.0f} "
        f"compact_s={ing['compact_s']:.3f}"))
    aff = report["ingest_affinity"]
    emit(common.row(
        "ingest_insert_affinity", 1e6 * staging / aff["insert_pts_per_s"],
        f"pts_per_s={aff['insert_pts_per_s']:.0f} "
        f"compact_s={aff['compact_s']:.3f} (redeal=proximity)"))
    emit(common.row(
        "query_quiet_store", 1e6 / und["quiet"]["qps"],
        f"qps={und['quiet']['qps']:.1f} "
        f"p50={und['quiet']['p50_ms']:.2f}ms "
        f"p99={und['quiet']['p99_ms']:.2f}ms"))
    emit(common.row(
        "query_under_ingest", 1e6 / und["under_ingest"]["qps"],
        f"qps={und['under_ingest']['qps']:.1f} "
        f"p50={und['under_ingest']['p50_ms']:.2f}ms "
        f"p99={und['under_ingest']['p99_ms']:.2f}ms "
        f"p99_ratio={und['p99_ratio_vs_quiet']:.2f} "
        f"gens={und['under_ingest']['generations_spanned']} "
        f"retightens={und['retightens']} splits={und['splits']}"))
    common.stamp(report)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        emit(f"# wrote {out_path}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ingest.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; CI dry-run (make bench-smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(emit=print, out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
