"""Bench-regression sentinel (make bench-smoke / obs-smoke gate).

Compares the bench report a run just produced against the rolling
baseline in the tracked ``BENCH_history.jsonl`` ledger (median of the
last 5 rows with the same smoke flag — see benchmarks/perf_ledger.py)
and fails CI when the run regresses past noise-tolerant bounds:

  * p50/p99 latency may grow to at most 1.6x baseline + 2.0 ms — wide
    enough that shared-runner jitter never trips it, tight enough that
    an injected 2x p99 regression demonstrably fails (--self-test
    proves both directions on a synthetic ledger);
  * throughput (selection qps and the routed arm's qps) may drop to at
    most baseline / 1.6;
  * routing efficiency may decay by at most one extra touched shard,
    and the approximate tier's candidate fraction by at most
    1.5x + 0.05 absolute;
  * measured recall_min may not fall more than 0.02 below baseline
    (the bench already hard-asserts the configured floor inline);
  * the ensemble-prediction arm's accuracy may not fall more than 0.02
    below baseline, and its per-query message bill may not grow by
    more than one message (the bench hard-asserts messages ==
    shards_touched per query inline);
  * contract violations and shadow divergences must be exactly zero —
    correctness counters get no noise allowance.

A run with no prior same-flag rows passes as a bootstrap (the seeded
ledger on main means CI always has a baseline).  Pure stdlib — this
gate must run even where jax cannot import.

  python benchmarks/check_perf.py --report /tmp/BENCH_serve_smoke.json \
      --history BENCH_history.jsonl
  python benchmarks/check_perf.py --self-test
"""

import argparse
import json
import sys

try:
    from benchmarks import perf_ledger
except ImportError:
    import perf_ledger

# Multiplicative headroom on latency bounds / throughput floors, and
# the absolute slack (ms) that keeps tiny-baseline smoke runs from
# flapping on scheduler noise.
LATENCY_FACTOR = 1.6
LATENCY_SLACK_MS = 2.0
THROUGHPUT_FACTOR = 1.6
SHARDS_SLACK = 1.0
CAND_FACTOR = 1.5
CAND_SLACK = 0.05
RECALL_SLACK = 0.02
ACCURACY_SLACK = 0.02
MESSAGES_SLACK = 1.0


def _check(row: dict, base: dict) -> list:
    """All bound violations of ``row`` against ``base`` (empty = pass)."""
    bad = []

    def upper(field, bound, label):
        v = row.get(field)
        if v is None or base.get(field) is None:
            return
        if float(v) > bound:
            bad.append(f"{field}: {float(v):.4g} > {label} = {bound:.4g} "
                       f"(baseline {base[field]:.4g})")

    def lower(field, bound, label):
        v = row.get(field)
        if v is None or base.get(field) is None:
            return
        if float(v) < bound:
            bad.append(f"{field}: {float(v):.4g} < {label} = {bound:.4g} "
                       f"(baseline {base[field]:.4g})")

    for field in ("p50_ms", "p99_ms"):
        if base.get(field) is not None:
            upper(field, base[field] * LATENCY_FACTOR + LATENCY_SLACK_MS,
                  f"{LATENCY_FACTOR}x + {LATENCY_SLACK_MS}ms")
    for field in ("qps", "routed_qps"):
        if base.get(field) is not None:
            lower(field, base[field] / THROUGHPUT_FACTOR,
                  f"baseline / {THROUGHPUT_FACTOR}")
    if base.get("shards_touched") is not None:
        upper("shards_touched", base["shards_touched"] + SHARDS_SLACK,
              f"baseline + {SHARDS_SLACK}")
    if base.get("candidate_fraction") is not None:
        upper("candidate_fraction",
              base["candidate_fraction"] * CAND_FACTOR + CAND_SLACK,
              f"{CAND_FACTOR}x + {CAND_SLACK}")
    if base.get("recall_min") is not None:
        lower("recall_min", base["recall_min"] - RECALL_SLACK,
              f"baseline - {RECALL_SLACK}")
    if base.get("predict_accuracy") is not None:
        lower("predict_accuracy",
              base["predict_accuracy"] - ACCURACY_SLACK,
              f"baseline - {ACCURACY_SLACK}")
    if base.get("predict_messages") is not None:
        upper("predict_messages",
              base["predict_messages"] + MESSAGES_SLACK,
              f"baseline + {MESSAGES_SLACK}")
    for field in ("contract_violations", "shadow_divergences"):
        v = row.get(field)
        if v is not None and int(v) != 0:
            bad.append(f"{field}: {v} != 0 (correctness counters get "
                       f"no noise allowance)")
    return bad


def check(row: dict, history: list, *, window: int = 5) -> int:
    """Print the verdict for one ledger row; 0 = pass, 1 = regression."""
    base = perf_ledger.baseline(history, smoke=row.get("smoke", False),
                                window=window)
    flavor = "smoke" if row.get("smoke") else "full"
    if base is None:
        print(f"check_perf: PASS (bootstrap — no prior {flavor} rows "
              f"in the ledger)")
        return 0
    bad = _check(row, base)
    if bad:
        print(f"check_perf: FAIL vs {base['rows']}-row {flavor} baseline "
              f"(commits {', '.join(base['commits'])}):")
        for b in bad:
            print(f"  - {b}")
        return 1
    print(f"check_perf: PASS vs {base['rows']}-row {flavor} baseline — "
          f"p99 {row.get('p99_ms'):.2f}ms (baseline "
          f"{base['p99_ms']:.2f}ms), qps {row.get('qps'):.0f} "
          f"(baseline {base['qps']:.0f})")
    return 0


def self_test() -> int:
    """Prove the sentinel in both directions on a synthetic ledger: an
    unregressed row passes, and an injected 2x p99 regression fails."""
    base_row = {
        "schema": perf_ledger.SCHEMA, "git_commit": "selftest",
        "smoke": True, "qps": 120.0, "p50_ms": 8.0, "p99_ms": 20.0,
        "routed_qps": 90.0, "shards_touched": 2.5,
        "candidate_fraction": 0.25, "recall_min": 0.99,
        "predict_accuracy": 0.97, "predict_messages": 8.0,
        "contract_violations": 0, "shadow_divergences": 0,
    }
    history = [dict(base_row) for _ in range(5)]

    ok_row = dict(base_row, p99_ms=24.0, qps=100.0)  # in-noise drift
    if check(ok_row, history) != 0:
        print("check_perf: SELF-TEST FAIL — in-noise row was rejected")
        return 1

    bad_row = dict(base_row, p99_ms=40.0)  # injected 2x p99 regression
    if check(bad_row, history) == 0:
        print("check_perf: SELF-TEST FAIL — 2x p99 regression passed")
        return 1

    slow_row = dict(base_row, qps=50.0)  # >1.6x throughput collapse
    if check(slow_row, history) == 0:
        print("check_perf: SELF-TEST FAIL — qps collapse passed")
        return 1

    dirty_row = dict(base_row, contract_violations=1)
    if check(dirty_row, history) == 0:
        print("check_perf: SELF-TEST FAIL — contract violation passed")
        return 1

    dumb_row = dict(base_row, predict_accuracy=0.80)
    if check(dumb_row, history) == 0:
        print("check_perf: SELF-TEST FAIL — prediction accuracy "
              "collapse passed")
        return 1

    print("check_perf: SELF-TEST PASS — clean row accepted; 2x p99, "
          "qps collapse, contract violation, and accuracy collapse "
          "all rejected")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="/tmp/BENCH_serve_smoke.json",
                    help="bench_serve JSON report to judge")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="tracked perf ledger to baseline against")
    ap.add_argument("--window", type=int, default=5,
                    help="rows per rolling baseline")
    ap.add_argument("--self-test", action="store_true",
                    help="prove the bounds on a synthetic ledger")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    with open(args.report) as f:
        report = json.load(f)
    row = perf_ledger.summarize(report)
    history = perf_ledger.load_history(args.history)
    # The run that produced --report usually appended its own row
    # already; judge it against the rows that precede it.
    if history and history[-1].get("timestamp") == row.get("timestamp") \
            and history[-1].get("git_commit") == row.get("git_commit"):
        history = history[:-1]
    sys.exit(check(row, history, window=args.window))


if __name__ == "__main__":
    main()
